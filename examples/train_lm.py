"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Demonstrates the full substrate on real (synthetic-bigram) data: sharded
deterministic pipeline -> jitted train step (grad accumulation + remat) ->
async atomic checkpoints -> a mid-run injected node failure with automatic
restart -> loss convergence toward the data entropy floor (ln 4 ≈ 1.386).

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --small    # CI-sized
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs.base import AttnCfg, ModelConfig
from repro.runtime import (FailureInjector, StragglerMonitor,
                           TrainLoopConfig, run_resilient)


def model_100m() -> ModelConfig:
    """12L d=640 GQA ff=1920 vocab=32768 — ~99M params."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=640, d_ff=1920,
        vocab=32_768, block_pattern=(("attn", "dense"),),
        attn=AttnCfg(n_heads=10, n_kv_heads=2, head_dim=64),
        act="silu_glu", grad_accum=1, remat="none")


def model_small() -> ModelConfig:
    return ModelConfig(
        name="lm-small", family="dense", n_layers=2, d_model=128, d_ff=384,
        vocab=2048, block_pattern=(("attn", "dense"),),
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=32),
        act="silu_glu", grad_accum=1, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    steps = args.steps or (60 if args.small else 300)
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro-train-")
    loop = TrainLoopConfig(
        steps=steps,
        seq_len=64 if args.small else 128,
        global_batch=8 if args.small else 4,
        lr=1e-3, warmup=max(10, steps // 10),
        data_kind="bigram",                    # entropy floor = ln(4)
        ckpt_dir=ckpt_dir, ckpt_interval=max(10, steps // 6),
        log_interval=max(1, steps // 15),
        failures=FailureInjector({steps // 2: "crash"}),   # mid-run node loss
        straggler=StragglerMonitor(),
        on_metrics=lambda r: print(
            f"  step {r['step']:5d}  loss {r['loss']:.4f}  "
            f"{r['sec']*1e3:9.1f} ms"))

    out = run_resilient(cfg, loop, max_restarts=2)
    first = min(out["losses"])
    print(f"\nrestarts (injected node failure): {out['restarts']}")
    print(f"loss: {out['losses'][first]:.3f} -> {out['final_loss']:.3f} "
          f"(data entropy floor ~1.386)")
    print(f"checkpoints under {ckpt_dir}")
    assert out["final_loss"] < out["losses"][first], "no learning happened?!"


if __name__ == "__main__":
    main()
