"""Batched serving example: prefill + decode rounds with throughput stats.

A reduced qwen2.5-3b serves a queue of random-prompt requests in batched
rounds; the planner first recommends how to split a chip budget between
replicas for the decode shape (the paper's replication = serving replicas).

    PYTHONPATH=src python examples/serve_lm.py                # single-device
    PYTHONPATH=src python examples/serve_lm.py --pipeline     # planned STG

``--pipeline`` serves the same queue through the decode pipeline: the
planner's decode-shape plan is placed on the local device pool
(plan -> placement -> prefill/decode stage programs -> LMServer), request
groups stream concurrently through the stages, per-stage KV-cache slices
stay resident on their placement slices, and sampled tokens feed back
over a continuous token-stream channel.  Completions are token-identical
to the single-device backend under greedy sampling.

``--trace out.json`` (with ``--pipeline``) records the serve through the
runtime tracer and exports a Chrome-trace JSON — open it in Perfetto or
chrome://tracing to see one lane per (stage, replica), wait spans
annotated with the blamed FIFO, and FIFO-occupancy counter tracks.

``--lint-only`` builds the same pipelined plan, runs the static verifier
(`core.verify.verify_decode_plan` — channel/cycle credits, fusion
legality, placement consistency, cache-donation avals), prints the full
verification report, and exits without serving — exit status 1 on any
ERROR finding.
"""
import sys

sys.path.insert(0, "src")

import json

import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeCfg
from repro.core import planner
from repro.runtime.server import LMServer, Request


def main(pipeline: bool = False, trace_path: str | None = None,
         lint_only: bool = False):
    arch = "qwen2.5-3b"
    cfg_full = get_config(arch)

    # planner: how should 64 chips serve decode_32k traffic?
    p = planner.plan(cfg_full, SHAPES["decode_32k"], chips=64)
    print("planner (64-chip serving budget):")
    print(p.summary())
    print()

    # actual serving at CPU scale with the reduced config
    cfg = cfg_full.reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab,
                                        rng.integers(4, 25)).tolist(),
                    max_new=16)
            for i in range(12)]
    pipe = None
    if pipeline or lint_only:
        from repro.graphs import lm_graph
        from repro.runtime.pipeline import DecodePipeline

        # re-plan the reduced config at pool scale, then place + compile it
        shape = ShapeCfg("decode_smoke", 64, 16, "decode")
        small = planner.plan(cfg, shape, chips=8, max_tp=4)
        stg, _ = lm_graph.build_stg(cfg, shape, max_tp=4)
        pipe = DecodePipeline(cfg, stg, small, warmup=not lint_only)
        print("pipelined backend:")
        print(pipe.placement.summary())
        print()
    if lint_only:
        from repro.core import verify
        from repro.models import blocks
        from repro.runtime.server import _bucket

        # the same plan tuple the serve below would preflight: 12
        # requests grouped max_batch=4 at a time
        shapes = []
        for lo in range(0, len(reqs), 4):
            chunk = reqs[lo:lo + 4]
            bucket = _bucket(max(len(r.prompt) for r in chunk))
            cap = blocks.attn_cache_capacity(
                cfg, bucket + max(r.max_new for r in chunk))
            shapes.append((len(chunk), bucket, cap))
        report = verify.verify_decode_plan(
            pipe, n_groups=len(shapes), group_shapes=shapes)
        print(report.render())
        sys.exit(0 if report.ok() else 1)
    tracer = None
    if trace_path is not None:
        if pipe is None:
            sys.exit("--trace needs --pipeline (the single-device backend "
                     "has no stage pipeline to trace)")
        from repro.runtime.pipeline import Tracer
        tracer = Tracer()
    srv = LMServer(cfg, max_batch=4, temperature=0.0, pipeline=pipe,
                   tracer=tracer)
    outs = srv.serve(reqs)
    for c in outs[:3]:
        print(f"req {c.uid}: {c.prompt_len} prompt tok -> "
              f"{len(c.tokens)} generated {c.tokens[:8]}...")
    print(json.dumps(srv.stats.summary(), indent=1))
    if tracer is not None:
        tracer.save(trace_path)
        print(f"wrote Chrome trace to {trace_path} "
              f"(open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    args = sys.argv[1:]
    trace = args[args.index("--trace") + 1] if "--trace" in args else None
    main(pipeline="--pipeline" in args, trace_path=trace,
         lint_only="--lint-only" in args)
