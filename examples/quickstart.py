"""Quickstart: the paper's space/time trade-off, from JPEG to TPU pods.

Part 1 reproduces the paper's own experiment: the JPEG encoder STG with its
Table-1 implementation library, solved by both the ILP (Eq. 3/4) and the
heuristic (bottleneck budgeting + node combining) at the published inverse
throughput targets — the heuristic uses substantially less area (Table 2).

Part 2 runs the *same* trade-off machinery on a modern workload: qwen2.5-3b
training as a streaming task graph over TPU v5e chips, in both of the
paper's modes (area budget -> throughput; throughput target -> chips), and
shows elastic re-planning when the chip budget changes.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import SHAPES, get_config
from repro.core import heuristic, ilp, planner
from repro.core.fork_join import JPEG_CALIBRATED
from repro.graphs import jpeg


def part1_jpeg():
    print("=" * 72)
    print("Part 1 — paper reproduction: JPEG encoder, ILP vs heuristic")
    print("=" * 72)
    stg = jpeg.build_stg()
    print(f"{'v_tgt':>6s} {'ILP area':>10s} {'heur area':>10s} {'saving':>8s}")
    for v_tgt in (1, 2, 4, 8):
        r_ilp = ilp.min_area(stg, v_tgt, JPEG_CALIBRATED)
        r_heu = heuristic.min_area(stg, v_tgt, JPEG_CALIBRATED)
        save = 1 - r_heu.total_area / r_ilp.total_area
        print(f"{v_tgt:6d} {r_ilp.total_area:10.0f} {r_heu.total_area:10.0f} "
              f"{save:8.0%}")
    print("\n(the ILP cannot express node combining — paper §II.B.1)")


def part2_lm():
    print()
    print("=" * 72)
    print("Part 2 — the same trade-off on a TPU pod: qwen2.5-3b train_4k")
    print("=" * 72)
    cfg = get_config("qwen2.5-3b")
    shape = SHAPES["train_4k"]

    print("\nMode 1: one pod (256 chips) -> maximise throughput")
    p = planner.plan(cfg, shape, chips=256)
    print(p.summary())
    ex = planner.to_execution(p, cfg=cfg, chips=256)
    print(f"  -> GSPMD projection: mesh {ex.mesh_shape} "
          f"(dp={ex.dp}, tp={ex.tp}), fsdp={ex.fsdp}")

    print("\nMode 2: hit 1M train tokens/s -> minimise chips (ILP vs heuristic)")
    for eng in ("ilp", "heuristic"):
        q = planner.plan(cfg, shape, tokens_per_s=1e6, engine=eng)
        print(f"  {eng:9s}: {q.total_chips:6.1f} chips "
              f"({q.impl_chips:.0f} impl + {q.overhead_chips:.1f} routing), "
              f"achieves {q.tokens_per_s:,.0f} tok/s")

    print("\nElastic: the pod shrinks to 128 chips -> re-plan")
    new, diff = planner.replan(cfg, shape, p, new_chips=128)
    print(f"  {diff['chips'][0]:.0f} -> {diff['chips'][1]:.0f} chips, "
          f"throughput x{diff['throughput_ratio']:.2f}, "
          f"{len(diff['stages_changed'])} stages re-laid-out")


if __name__ == "__main__":
    part1_jpeg()
    part2_lm()
