"""Elastic scaling drill: train -> lose half the slice -> re-plan -> resume.

Runs with 8 simulated devices (XLA host platform override, set before jax
imports).  A model trains on an 8-chip mesh, checkpoints, then the slice
"shrinks" to 4 chips: the planner re-solves the space/time trade-off, the
checkpoint is restored against the new mesh's shardings, and training
resumes — same data order, continuous loss.  This is the paper's core
motivation (automatic re-scaling instead of manual re-programming).

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import os
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()
sys.path.insert(0, "src")

import tempfile

import jax

from repro.configs.base import AttnCfg, ModelConfig, ShapeCfg
from repro.core import planner
from repro.runtime import TrainLoopConfig, train_loop
from repro.runtime.elastic import rescale


def main():
    cfg = ModelConfig(
        name="lm-elastic", family="dense", n_layers=2, d_model=128, d_ff=256,
        vocab=1024, block_pattern=(("attn", "dense"),),
        attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=32),
        grad_accum=1, remat="none")
    shape = ShapeCfg("elastic", 64, 8, "train")
    ckpt = tempfile.mkdtemp(prefix="repro-elastic-")
    devs = jax.devices()
    print(f"{len(devs)} devices")

    # Phase 1: full slice (8 chips), planner-chosen layout
    p8 = planner.plan(cfg, shape, chips=8)
    ex8 = planner.to_execution(p8, cfg=cfg, chips=8)
    mesh8 = jax.make_mesh(ex8.mesh_shape, ex8.mesh_axes)
    print(f"phase 1: mesh {ex8.mesh_shape}  "
          f"(planned {p8.tokens_per_s:,.0f} tok/s)")
    s1 = train_loop(cfg, TrainLoopConfig(
        steps=20, seq_len=64, global_batch=8, ckpt_dir=ckpt, ckpt_interval=10,
        log_interval=5, warmup=5, tp=ex8.tp), mesh=mesh8)
    print(f"  steps {s1.steps_run}, loss {s1.final_loss:.4f}")

    # Phase 2: slice shrinks to 4 chips -> re-plan + reshard + resume
    r = rescale(cfg, shape, p8, new_chips=4, devices=devs[:4])
    print(f"phase 2: {r.summary()}")
    s2 = train_loop(cfg, TrainLoopConfig(
        steps=40, seq_len=64, global_batch=8, ckpt_dir=ckpt, ckpt_interval=10,
        log_interval=5, warmup=5, tp=r.execution.tp), mesh=r.mesh)
    print(f"  resumed from step {s2.restored_from}, "
          f"ran {s2.steps_run} more, loss {s2.final_loss:.4f}")

    # Phase 3: slice grows back to 8 -> re-plan again
    r2 = rescale(cfg, shape, r.plan, new_chips=8, devices=devs)
    print(f"phase 3: {r2.summary()}")
    s3 = train_loop(cfg, TrainLoopConfig(
        steps=60, seq_len=64, global_batch=8, ckpt_dir=ckpt, ckpt_interval=10,
        log_interval=5, warmup=5, tp=r2.execution.tp), mesh=r2.mesh)
    print(f"  resumed from step {s3.restored_from}, loss {s3.final_loss:.4f}")
    assert s3.final_step == 60


if __name__ == "__main__":
    main()
