"""Dump the while-loop tree (with conditions) of a compiled cell."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import re
import sys

from repro.analysis import hlo as H
from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.launch import sharding as shd
from repro.launch.dryrun import _shardings_for

import jax


def main(arch="qwen2.5-3b", shape_name="train_4k", tp="16", accum="0"):
    import dataclasses
    cfg = get_config(arch)
    if int(accum):
        cfg = dataclasses.replace(cfg, grad_accum=int(accum))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(tp=int(tp))
    policy = shd.ShardingPolicy(fsdp=(shape.kind == "train"))
    grad_sh = None
    if shape.kind == "train":
        from repro.launch.steps import abstract_params
        from repro.models import build_model
        params_struct = abstract_params(build_model(cfg))
        grad_sh = shd.tree_shardings(params_struct, mesh, cfg, policy)
    bundle = input_specs(cfg, shape, grad_shardings=grad_sh)
    in_sh = _shardings_for(bundle, mesh, cfg, policy)
    from repro import sharding_ctx as sctx
    with mesh, sctx.activate(sctx.from_mesh(mesh)):
        compiled = jax.jit(bundle.fn, in_shardings=in_sh).lower(*bundle.arg_specs).compile()
    text = compiled.as_text()
    with open("/tmp/qwen_hlo.txt", "w") as f:
        f.write(text)
    comps = H.split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)

    def walk(comp, depth=0, seen=frozenset()):
        if comp not in comps or depth > 12 or comp in seen:
            return
        seen = seen | {comp}
        n_coll = {}
        for line in comps[comp]:
            cm = H._COLLECTIVE_LINE.search(line)
            if cm:
                n_coll[cm.group(2)] = n_coll.get(cm.group(2), 0) + 1
        if n_coll:
            print("  " * depth + f"[{comp[:60]}] colls={n_coll}")
        for line in comps[comp]:
            wm = H._WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tc = H.trip_count(comps.get(cond, []))
                consts = []
                for l in comps.get(cond, []):
                    consts += H._S32_CONST.findall(l)
                print("  " * depth + f"WHILE trip={tc:.0f} consts={consts} "
                      f"body={body[:55]}")
                walk(body, depth + 1, seen)
                continue
            fm = H._CALL_RE.search(line)
            if fm:
                walk(fm.group(1), depth + 1, seen)

    walk(entry)


if __name__ == "__main__":
    main(*sys.argv[1:])
