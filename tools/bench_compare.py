"""Diff a benchmark run against a committed baseline — the perf gate.

Compares every ``BENCH_*.json`` in ``--new`` against the file of the same
name in ``--baseline``, matching rows by (workload, backend/path) and
diffing three metric families:

  * **tokens/s and roofline fraction** (``decode_tok_per_s``,
    ``prefill_tok_per_s``, ``measured_tokens_per_s``,
    ``fraction_of_roofline`` — the decode step's achieved fraction of
    the measured memory-bandwidth bound) — higher is better; a
    regression beyond ``--tolerance`` (default 20%) **fails** the run
    (exit 1);
  * **measured bubble** (``bubble_1f1b``, ``bubble_interleaved``) —
    lower is better; beyond-tolerance regressions warn (``--strict``
    escalates warnings to failures);
  * **per-stage inverse throughput / host overhead / stall time**
    (``per_stage_us``, ``per_stage_host_us``, ``per_stage_stall_ms``,
    ``per_stage_starve_ms`` … dicts) — lower is better; warns like
    bubble, as do the serving SLO percentiles (``ttft_p95_ms``,
    ``token_gap_p99_ms``, …) the traced bench_serve replay emits.  The
    SUM of ``per_stage_host_us`` is diffed too (``per_stage_host_us[sum]``)
    so total-dispatch creep spread across stages is visible even when
    every stage stays inside tolerance; the fused serve A/B row
    (backend ``pipelined-fused``) gates its tokens/s like any rate metric
    and warns on a shrinking ``speedup_vs_unfused``.

Wall-clock rates are host-dependent: a committed baseline is only
comparable on a similar host, which is why the PR-CI gate REGENERATES
its baseline — it re-runs the smoke benches from the PR's merge-base on
the same runner and compares that same-host pair (the committed
`benchmarks/baseline-smoke/` is the fallback when the base tree predates
the smoke mode, and the local runbook reference).  Refresh the committed
baselines after an intentional perf change with::

    PYTHONPATH=src python -m benchmarks.run --json-dir benchmarks/baseline
    PYTHONPATH=src python -m benchmarks.run pipeline serve --smoke \
        --json-dir benchmarks/baseline-smoke

Usage::

    python tools/bench_compare.py --baseline benchmarks/baseline-smoke \
        --new bench-artifacts [--tolerance 0.2] [--strict]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# metric name -> direction ("up" = higher is better), gate class
RATE_METRICS = {                      # regressions FAIL
    "decode_tok_per_s": "up",
    "prefill_tok_per_s": "up",
    "measured_tokens_per_s": "up",
    # achieved fraction of the measured memory-bandwidth bound for the
    # decode step (bench_serve roofline accounting) — bandwidth is
    # re-measured every run on the same host, so the ratio is
    # host-normalised and gates as hard as tokens/s
    "fraction_of_roofline": "up",
}
SOFT_METRICS = {                      # regressions WARN (fail with --strict)
    "bubble_1f1b": "down",
    "bubble_interleaved": "down",
    "v_measured": "down",
    # serving SLOs from the traced replay (bench_serve) — latency, so
    # lower is better; warn-only because tail percentiles are noisy on
    # shared CI hosts
    "queue_wait_p95_ms": "down",
    "ttft_p50_ms": "down",
    "ttft_p95_ms": "down",
    "ttft_p99_ms": "down",
    "token_gap_p50_ms": "down",
    "token_gap_p95_ms": "down",
    "token_gap_p99_ms": "down",
    # chaos-drill recovery metrics (bench_serve --inject): failover
    # recovery wall time and tokens dropped (parity is asserted in the
    # bench itself, so tokens_lost > baseline only appears if that
    # assertion is ever relaxed) — warn-only, recovery time is host noise
    "recovery_ms": "down",
    "tokens_lost": "down",
    # fused-vs-unfused serve A/B (bench_serve backend "pipelined-fused"):
    # the fusion win itself, tracked so a shrinking speedup warns even
    # while absolute tokens/s stays inside tolerance
    "speedup_vs_unfused": "up",
    # decode-kernel step A/B (bench_serve backend "pipelined-refdecode"):
    # fused step time over ref step time — the kernel win, tracked like
    # the fusion win above
    "kernel_step_speedup": "up",
}
# per-stage dict metric -> direction; all soft (per-stage values are the
# noisiest surface — the scalar roofline/rate metrics above carry the
# hard gates)
DICT_METRICS = {
    "per_stage_us": "down",
    "per_stage_host_us": "down",
    "per_stage_stall_ms": "down",
    "per_stage_starve_ms": "down",
    "per_stage_stall_cycles": "down",
    "per_stage_starve_cycles": "down",
    "per_stage_fraction_of_roofline": "up",
}
# dict metrics whose SUM is also diffed as a first-class warn metric
# (``metric[sum]``): total host dispatch per token is the quantity stage
# fusion optimises, and creep spread over many stages can hide inside
# per-stage tolerance while the total quietly regresses
SUM_METRICS = ("per_stage_host_us",)


def _finite(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and v == v and abs(v) != float("inf")


def _row_key(row: dict) -> tuple:
    return (row.get("workload", "?"), row.get("backend", row.get("path", "?")))


def _index(rows: list) -> dict:
    return {_row_key(r): r for r in rows if isinstance(r, dict)}


def _regression(direction: str, base: float, new: float) -> float:
    """Fractional regression (positive = worse), direction-normalised."""
    if not isinstance(base, (int, float)) or not isinstance(new, (int, float)):
        return 0.0
    if base <= 0:
        return 0.0
    delta = (base - new) / base if direction == "up" else (new - base) / base
    return delta


def compare_dirs(baseline_dir: str, new_dir: str, tolerance: float,
                 strict: bool = False, verbose: bool = True):
    """Returns (failures, warnings, compared) as lists of report lines."""
    failures, warnings, compared = [], [], []

    def check(name, key, metric, direction, base, new, hard):
        if not isinstance(base, (int, float)) or \
                not isinstance(new, (int, float)):
            return                        # e.g. a null SLO/stall field
        reg = _regression(direction, base, new)
        line = (f"{name} {key[0]}/{key[1]} {metric}: "
                f"{base:.4g} -> {new:.4g} ({-reg:+.1%})")
        compared.append(line)
        if reg > tolerance:
            (failures if hard or strict else warnings).append(line)

    names = sorted(f for f in os.listdir(new_dir)
                   if f.startswith("BENCH_") and f.endswith(".json")
                   and not f.endswith("_trace.json"))   # Chrome traces

    for name in names:
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(base_path):
            warnings.append(f"{name}: no baseline file (new bench? refresh "
                            f"the baseline to start its trajectory)")
            continue
        with open(base_path) as f:
            base_rows = _index(json.load(f))
        with open(os.path.join(new_dir, name)) as f:
            new_rows = _index(json.load(f))
        for key, nrow in new_rows.items():
            brow = base_rows.get(key)
            if brow is None:
                continue                      # workload not in baseline
            for metric, direction in RATE_METRICS.items():
                if metric in nrow and metric in brow:
                    check(name, key, metric, direction,
                          brow[metric], nrow[metric], hard=True)
            for metric, direction in SOFT_METRICS.items():
                if metric in nrow and metric in brow:
                    check(name, key, metric, direction,
                          brow[metric], nrow[metric], hard=False)
            for metric, direction in DICT_METRICS.items():
                bd, nd = brow.get(metric), nrow.get(metric)
                if isinstance(bd, dict) and isinstance(nd, dict):
                    for stage in sorted(set(bd) & set(nd)):
                        check(name, key, f"{metric}[{stage}]", direction,
                              bd[stage], nd[stage], hard=False)
                    if metric in SUM_METRICS:
                        bs = [v for v in bd.values() if _finite(v)]
                        ns = [v for v in nd.values() if _finite(v)]
                        if bs and ns:
                            check(name, key, f"{metric}[sum]", "down",
                                  sum(bs), sum(ns), hard=False)
    if verbose:
        for line in compared:
            print(f"  {line}")
        for line in warnings:
            print(f"WARN {line}")
        for line in failures:
            print(f"FAIL {line}")
    return failures, warnings, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="directory of committed BENCH_*.json")
    ap.add_argument("--new", required=True,
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression (default 0.2)")
    ap.add_argument("--strict", action="store_true",
                    help="escalate soft-metric warnings to failures")
    args = ap.parse_args(argv)
    failures, warnings_, compared = compare_dirs(
        args.baseline, args.new, args.tolerance, strict=args.strict)
    print(f"\nbench_compare: {len(compared)} metrics compared, "
          f"{len(warnings_)} warnings, {len(failures)} failures "
          f"(tolerance {args.tolerance:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
