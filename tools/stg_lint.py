#!/usr/bin/env python
"""stg-lint: run the static plan verifier over every committed example
graph, planner plan, schedule, and fusion plan — the CI gate that keeps
`core.verify`'s guarantees in sync with the code.

What it lints (all device-free):

  * **example graphs** — jpeg, StreamIt fft/filterbank/autocor, nbody:
    structural validity, selection coverage, and channel-capacity
    analysis under the real `ChannelSet.for_graph` sizing at
    capacity_blocks 1 and 2 (cb=1 is where rate-changing edges used to
    sit below the SDF liveness floor);
  * **config plans** — every registry arch x runnable shape: build the
    lm STG, run the planner, and verify the resulting (STG, Selection)
    pair;
  * **schedules** — fill-drain / 1F1B / interleaved 1F1B over a sweep of
    (stages, micro, chunks): the exact credit simulation of each op
    order against the default FIFO capacities;
  * **decode feedback sizing** — the head->embed cycle with the
    executor's default ``max(2, n_groups)`` stream capacity for 1..8
    groups;
  * **fusion plans** — `enumerate_fusions` over the jpeg chain and the
    tiny lm chain, each group applied via `restructure.combine` +
    `validate_restructure`.

Exit status 1 iff any ERROR finding (CI fails); WARNs print but pass.
``--fast`` lints a small subset (the test-suite smoke), ``-v`` prints
every report instead of only failing ones.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import restructure, verify  # noqa: E402
from repro.core.stg import Selection  # noqa: E402


def _lint(title: str, report, results: list, verbose: bool) -> None:
    results.append((title, report))
    if verbose or not report.ok():
        print(f"== {title}")
        print(report.render())
    else:
        n_warn = len(report.warnings())
        tail = f" ({n_warn} warning(s))" if n_warn else ""
        print(f"ok: {title}{tail}")


def lint_example_graphs(results, *, fast: bool, verbose: bool) -> None:
    from repro.graphs import jpeg, nbody, streamit
    builders = [("jpeg", jpeg.build_stg),
                ("streamit-fft", streamit.build_fft),
                ("nbody", nbody.build_stg)]
    if not fast:
        builders += [("streamit-filterbank", streamit.build_filterbank),
                     ("streamit-autocor", streamit.build_autocor)]
    for name, build in builders:
        stg = build()
        for cb in (1, 2):
            for pick, mk in (("fastest", Selection.fastest),
                             ("smallest", Selection.smallest)):
                rep = verify.verify_graph(stg, mk(stg), capacity_blocks=cb)
                _lint(f"graph {name} [{pick}, cb={cb}]", rep, results,
                      verbose)


def lint_config_plans(results, *, fast: bool, verbose: bool) -> None:
    from repro import configs
    from repro.core import planner
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import as_selection
    cells = [("tiny", "decode", None)]
    if not fast:
        cells = [(a, s, why) for a, s, ok, why in configs.all_cells()
                 if ok] + cells
    for arch, shape_name, _ in cells:
        if arch == "tiny":
            from repro.configs.base import ShapeCfg
            from repro.configs.tiny import CONFIG as cfg
            shape = ShapeCfg("decode_smoke", 64, 16, "decode")
        else:
            cfg = configs.get_config(arch)
            shape = configs.SHAPES[shape_name]
        try:
            stg, _info = lm_graph.build_stg(cfg, shape, max_tp=8)
            plan = planner.plan(cfg, shape, chips=64, max_tp=8)
        except (ValueError, KeyError) as e:
            # an unplannable cell is the planner's business, not a plan
            # verification failure — note it and move on
            print(f"skip: plan {arch}/{shape_name} — {e}")
            continue
        rep = verify.verify_graph(stg, as_selection(plan))
        _lint(f"plan {arch}/{shape_name}", rep, results, verbose)


def lint_schedules(results, *, fast: bool, verbose: bool) -> None:
    from repro.runtime.pipeline import schedule as S
    shapes = [(2, 4), (4, 8)] if fast else [(2, 2), (2, 4), (4, 8),
                                            (4, 16), (8, 8)]
    for p, m in shapes:
        for mk, name in ((S.fill_drain, "fill_drain"),
                         (S.one_f_one_b, "1f1b")):
            sched = mk(p, m)
            M = sched.n_model_stages
            for cb in (1, 2, 4):
                rep = verify.VerificationReport(
                    plan=f"{name}({p},{m}) cb={cb}")
                verify.verify_schedule_credits(
                    sched, [cb] * (M - 1),
                    [cb] * (M - 1) if sched.trains else [], rep)
                _lint(f"schedule {name}({p}x{m}) cb={cb}", rep, results,
                      verbose)
        for v in (2,) if fast else (2, 4):
            if v > 1 and m >= p * v and (p * v) % p == 0:
                sched = S.interleaved_1f1b(p, m, v)
                M = sched.n_model_stages
                rep = verify.VerificationReport(
                    plan=f"interleaved({p},{m},v{v})")
                verify.verify_schedule_credits(
                    sched, [4] * (M - 1), [4] * (M - 1), rep)
                _lint(f"schedule interleaved({p}x{m},v{v})", rep,
                      results, verbose)


def lint_decode_feedback(results, *, verbose: bool) -> None:
    for n_groups in (1, 2, 4, 8):
        fb = max(2, n_groups)      # the _ServeRun default sizing
        edges = [verify.EdgeSpec("embed", "blocks", 4, label="act0"),
                 verify.EdgeSpec("blocks", "head", 4, label="act1"),
                 verify.EdgeSpec("head", "embed", fb, label="feedback",
                                 gated=False)]
        rep = verify.VerificationReport(
            plan=f"decode feedback, {n_groups} group(s), capacity {fb}")
        verify.check_channel_capacities(edges, rep)
        verify.check_cycles(edges, n_groups, rep)
        _lint(f"decode feedback x{n_groups}", rep, results, verbose)


def lint_fusions(results, *, fast: bool, verbose: bool) -> None:
    from repro.graphs import jpeg
    stg = jpeg.build_stg()
    sel = Selection.fastest(stg)
    # only compute nodes combine (source/sink stay at the boundary)
    names = [n for n in stg.topo_order()
             if stg.nodes[n].kind == "compute"]
    plans = restructure.enumerate_fusions(names, max_group=3)
    if fast:
        plans = plans[:8]
    for groups in plans:
        rep = verify.VerificationReport(
            plan="jpeg fusion " + "+".join("|".join(g) for g in groups))
        verify.verify_fusion(names, groups, report=rep)
        verify.verify_graph_fusion(stg, sel, groups, rep)
        label = "+".join("/".join(g) for g in groups)
        _lint(f"fusion jpeg [{label}]", rep, results, verbose)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="small subset (the test-suite smoke)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every report, not just failures")
    args = ap.parse_args(argv)

    results: list = []
    lint_example_graphs(results, fast=args.fast, verbose=args.verbose)
    lint_config_plans(results, fast=args.fast, verbose=args.verbose)
    lint_schedules(results, fast=args.fast, verbose=args.verbose)
    lint_decode_feedback(results, verbose=args.verbose)
    lint_fusions(results, fast=args.fast, verbose=args.verbose)

    n_err = sum(len(r.errors()) for _, r in results)
    n_warn = sum(len(r.warnings()) for _, r in results)
    failed = [t for t, r in results if not r.ok()]
    print(f"\nstg-lint: {len(results)} plan(s) linted — "
          f"{n_err} error(s), {n_warn} warning(s)")
    if failed:
        print("failing plans:")
        for t in failed:
            print(f"  {t}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
