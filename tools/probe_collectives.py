"""Debug probe: where does the collective wire-byte total come from?

Lowers+compiles one cell, then walks the post-SPMD HLO the same way
repro.analysis.hlo.collect does, but records per-line attribution:
(computation, trip-multiplier product, kind, shard bytes, wire bytes).
Prints the top contributors so the accounting can be hand-verified.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import re
import sys

from repro.analysis import hlo as H
from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.launch import sharding as shd
from repro.launch.dryrun import _shardings_for

import jax


def main(arch="qwen2.5-3b", shape_name="train_4k", tp="16", accum="0",
         ep_axis="model", moe_impl="einsum"):
    import dataclasses
    from repro.models import blocks as _blocks
    _blocks.set_moe_impl(moe_impl)
    cfg = get_config(arch)
    if int(accum):
        cfg = dataclasses.replace(cfg, grad_accum=int(accum))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(tp=int(tp))
    policy = shd.ShardingPolicy(fsdp=(shape.kind == "train"),
                                seq_shard_cache=False, ep_axis=ep_axis)
    grad_sh = None
    if shape.kind == "train":
        from repro.launch.steps import abstract_params
        from repro.models import build_model
        params_struct = abstract_params(build_model(cfg))
        grad_sh = shd.tree_shardings(params_struct, mesh, cfg, policy)
    bundle = input_specs(cfg, shape, grad_shardings=grad_sh)
    in_sh = _shardings_for(bundle, mesh, cfg, policy)
    from repro import sharding_ctx as sctx
    with mesh, sctx.activate(sctx.from_mesh(mesh,
                                            ep_data=policy.ep_axis == "data")):
        jitted = jax.jit(bundle.fn, in_shardings=in_sh)
        compiled = jitted.lower(*bundle.arg_specs).compile()
    text = compiled.as_text()
    comps = H.split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)

    rows = []

    def walk(comp, mult, depth=0, seen=frozenset()):
        if comp not in comps or depth > 50 or comp in seen:
            return
        seen = seen | {comp}
        for line in comps[comp]:
            cm = H._COLLECTIVE_LINE.search(line)
            if cm:
                kind = cm.group(2)
                g = H._group_size(line, 256)
                shard = H._shape_bytes(cm.group(1))
                rows.append((comp, mult, kind, g, shard, line.strip()[:160]))
            wm = H._WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tc = H.trip_count(comps.get(cond, []))
                print(f"WHILE in {comp}: body={body} cond={cond} trip={tc}")
                walk(body, mult * tc, depth + 1, seen)
                continue
            fm = H._CALL_RE.search(line)
            if fm:
                walk(fm.group(1), mult, depth + 1, seen)

    walk(entry, 1.0)
    rows.sort(key=lambda r: -(r[1] * r[4]))
    total = 0.0
    for comp, mult, kind, g, shard, line in rows[:25]:
        print(f"mult={mult:8.0f} kind={kind:18s} g={g:4d} shard={shard/1e6:10.2f}MB "
              f"tot_wire={mult*shard*256/1e12:8.3f}TB  comp={comp[:40]}")
    for comp, mult, kind, g, shard, line in rows:
        total += mult * shard * 256
    print(f"\nnum collective lines: {len(rows)}; naive total (shard*256*mult): {total/1e12:.2f} TB")
    coll = H.collect(text, 256)
    print("collect() says:", {k: f"{v/1e12:.2f}TB" for k, v in coll.wire_bytes.items()})
    print("counts:", coll.counts)


if __name__ == "__main__":
    main(*sys.argv[1:])
