"""Activation-sharding context, threaded through model code.

Dependency-free (models must not import the launcher).  When active, the
model pins key activation layouts with with_sharding_constraint so GSPMD
doesn't invent pathological layouts — without these pins it chooses to
*replicate the batch dim* of activations to match FSDP-sharded weight
contracting dims (observed: 16x redundant compute + 25x collective traffic
on qwen2.5-3b train_4k; see EXPERIMENTS.md §Perf).

Model code calls the module-level ``act()`` helper with symbolic axes:

    q = sc.act(q, "dp", None, "tp", None)     # (B, S, H, hd)

which is a no-op unless a ``ShardCtx`` is activated (the launcher/dry-run
does ``with sharding_ctx.activate(ctx): jit(...).lower(...)``).  Symbols:
``"dp"`` = the data axes (batch), ``"tp"`` = the model axis.  Axes that do
not divide the dim are dropped per-dim (small models / odd head counts stay
unsharded rather than erroring).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    mesh: Any
    dp: tuple[str, ...]           # data axes (batch)
    tp: str = "model"
    sp: bool = False              # Megatron-style sequence parallelism:
                                  # residual stream's seq dim sharded over tp
                                  # (GSPMD turns the per-block all-reduces
                                  # into all-gather + reduce-scatter pairs —
                                  # half the wire bytes, sharded norms)
    ep_data: bool = False         # experts live on the data axes (a2a
                                  # dispatch); False: experts on the model
                                  # axis (the naive EP baseline)

    def _resolve(self, ax):
        if ax == "dp":
            return self.dp
        if ax == "tp":
            return self.tp
        if ax == "sp":
            return self.tp if self.sp else None
        if ax == "ep":
            return ("data",) if self.ep_data else self.tp
        if ax == "ep_tok":            # token dim of the dispatched tensor
            return None if self.ep_data else self.dp
        return ax

    def _ok(self, dim: int, axes) -> bool:
        if axes is None:
            return False
        n = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            n *= self.mesh.shape[a]
        return dim % n == 0

    def pin(self, x, *axes):
        """Constrain x: axes[i] is the mesh axis (or None) for dim i.
        Axes that don't divide the dim are dropped."""
        if x is None:
            return x
        spec = []
        for dim, ax in zip(x.shape, axes):
            ax = self._resolve(ax)
            spec.append(ax if self._ok(dim, ax) else None)
        while len(spec) < x.ndim:
            spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def batch(self, x):
        return self.pin(x, "dp")

    def batch_seq(self, x):
        """(B, S, D): batch over dp, features replicated."""
        return self.pin(x, "dp", None, None)

    def logits(self, x):
        """(B, S, V): batch over dp, vocab over tp."""
        return self.pin(x, "dp", None, "tp")


# -- module-level activation (used by model code without signature churn) ---
_ACTIVE: ShardCtx | None = None


@contextlib.contextmanager
def activate(ctx: ShardCtx | None):
    """Make ``ctx`` the active sharding context while tracing/lowering."""
    global _ACTIVE
    old = _ACTIVE
    _ACTIVE = ctx
    try:
        yield ctx
    finally:
        _ACTIVE = old


def current() -> ShardCtx | None:
    return _ACTIVE


def act(x, *axes):
    """Pin an activation if a context is active; identity otherwise."""
    if _ACTIVE is None or x is None:
        return x
    return _ACTIVE.pin(x, *axes)


def from_mesh(mesh, *, sp: bool = False, ep_data: bool = False) -> ShardCtx:
    """Build a ShardCtx from a mesh with ("pod",)? "data" + "model" axes."""
    names = mesh.axis_names
    dp = tuple(a for a in names if a != "model")
    return ShardCtx(mesh=mesh, dp=dp, tp="model", sp=sp, ep_data=ep_data)
