from .adafactor import adafactor  # noqa: F401
from .adamw import adamw  # noqa: F401
from .api import Optimizer, get_optimizer  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
