"""Error-feedback int8 gradient compression with ring reduce-scatter.

Why a custom ring: the obvious "quantize + all-gather" moves (n-1)*N int8
bytes per device — MORE than a ring all-reduce's 2(n-1)/n*N*4 f32 bytes
once n > 8.  The right primitive is a *quantized ring reduce-scatter*
(reduce chunks hop-by-hop, requantizing per hop) followed by an int8 ring
all-gather: per-device wire = 2(n-1)/n * N int8 bytes — 4x less than an
f32 ring all-reduce at any n.  Both rings are jax-native (`shard_map` +
`lax.ppermute`), so they lower to collective-permute chains that the
dry-run's HLO parser prices like any other collective
(benchmarks/bench_compress.py shows the measured wire ratio).

Per-hop requantization is lossy; the **error-feedback** buffer carries the
residual into the next step (EF-SGD-style), which preserves convergence —
tests/test_compress.py checks the EF contract (residual = exactly what was
not communicated) and end-to-end training parity on the bigram task.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------- int8 -----
def quantize_int8(x):
    """Symmetric global-scale int8: returns (q, scale) with scale ()."""
    a = jnp.max(jnp.abs(x))
    scale = (jnp.maximum(a, 1e-12) / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(x, err):
    """Error-feedback quantization: returns ((q, scale), new_err) with the
    contract  dequant(q, scale) + new_err == x + err  (exactly)."""
    corrected = x.astype(jnp.float32) + err
    q, s = quantize_int8(corrected)
    return (q, s), corrected - dequantize_int8(q, s)


# ------------------------------------------------- ring reduce-scatter -----
def ring_reduce_scatter_int8(x, axis_name: str, n: int):
    """Quantized ring RS over a named axis.  x: flat f32, size % n == 0.
    Returns this device's reduced chunk (f32, size |x|/n).
    Per-device wire: (n-1)/n * |x| int8 bytes (+ n-1 scalar scales)."""
    idx = jax.lax.axis_index(axis_name)
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # Device d injects chunk (d-1)%n; after hop i (1-based) it holds the
    # partial for chunk (d-1-i)%n and adds its own contribution; after
    # n-1 hops it holds the full sum of chunk d.
    def body(i, carry):
        q, s = carry
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        take = (idx - i - 2) % n
        summed = dequantize_int8(q, s) + chunks[take]
        return quantize_int8(summed)

    q0, s0 = quantize_int8(chunks[(idx - 1) % n])
    qf, sf = jax.lax.fori_loop(0, n - 1, body, (q0, s0))
    return dequantize_int8(qf, sf)


def ring_all_gather_int8(chunk, axis_name: str, n: int):
    """int8 ring AG of per-device chunks -> full flat f32 buffer.
    Per-device wire: (n-1)/n * |full| int8 bytes."""
    q, s = quantize_int8(chunk)
    perm = [(i, (i + 1) % n) for i in range(n)]
    idx = jax.lax.axis_index(axis_name)

    def body(i, carry):
        out_q, out_s, cur_q, cur_s = carry
        cur_q = jax.lax.ppermute(cur_q, axis_name, perm)
        cur_s = jax.lax.ppermute(cur_s, axis_name, perm)
        src = (idx - i - 1) % n
        out_q = jax.lax.dynamic_update_index_in_dim(out_q, cur_q, src, 0)
        out_s = jax.lax.dynamic_update_index_in_dim(out_s, cur_s, src, 0)
        return out_q, out_s, cur_q, cur_s

    out_q = jnp.zeros((n, *q.shape), jnp.int8)
    out_s = jnp.zeros((n,), jnp.float32)
    out_q = jax.lax.dynamic_update_index_in_dim(out_q, q, idx, 0)
    out_s = jax.lax.dynamic_update_index_in_dim(out_s, s, idx, 0)
    out_q, out_s, _, _ = jax.lax.fori_loop(0, n - 1, body,
                                           (out_q, out_s, q, s))
    return (out_q.astype(jnp.float32) * out_s[:, None]).reshape(-1)


def compressed_mean(x, axis_name: str, n: int):
    """Drop-in mean-over-axis: int8 ring RS + int8 ring AG (+EF outside)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunk = ring_reduce_scatter_int8(flat, axis_name, n)
    full = ring_all_gather_int8(chunk, axis_name, n)
    if pad:
        full = full[:-pad]
    return (full / n).reshape(x.shape)


# ----------------------------------------------------------- high level ----
@dataclass(frozen=True)
class CompressionState:
    """Per-device error-feedback buffers, stacked on a leading device dim
    (n, *leaf.shape), sharded over the sync axis."""
    err: dict

    @classmethod
    def init(cls, params, n: int):
        return cls(err=jax.tree.map(
            lambda p: jnp.zeros((n, *p.shape), jnp.float32), params))


def make_compressed_sync(mesh, axis: str = "data"):
    """Returns sync(local_grads, state) -> (synced, state').

    ``local_grads``: pytree with leading device dim (n, ...) sharded over
    ``axis`` — row i is device i's unreduced gradient.  ``synced`` has the
    same stacked layout; every row equals the EF-corrected int8-ring mean.
    """
    from jax.experimental.shard_map import shard_map
    n = mesh.shape[axis]

    def body(g_tree, err_tree):
        def one(g, e):
            g = g[0].astype(jnp.float32)
            e = e[0]
            gc = g + e
            synced = compressed_mean(gc, axis, n)
            return synced[None], (gc - synced)[None]
        pairs = jax.tree.map(one, g_tree, err_tree)
        synced = jax.tree.map(lambda p: p[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        errs = jax.tree.map(lambda p: p[1], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        return synced, errs

    def sync(local_grads, state: CompressionState):
        spec = jax.tree.map(lambda _: P(axis), local_grads)
        f = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                      out_specs=(spec, spec), check_rep=False)
        synced, new_err = f(local_grads, state.err)
        return synced, CompressionState(err=new_err)

    return sync
