"""AdamW with decoupled weight decay and global-norm clipping.

State per parameter: fp32 m and v (ZeRO-style sharding is applied by the
launcher's sharding rules, not here)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .api import Optimizer


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw(lr, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        gnorm = global_norm(grads)
        scale = 1.0
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            step_val = mhat / (jnp.sqrt(vhat) + eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                step_val = step_val + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * step_val
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update, name="adamw")
