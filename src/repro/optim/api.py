"""Minimal optimizer API (optax-style pure functions, no external deps)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable


@dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) ->
    (new_params, new_state).  All pure pytree->pytree functions."""

    init: Callable
    update: Callable
    name: str = "opt"


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    from .adafactor import adafactor
    from .adamw import adamw
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name}")
