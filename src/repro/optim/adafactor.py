"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

Used for the 400B-class configs (jamba-1.5-large, llama4-maverick): AdamW's
8 bytes/param of fp32 moments does not fit a single 256-chip v5e pod at
398B params; Adafactor's row+column factors are ~O(sqrt) of that.  This is
itself one of the framework's distributed-optimization features."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .api import Optimizer
from .adamw import global_norm


def adafactor(lr, *, decay: float = 0.99, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def factored(p) -> bool:
        return p.ndim >= 2 and min(p.shape[-2:]) >= min_dim_size_to_factor

    def init_leaf(p):
        if factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),          # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        return {"f": jax.tree.map(init_leaf, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8            # paper's decay schedule toward `decay`
        beta = jnp.minimum(beta, decay)
        lr_t = lr_fn(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * u
            if weight_decay and p.ndim >= 2:
                newp = newp - lr_t * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        out = jax.tree.map(upd, params, grads, state["f"],
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("v" in x or "vr" in x))
        # out leaves are (param, state) tuples at the positions of params
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"f": new_state}

    return Optimizer(init=init, update=update, name="adafactor")
