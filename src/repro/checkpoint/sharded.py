"""Atomic sharded checkpoints with async save and retention.

Commit protocol (multi-host safe by construction):
  1. every process writes its addressable shards into ``<dir>/.tmp-<step>-<nonce>/shard-{proc:05d}.npz``
  2. barrier (no-op single-process; ``jax.experimental.multihost_utils``
     at scale)
  3. process 0 writes ``meta.json`` (tree paths, shapes, dtypes, step,
     n_processes, user metadata), then atomically ``rename``s the tmp dir
     to ``step-<step>``.  A checkpoint directory is valid iff the rename
     happened, so readers can never observe a torn checkpoint.
  4. retention: keep the newest ``keep`` steps (plus any step in
     ``keep_every`` milestones), delete the rest.

Restore validates path-set/shape/dtype against a ``like`` pytree (from
``jax.eval_shape``) and device_puts against target shardings when given —
this is also the resharding path used by elastic rescale (restore the same
checkpoint under a different mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

import jax
import numpy as np

_STEP_PREFIX = "step-"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(p): np.asarray(l) for p, l in leaves}


def list_steps(ckpt_dir: str | os.PathLike) -> list[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    out = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith(_STEP_PREFIX):
            try:
                out.append(int(p.name[len(_STEP_PREFIX):]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _apply_retention(ckpt_dir: Path, keep: int, keep_every: int | None):
    steps = list_steps(ckpt_dir)
    if keep <= 0 or len(steps) <= keep:
        return
    protected = set(steps[-keep:])
    if keep_every:
        protected |= {s for s in steps if s % keep_every == 0}
    for s in steps:
        if s not in protected:
            shutil.rmtree(ckpt_dir / f"{_STEP_PREFIX}{s}", ignore_errors=True)


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, tree, *,
                    metadata: dict | None = None, keep: int = 3,
                    keep_every: int | None = None,
                    process_index: int | None = None,
                    n_processes: int | None = None) -> Path:
    """Write one atomic checkpoint; returns the committed directory."""
    proc = jax.process_index() if process_index is None else process_index
    nproc = jax.process_count() if n_processes is None else n_processes
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp-{step}-{os.getpid()}-{time.time_ns()}"
    tmp.mkdir()
    try:
        flat = _flatten(tree)
        np.savez(tmp / f"shard-{proc:05d}.npz", **flat)
        # (multi-host: barrier here so all shards exist before commit)
        if proc == 0:
            meta = {
                "step": int(step),
                "n_processes": int(nproc),
                "paths": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                          for k, v in flat.items()},
                "metadata": metadata or {},
                "time": time.time(),
            }
            (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
            final = d / f"{_STEP_PREFIX}{step}"
            if final.exists():            # re-save of same step: replace
                shutil.rmtree(final)
            os.rename(tmp, final)         # the atomic commit point
            _apply_retention(d, keep, keep_every)
            return final
        return tmp
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore_checkpoint(ckpt_dir: str | os.PathLike, like, *, step: int | None = None,
                       shardings=None, process_index: int | None = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — restored leaves are device_put against them (the
    elastic-reshard path).  Returns (tree, meta)."""
    proc = jax.process_index() if process_index is None else process_index
    d = Path(ckpt_dir)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {d}")
    cdir = d / f"{_STEP_PREFIX}{step}"
    meta = json.loads((cdir / "meta.json").read_text())
    with np.load(cdir / f"shard-{proc:05d}.npz") as z:
        flat = {k: z[k] for k in z.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    missing = [p for p, _ in paths if _path_str(p) not in flat]
    if missing:
        raise ValueError(f"checkpoint {cdir} missing leaves: "
                         f"{[_path_str(p) for p in missing][:5]}...")
    leaves = []
    for p, leaf in paths:
        k = _path_str(p)
        arr = flat[k]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{k}: checkpoint shape {arr.shape} != {want_shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta


class AsyncCheckpointer:
    """At-most-one-in-flight background checkpoint writer.

    ``save()`` snapshots the tree to host memory synchronously (cheap: a
    device->host copy) and enqueues the disk write, so the train loop only
    ever blocks on I/O if a previous save is still running (back-pressure,
    never unbounded memory).  ``wait()`` drains; always call it before
    process exit (the trainer does).
    """

    def __init__(self, ckpt_dir: str | os.PathLike, *, keep: int = 3,
                 keep_every: int | None = None):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self.keep_every = keep_every
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._inflight: Future | None = None
        self._lock = threading.Lock()
        self.saved_steps: list[int] = []

    def save(self, step: int, tree, *, metadata: dict | None = None) -> None:
        host_tree = jax.tree.map(lambda x: np.array(x, copy=True),
                                 tree)   # true snapshot, never a view
        with self._lock:
            if self._inflight is not None:
                self._inflight.result()              # back-pressure
            self._inflight = self._pool.submit(
                save_checkpoint, self.ckpt_dir, step, host_tree,
                metadata=metadata, keep=self.keep, keep_every=self.keep_every)
            self.saved_steps.append(int(step))

    def wait(self) -> None:
        with self._lock:
            if self._inflight is not None:
                self._inflight.result()
                self._inflight = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
