from .sharded import (AsyncCheckpointer, latest_step, list_steps,
                      restore_checkpoint, save_checkpoint)

__all__ = ["AsyncCheckpointer", "latest_step", "list_steps",
           "restore_checkpoint", "save_checkpoint"]
