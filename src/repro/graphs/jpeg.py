"""JPEG encoder STG (paper §III.B, Fig. 10, Tables 1-2).

Four producer/consumer kernels: Color Conversion -> DCT -> Quantization ->
Encoding, at 8x8-block granularity (one token = one 8x8 block of one
component).  Two layers:

  * the *published implementation library* (Table 1), fed verbatim to the
    trade-off finders to reproduce Table 2;
  * *functional* numpy kernels so transformed graphs can be simulated and
    checked for stream equivalence.
"""
from __future__ import annotations

import numpy as np

from ..core.stg import COMPUTE, SINK, SOURCE, STG, Impl, Node

# --- Table 1 (published implementation library) ---------------------------
TABLE1 = {
    "color": [("v1", 1, 512), ("v2", 2, 256), ("v3", 4, 128), ("v4", 8, 64)],
    "dct": [("v1", 1, 800), ("v2", 2, 400), ("v3", 4, 224), ("v4", 6, 160),
            ("v5", 32, 50)],
    "quant": [("v1", 1, 512), ("v2", 2, 256), ("v3", 4, 128), ("v4", 8, 64),
              ("v5", 128, 4)],
    "encode": [("v1", 512, 22)],
}

# Published Table 2 rows: v_tgt -> (ilp_total, heuristic_total)
TABLE2_TOTALS = {1: (23968, 13888), 2: (11920, 7456), 4: (5984, 3600), 8: (2976, 1736)}


def _impls(key: str) -> tuple[Impl, ...]:
    return tuple(Impl(name=n, area=a, ii=v) for (n, v, a) in TABLE1[key])


# --- functional kernels (token = float32 8x8 block) ------------------------
_QTABLE = np.array(  # standard JPEG luminance quantisation table
    [[16, 11, 10, 16, 24, 40, 51, 61],
     [12, 12, 14, 19, 26, 58, 60, 55],
     [14, 13, 16, 24, 40, 57, 69, 56],
     [14, 17, 22, 29, 51, 87, 80, 62],
     [18, 22, 37, 56, 68, 109, 103, 77],
     [24, 35, 55, 64, 81, 104, 113, 92],
     [49, 64, 78, 87, 103, 121, 120, 101],
     [72, 92, 95, 98, 112, 100, 103, 99]], dtype=np.float32)

_DCT_M = np.zeros((8, 8), dtype=np.float32)
for _k in range(8):
    for _n in range(8):
        _DCT_M[_k, _n] = np.cos(np.pi / 8 * (_n + 0.5) * _k)
_DCT_M[0] *= np.sqrt(1 / 8)
_DCT_M[1:] *= np.sqrt(2 / 8)

_ZIGZAG = sorted(((i, j) for i in range(8) for j in range(8)),
                 key=lambda p: (p[0] + p[1], p[1] if (p[0] + p[1]) % 2 else -p[1]))


def color_convert(block_rgb: np.ndarray) -> np.ndarray:
    """RGB (8,8,3) -> luma Y (8,8), BT.601."""
    r, g, b = block_rgb[..., 0], block_rgb[..., 1], block_rgb[..., 2]
    return (0.299 * r + 0.587 * g + 0.114 * b - 128.0).astype(np.float32)


def dct2(block: np.ndarray) -> np.ndarray:
    return (_DCT_M @ block @ _DCT_M.T).astype(np.float32)


def quantize(block: np.ndarray) -> np.ndarray:
    return np.round(block / _QTABLE).astype(np.int32)


def encode_rle(block: np.ndarray) -> tuple:
    """Zig-zag + run-length encode (DC kept verbatim); token = tuple."""
    zz = [int(block[i, j]) for (i, j) in _ZIGZAG]
    out = [zz[0]]
    run = 0
    for v in zz[1:]:
        if v == 0:
            run += 1
        else:
            out.append((run, v))
            run = 0
    out.append((0, 0))  # EOB
    return tuple(out)


def _pure(f):
    def fn(inputs, state):
        return [[f(inputs[0][0])]], state
    return fn


def build_stg() -> STG:
    g = STG()
    g.add_node(Node("camera", impls=(Impl("stream", area=0, ii=1e-9),),
                    kind=SOURCE, out_rates=(1,)))
    g.add_node(Node("color", impls=_impls("color"), fn=_pure(color_convert)))
    g.add_node(Node("dct", impls=_impls("dct"), fn=_pure(dct2)))
    g.add_node(Node("quant", impls=_impls("quant"), fn=_pure(quantize)))
    g.add_node(Node("encode", impls=_impls("encode"), fn=_pure(encode_rle)))
    g.add_node(Node("bitstream", impls=(Impl("sink", area=0, ii=1e-9),), kind=SINK))
    g.connect("camera", "color")
    g.connect("color", "dct")
    g.connect("dct", "quant")
    g.connect("quant", "encode")
    g.connect("encode", "bitstream")
    g.validate()
    return g


def random_blocks(n: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, size=(8, 8, 3)).astype(np.float32) for _ in range(n)]


def reference_pipeline(blocks: list[np.ndarray]) -> list:
    return [encode_rle(quantize(dct2(color_convert(b)))) for b in blocks]
