from . import jpeg, nbody, streamit  # noqa: F401
