"""N-body gravity force node (paper §II.A.3, Figs. 2-4, Eq. 2).

The 2D force calculation's primitive DAG.  Per the paper: division takes 8
cycles and stalls the naive pipeline at II=8 (Fig. 2); expansion reaches
II=1 (Fig. 3); the implementation frontier spans II = 1 .. 33 where 33 is
the whole node folded onto one PE (Fig. 4) — i.e. op iis sum to 33.

F_ij = G * Mi * Mj / |Pi - Pj|^3 * (Pi - Pj),  G = 0.0625
"""
from __future__ import annotations

import numpy as np

from ..core.intra_node import CompositeBody, PrimOp, enumerate_impls
from ..core.stg import SINK, SOURCE, STG, Impl, Node

G_CONST = 0.0625

# Primitive DAG for the 2D force kernel.  Latencies follow the paper's PE
# model (add/sub 1, mul 2, div/sqrt 8); total = 33 so the single-PE
# implementation has II = 33 exactly as Fig. 4's slowest point.
FORCE_OPS = (
    PrimOp("dx", "sub"),                              # Pi.x - Pj.x      (1)
    PrimOp("dy", "sub"),                              # Pi.y - Pj.y      (1)
    PrimOp("dx2", "mul", ("dx",)),                    # dx*dx            (2)
    PrimOp("dy2", "mul", ("dy",)),                    # dy*dy            (2)
    PrimOp("r2", "add", ("dx2", "dy2")),              # dx2+dy2          (1)
    PrimOp("r", "sqrt", ("r2",)),                     # sqrt             (8)
    PrimOp("r3", "mul", ("r2", "r")),                 # r2*r             (2)
    PrimOp("mm", "mul", ()),                          # Mi*Mj            (2)
    PrimOp("gmm", "mul", ("mm",)),                    # G*Mi*Mj          (2)
    PrimOp("f", "div", ("gmm", "r3")),                # gmm / r3         (8)
    PrimOp("fx", "mul", ("f", "dx")),                 # f*dx             (2)
    PrimOp("fy", "mul", ("f", "dy")),                 # f*dy             (2)
)

FORCE_BODY = CompositeBody(ops=FORCE_OPS)


def force_impls() -> list[Impl]:
    """The Fig. 4 frontier: II from 1 to 33."""
    return enumerate_impls(FORCE_BODY)


def force_fn(pair: tuple) -> tuple:
    """pair = (Pi(2,), Mi, Pj(2,), Mj) -> force vector (2,)."""
    pi, mi, pj, mj = pair
    d = np.asarray(pi, dtype=np.float64) - np.asarray(pj, dtype=np.float64)
    r2 = float(d @ d)
    r3 = r2 * np.sqrt(r2)
    f = G_CONST * mi * mj / r3
    return (f * d[0], f * d[1])


def build_stg() -> STG:
    """pairs -> force -> accumulate sink (streaming all-pairs)."""
    g = STG()
    g.add_node(Node("pairs", impls=(Impl("stream", area=0, ii=1e-9),), kind=SOURCE))
    def fn(inputs, state):
        return [[force_fn(inputs[0][0])]], state
    g.add_node(Node("force", impls=tuple(force_impls()), fn=fn))
    g.add_node(Node("acc", impls=(Impl("sink", area=0, ii=1e-9),), kind=SINK))
    g.connect("pairs", "force")
    g.connect("force", "acc")
    g.validate()
    return g


def random_pairs(n: int, seed: int = 0) -> list[tuple]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        pi, pj = rng.normal(size=2), rng.normal(size=2)
        while np.allclose(pi, pj):
            pj = rng.normal(size=2)
        out.append((tuple(pi), float(rng.uniform(0.5, 2)), tuple(pj),
                    float(rng.uniform(0.5, 2))))
    return out
