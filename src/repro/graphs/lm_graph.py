"""LM models as streaming task graphs — the paper's technique at pod scale.

The space/time scaling problem the paper solves for MPPA overlays is the
TPU parallelism-planning problem in disguise (DESIGN.md §3):

    composite node        = model stage (embed / layer block / head)
    implementation P_m^s  = tensor-parallel degree tp (node *splitting*):
                            area = tp chips, II = modeled µs per firing
    replication nr        = data parallelism over firings (microbatches /
                            serving slots), round-robin — exactly the
                            paper's replica semantics
    fork/join tree        = resharding/routing between stage groups with
                            mismatched replica counts; a pass-through
                            "router PE" costs the chip-time needed to
                            forward one firing's activations at the target
                            rate (``TPU_ROUTER`` below), so Eq. 9/14 and
                            the combining optimisation apply verbatim
    area budget A_C       = number of chips (HBM capacity filters the
                            implementation library per node)

A *firing* is one microbatch (train/prefill: ``mb_seqs`` sequences of
``seq_len`` tokens) or one decode step for one serving slot (``SLOT``
sequences, one token each).  II(tp) is the analytic three-term roofline
max — the same model EXPERIMENTS.md §Roofline validates against compiled
dry-run artifacts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

from ..analysis.roofline import HW_V5E, Hardware
from ..configs.base import ModelConfig, ShapeCfg
from ..core.fork_join import ForkJoinModel
from ..core.stg import STG, Channel, Impl, Node, scale_impls

BF16 = 2
F32 = 4
DECODE_SLOT = 8          # sequences per serving-slot firing


# ===========================================================================
# per-stage analytic costs
# ===========================================================================
@dataclass(frozen=True)
class StageCost:
    """Per-firing costs of one stage (before parallelisation).

    flops:        fwd(+bwd) floating ops per firing
    param_bytes:  weight bytes read per firing (compute copy, bf16)
    state_bytes:  persistent per-chip state that must FIT (params + optimizer
                  + grads for train; params + kv-cache share for decode)
    hbm_bytes:    HBM traffic per firing (params + activations + cache)
    coll_per_tp:  f(tp) -> per-chip collective bytes per firing at degree tp
    act_out_bytes: activation bytes leaving the stage per firing (boundary /
                  fork-join routing size)
    """
    name: str
    flops: float
    param_bytes: float
    state_bytes: float
    hbm_bytes: float
    act_out_bytes: float
    tp_collectives: str = "megatron"   # megatron | moe | none


def _attn_cost(cfg: ModelConfig, toks: int, ctx: int, train: bool,
               decode_batch: int = 0) -> tuple[float, float, float]:
    """(flops_fwd, params, extra_hbm) for one attention sublayer."""
    a = cfg.attn
    d = cfg.d_model
    qkvo = d * (a.n_heads + 2 * a.n_kv_heads) * a.head_dim \
        + a.n_heads * a.head_dim * d
    proj = 2.0 * toks * qkvo
    eff_ctx = min(ctx, a.window) if a.window else ctx
    # causal prefill sees ~ctx/2 average; decode sees the full cache
    avg_ctx = eff_ctx if decode_batch else eff_ctx / 2
    score = 2.0 * toks * avg_ctx * a.n_heads * a.head_dim * 2
    extra = 0.0
    if decode_batch:   # KV-cache read dominates decode
        extra = decode_batch * eff_ctx * 2 * a.n_kv_heads * a.head_dim * BF16
    return proj + score, qkvo, extra


def _mamba_cost(cfg: ModelConfig, toks: int) -> tuple[float, float]:
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    H = m.n_ssm_heads(d)
    N = m.d_state
    params = d * 2 * di + d * (2 * m.n_groups * N + H) + m.d_conv * di + di * d
    flops = 2.0 * toks * params + 6.0 * toks * di * N   # proj + SSD state math
    return flops, params


def _mlp_cost(cfg: ModelConfig, toks: int) -> tuple[float, float]:
    if cfg.d_ff == 0:
        return 0.0, 0.0
    mult = 3 if cfg.act == "silu_glu" else 2
    params = mult * cfg.d_model * cfg.d_ff
    return 2.0 * toks * params, params


def _moe_cost(cfg: ModelConfig, toks: int) -> tuple[float, float, float]:
    """(flops, params_total, params_active) for one MoE sublayer."""
    e = cfg.moe
    mult = 3 if cfg.act == "silu_glu" else 2
    per_expert = mult * cfg.d_model * e.d_ff
    params = e.n_experts * per_expert + cfg.d_model * e.n_experts
    active = e.top_k * per_expert
    if e.shared_expert:
        params += per_expert
        active += per_expert
    flops = 2.0 * toks * active + 2.0 * toks * cfg.d_model * e.n_experts
    return flops, params, active


def stage_costs(cfg: ModelConfig, shape: ShapeCfg, *,
                mb_seqs: int | None = None) -> tuple[list[StageCost], dict]:
    """Decompose (cfg, shape) into per-firing stage costs."""
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    if decode:
        slot = min(DECODE_SLOT, shape.global_batch)
        toks = slot
        ctx = shape.seq_len
        n_firings = shape.global_batch // slot
    else:
        mb_seqs = mb_seqs or max(1, shape.global_batch // cfg.grad_accum)
        toks = mb_seqs * shape.seq_len
        ctx = shape.seq_len
        n_firings = cfg.grad_accum if train else shape.global_batch // mb_seqs

    fb = 3.0 if train else 1.0            # bwd = 2x fwd
    # optimizer bytes/param: AdamW fp32 m+v = 8; Adafactor factored ≈ 1
    opt = (8.0 if cfg.optimizer == "adamw" else 1.0) if train else 0.0
    grad = 4.0 if train else 0.0          # fp32 grad accumulator
    act_out = toks * cfg.d_model * BF16
    d = cfg.d_model

    stages: list[StageCost] = []

    def add(name, flops_fwd, params, extra_hbm=0.0, extra_state=0.0,
            coll="megatron"):
        pb = params * BF16
        stages.append(StageCost(
            name=name,
            flops=fb * flops_fwd,
            param_bytes=pb,
            state_bytes=params * (F32 + opt + grad) + extra_state,
            hbm_bytes=pb + fb * (extra_hbm + 2 * act_out)
            + (params * opt / max(1, n_firings)),
            act_out_bytes=act_out,
            tp_collectives=coll))

    # embed (lookup is bytes-bound; flops negligible)
    vp = cfg.padded_vocab
    add("embed", 2.0 * toks * d, vp * d, coll="none")

    enc_layers = cfg.enc_layers if cfg.encdec else 0
    for li, (mixer, mlp) in enumerate(
            cfg.block_pattern * (cfg.n_layers // len(cfg.block_pattern))):
        flops = 0.0
        params = 0.0
        extra_hbm = 0.0
        extra_state = 0.0
        coll = "megatron"
        if mixer == "attn":
            f, p, eh = _attn_cost(cfg, toks, ctx, train,
                                  decode_batch=toks if decode else 0)
            flops += f
            params += p
            extra_hbm += eh
            if decode or shape.kind == "prefill":
                a = cfg.attn
                eff = min(ctx, a.window) if a.window else ctx
                extra_state += (shape.global_batch * eff * 2 * a.n_kv_heads
                                * a.head_dim * BF16)
        else:
            f, p = _mamba_cost(cfg, toks)
            flops += f
            params += p
            if decode or shape.kind == "prefill":
                m = cfg.mamba
                extra_state += (shape.global_batch * m.n_ssm_heads(d)
                                * m.head_dim * m.d_state * F32)
        if mlp == "moe":
            f, p, _ = _moe_cost(cfg, toks)
            flops += f
            params += p
            coll = "moe"
        else:
            f, p = _mlp_cost(cfg, toks)
            flops += f
            params += p
        add(f"block{li:02d}", flops, params, extra_hbm, extra_state, coll)
    # encoder layers (enc-dec): modelled as extra dense blocks on the prefix
    for li in range(enc_layers):
        toks_e = (cfg.num_prefix or 128) * (mb_seqs or 1)
        f1, p1, _ = _attn_cost(cfg, toks_e, cfg.num_prefix or 128, train)
        f2, p2 = _mlp_cost(cfg, toks_e)
        add(f"enc{li:02d}", f1 + f2, p1 + p2)

    # head + loss (train) / sampling logits (serve)
    head_flops = 2.0 * toks * d * vp
    add("head", head_flops, vp * d, coll="none")

    info = {"toks_per_firing": toks, "n_firings": n_firings,
            "act_bytes": act_out, "train": train,
            "mb_seqs": None if decode else mb_seqs}
    return stages, info


# ===========================================================================
# implementation libraries:  II(tp) from the three-term roofline
# ===========================================================================
def impl_library(st: StageCost, *, hw: Hardware, train: bool,
                 max_tp: int = 256, seq_len: int = 1,
                 toks: int = 1) -> list[Impl]:
    """One Impl per feasible tensor-parallel degree."""
    out = []
    tp = 1
    while tp <= max_tp:
        # memory feasibility: persistent state must fit the tp chips
        # (leave ~25% HBM headroom for activations/temps)
        if st.state_bytes / tp <= 0.75 * hw.hbm_bytes:
            compute_s = st.flops / (tp * hw.peak_flops)
            memory_s = st.hbm_bytes / (tp * hw.hbm_bw)
            if st.tp_collectives == "megatron" and tp > 1:
                per_chip = (2 if not train else 4) * 2 * (tp - 1) / tp \
                    * st.act_out_bytes / tp
                coll_s = per_chip / hw.link_bw
            elif st.tp_collectives == "moe" and tp > 1:
                per_chip = (2 if not train else 4) * (tp - 1) / tp \
                    * st.act_out_bytes / tp
                coll_s = per_chip / hw.link_bw
            else:
                coll_s = 0.0
            ii_us = max(compute_s, memory_s, coll_s) * 1e6
            out.append(Impl(name=f"tp{tp}", area=float(tp), ii=ii_us,
                            meta={"compute_us": compute_s * 1e6,
                                  "memory_us": memory_s * 1e6,
                                  "coll_us": coll_s * 1e6,
                                  "tp": tp}))
        tp *= 2
    if not out:
        raise ValueError(f"stage {st.name}: no tp <= {max_tp} fits "
                         f"{st.state_bytes/1e9:.1f}GB of state")
    return out


def tpu_fork_join(act_bytes: float, v_tgt_us: float, *,
                  hw: Hardware = HW_V5E, nf: int = 4) -> ForkJoinModel:
    """The paper's router PE, priced in chips: forwarding one firing's
    activations takes act_bytes/link_bw; sustaining one firing per
    v_tgt_us therefore costs (act_us / v_tgt_us) chip-equivalents."""
    act_us = act_bytes / hw.link_bw * 1e6
    return ForkJoinModel(nf=nf, node_area=act_us / max(v_tgt_us, 1e-9),
                         count_root=False)


def build_stg(cfg: ModelConfig, shape: ShapeCfg, *, hw: Hardware = HW_V5E,
              max_tp: int = 256, mb_seqs: int | None = None,
              ii_scale: dict[str, float] | None = None) -> tuple[STG, dict]:
    """The LM streaming task graph with per-node implementation libraries.

    ``ii_scale`` multiplies each named stage's implementation IIs — the
    measurement-feedback hook: runtime.pipeline reports measured/analytic
    ratios per stage, and replanning on the scaled graph sizes replica
    counts to *measured* behaviour instead of the roofline promise.
    """
    stages, info = stage_costs(cfg, shape, mb_seqs=mb_seqs)
    if ii_scale:
        unknown = set(ii_scale) - {st.name for st in stages}
        if unknown:
            raise ValueError(
                f"ii_scale names unknown stages {sorted(unknown)}; a typo'd "
                f"or regrouped key would silently skip calibration")
    g = STG()
    prev = None
    for st in stages:
        impls = impl_library(st, hw=hw, train=info["train"], max_tp=max_tp)
        if ii_scale and st.name in ii_scale:
            impls = scale_impls(impls, ii_scale[st.name])
        g.add_node(Node(name=st.name, impls=tuple(impls)))
        if prev is not None:
            g.connect(prev, st.name)
        prev = st.name
    info["stages"] = {st.name: st for st in stages}
    return g, info
