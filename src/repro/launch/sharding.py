"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Name-based rules over parameter paths (t5x-style).  Policy:
  * TP over "model": attention head projections, MLP hidden, experts (EP),
    vocab (embedding rows / head columns), mamba inner dim.
  * FSDP over "data" (+"pod"): the non-TP matrix dim of every large weight,
    applied only when divisible (vocab is pre-padded so it always is).
  * Everything 1-D (norms, biases vectors) replicated.
Optimizer state inherits its parameter's spec (fp32 moments are ZeRO-
sharded by construction).  The planner (repro.core.planner) selects the
policy knobs; this module just realises them.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .mesh import data_axes


@dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True            # shard params/opt-state over the data axes
    tp: bool = True              # tensor/expert parallelism over "model"
    seq_shard_cache: bool = False  # long-context: shard cache seq over data
    ep_axis: str = "model"       # "model": experts on the model axis (+FSDP
                                 # over data)  |  "data": experts on the data
                                 # axis + within-expert TP over model (a2a
                                 # dispatch; expert weights never gathered)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divisible(dim: int, mesh, axes) -> bool:
    if not axes:
        return True
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0


def param_spec(path: str, shape, mesh, cfg: ModelConfig,
               policy: ShardingPolicy) -> P:
    """PartitionSpec for one parameter leaf."""
    ndim = len(shape)
    dp = data_axes(mesh)
    fs = dp if policy.fsdp else None
    tp = "model" if policy.tp else None
    stacked = bool(re.search(r"(^|/)(layers|enc_layers)/", path))
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*axes):
        """Drop axes that don't divide; pad rank with None."""
        out = list(lead)
        for dim, ax in zip(body, axes):
            if ax is None:
                out.append(None)
            elif _divisible(dim, mesh, ax):
                out.append(ax)
            else:
                out.append(None)
        while len(out) < ndim:
            out.append(None)
        return P(*out)

    name = path.rsplit("/", 1)[-1]

    if name == "embed":
        return spec(tp, fs)                      # (V, D): vocab TP, d FSDP
    if name == "head":
        return spec(fs, tp)                      # (D, V)
    if "experts" in path and name in ("w_gate", "w_up"):
        if policy.ep_axis == "data":
            return spec(("data",), None, tp)     # (E, D, F): EP over "data",
        return spec(tp, fs, None)                # expert-TP over "model"
    if "experts" in path and name == "w_down":
        if policy.ep_axis == "data":
            return spec(("data",), tp, None)     # (E, F, D)
        return spec(tp, None, fs)
    if name in ("w_gate", "w_up", "wq", "wk", "wv", "w_xz"):
        return spec(fs, tp)                      # (D, out): column-parallel
    if name in ("w_down", "wo", "w_out"):
        return spec(tp, fs)                      # (in, D): row-parallel
    if name == "w_bcdt":
        return spec(fs, None)                    # small projections
    if name == "router":
        return spec(None, None)
    if name == "conv_w":
        return spec(None, tp)                    # (d_conv, d_inner)
    if name in ("bq", "bk", "bv"):
        return spec(tp)
    if name == "gate_norm":
        return spec(tp)                          # (d_inner,)
    return spec(*([None] * len(body)))           # norms, scalars: replicate


def stage_param_specs(stage: str, tree, mesh, cfg: ModelConfig,
                      policy: ShardingPolicy | None = None):
    """Spec tree for one *pipeline stage's* param pytree over its sub-mesh.

    The spatial executor (`runtime/pipeline/jax_pipe.py`) keeps per-stage
    param trees whose leaves reuse the block naming this module's rules key
    off (wq/wo/w_up/...), plus two stage-local outliers: the embed stage's
    table is "emb" (the (V, D) embedding rule) and the head stage's
    projection is "w_out", which would otherwise hit the mamba row-parallel
    rule — as the (D, V) unembedding it takes the "head" rule instead.
    FSDP defaults off: a stage sub-mesh's "data" axis has size 1 (the
    replica dimension is spatial, not a mesh axis), so there is nothing to
    ZeRO-shard within a slice.
    """
    policy = policy or ShardingPolicy(fsdp=False, tp=True)

    def leaf_spec(path, leaf):
        p = _path_str(path)
        name = p.rsplit("/", 1)[-1]
        if stage == "embed" and name == "emb":
            p = "embed"
        elif stage == "head" and name == "w_out":
            p = "head"
        return param_spec(p, leaf.shape, mesh, cfg, policy)

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def stage_param_shardings(stage: str, tree, mesh, cfg: ModelConfig,
                          policy: ShardingPolicy | None = None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        stage_param_specs(stage, tree, mesh, cfg, policy))


def tree_pspecs(tree, mesh, cfg: ModelConfig, policy: ShardingPolicy):
    """Spec tree for a params-like pytree (from jax.eval_shape)."""
    def leaf_spec(path, leaf):
        return param_spec(_path_str(path), leaf.shape, mesh, cfg, policy)
    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def tree_shardings(tree, mesh, cfg: ModelConfig, policy: ShardingPolicy):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(tree, mesh, cfg, policy))


# -- activations / batches ---------------------------------------------------
def batch_specs(mesh, batch_tree, *, accum: bool = False):
    """Token batches: batch dim over the data axes.  With gradient
    accumulation the leading dim is the accumulation index (unsharded) and
    the batch dim is second."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        batch_axis = 1 if accum else 0
        axes = [None] * len(leaf.shape)
        if leaf.shape[batch_axis] % _prod(mesh, dp) == 0:
            axes[batch_axis] = dp
        else:
            import warnings
            warnings.warn(
                f"batch dim {leaf.shape[batch_axis]} does not divide the "
                f"data axes (x{_prod(mesh, dp)}): batch will be REPLICATED "
                f"— lower grad_accum so microbatch >= dp (measured 46x "
                f"collective blow-up on qwen tp1; EXPERIMENTS.md §Perf)",
                stacklevel=2)
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_specs(mesh, cache_tree, cfg: ModelConfig, policy: ShardingPolicy):
    """Decode caches.  Stacked leading period dim; batch dim next.  If the
    batch is unshardable (long-context batch=1), shard the cache sequence
    dim over the data axes instead (sequence parallelism for the cache)."""
    dp = data_axes(mesh)
    ndp = _prod(mesh, dp)

    def spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        name = p.rsplit("/", 1)[-1]
        is_kv = name in ("k", "v", "cross_k", "cross_v")
        if p.endswith("pos"):
            return P()
        axes: list = [None] * len(shape)
        # layout: (periods, B, ...) for caches
        if len(shape) >= 2 and shape[1] % ndp == 0:
            axes[1] = dp
        elif policy.seq_shard_cache and is_kv:
            # (periods, B, C, KV, hd): batch unshardable (long-context
            # B=1) — shard capacity over the data axes instead
            if len(shape) >= 3 and shape[2] % ndp == 0:
                axes[2] = dp
        # model axis: prefer kv heads; else shard the capacity dim
        # (flash-decoding-style sequence-parallel cache — without this the
        # 33B+ decode cells exceed 16 GB/chip; EXPERIMENTS.md §Perf)
        mdl = mesh.shape["model"]
        if len(shape) == 5 and shape[3] % mdl == 0:
            axes[3] = "model"
        elif is_kv and len(shape) >= 3 and axes[2] is None \
                and shape[2] % mdl == 0:
            axes[2] = "model"
        elif name == "conv" and len(shape) == 4 and shape[3] % mdl == 0:
            axes[3] = "model"          # mamba conv history: d_inner over tp
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def _prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
