"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (before any other import, including
repro.*): jax locks the device count on first init."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from pathlib import Path  # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.jaxpr_cost import count_step  # noqa: E402
from repro.analysis.roofline import HW_V5E, analyze_compiled  # noqa: E402
from repro.configs import SHAPES, get_config, all_cells  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_device_count  # noqa: E402
from repro.launch.steps import input_specs  # noqa: E402

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _shardings_for(bundle, mesh, cfg, policy):
    """NamedSharding tree matching the bundle's argument specs."""
    args = bundle.arg_specs
    if bundle.kind == "train":
        params, opt_state, step, batch = args
        return (shd.tree_shardings(params, mesh, cfg, policy),
                shd.tree_shardings(opt_state, mesh, cfg, policy),
                NamedSharding(mesh, P()),
                shd.named(mesh, shd.batch_specs(mesh, batch, accum=True)))
    if bundle.kind == "prefill":
        params, batch = args
        return (shd.tree_shardings(params, mesh, cfg, policy),
                shd.named(mesh, shd.batch_specs(mesh, batch)))
    params, cache, tokens = args
    return (shd.tree_shardings(params, mesh, cfg, policy),
            shd.named(mesh, shd.cache_specs(mesh, cache, cfg, policy)),
            shd.named(mesh, shd.batch_specs(mesh, tokens)))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True, policy: shd.ShardingPolicy | None = None,
             verbose: bool = True, tp: int | None = None, sp: bool = False,
             accum: int | None = None, fsdp: bool | None = None,
             param_dtype: str | None = None, ep_axis: str = "model",
             moe_impl: str = "einsum", rep: int | None = None,
             variant: str = "") -> dict:
    """Lower+compile one cell.

    Variant knobs (the §Perf hillclimb levers; defaults = baseline policy):
      tp      — model-axis width; mesh reshapes to (256//tp, tp)
      sp      — Megatron-style sequence parallelism on the residual stream
      accum   — gradient-accumulation override (microbatch size lever)
      fsdp    — force FSDP on/off
      variant — artifact-name suffix so baselines are never overwritten
    """
    import dataclasses

    from repro.models import blocks as _blocks
    _blocks.set_moe_impl(moe_impl)
    cfg = get_config(arch)
    if accum is not None:
        cfg = dataclasses.replace(cfg, grad_accum=accum)
    if param_dtype is not None:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, tp=tp, rep=rep)
    if rep:
        mesh_name = "x".join(str(x) for x in mesh.devices.shape)
    else:
        mesh_name = ("2x16x16" if multi_pod else "16x16") if tp in (None, 16)             else ("2x%dx%d" % (256 // tp, tp) if multi_pod
              else "%dx%d" % (256 // tp, tp))
    n_dev = mesh_device_count(mesh)
    if policy is None:
        policy = shd.ShardingPolicy(
            fsdp=(shape.kind == "train") if fsdp is None else fsdp,
            seq_shard_cache=(shape.name == "long_500k"),
            ep_axis=ep_axis)

    t0 = time.time()
    grad_sh = None
    if shape.kind == "train":
        from repro.launch.steps import abstract_params
        from repro.models import build_model
        params_struct = abstract_params(build_model(cfg))
        grad_sh = shd.tree_shardings(params_struct, mesh, cfg, policy)
    bundle = input_specs(cfg, shape, grad_shardings=grad_sh)
    in_sh = _shardings_for(bundle, mesh, cfg, policy)
    # outputs mirror the param/opt/cache input shardings (metrics replicated)
    if bundle.kind == "train":
        out_sh = (in_sh[0], in_sh[1],
                  {"loss": NamedSharding(mesh, P()), "step": NamedSharding(mesh, P())})
    elif bundle.kind == "decode":
        out_sh = (NamedSharding(mesh, P()), in_sh[1])
    else:
        out_sh = None  # prefill: let GSPMD place logits + fresh cache
    from repro import sharding_ctx as sctx
    with mesh, sctx.activate(sctx.from_mesh(mesh, sp=sp,
                                            ep_data=policy.ep_axis == "data")):
        jitted = jax.jit(bundle.fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*bundle.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    cost = count_step(bundle.fn, *bundle.arg_specs)

    mem = compiled.memory_analysis()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    # MODEL_FLOPS: 6*N*D train, 2*N*D inference (fwd only)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    hlo_text = compiled.as_text()
    rep = analyze_compiled(
        compiled, arch=arch, shape_name=shape_name, mesh_name=mesh_name,
        n_devices=n_dev, model_flops=model_flops, tokens=tokens,
        step_flops=cost.flops, step_bytes=cost.major_bytes,
        hlo_text=hlo_text)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant or "baseline",
        "knobs": {"tp": tp or 16, "sp": sp, "accum": cfg.grad_accum,
                  "fsdp": policy.fsdp, "ep_axis": policy.ep_axis,
                  "moe_impl": moe_impl},
        "kind": bundle.kind, "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": json.loads(rep.to_json()),
        "policy": {"fsdp": policy.fsdp, "tp": policy.tp,
                   "seq_shard_cache": policy.seq_shard_cache},
    }
    if verbose:
        arg_gb = (result["memory"]["argument_size"] or 0) / 1e9
        tmp_gb = (result["memory"]["temp_size"] or 0) / 1e9
        print(f"[OK] {arch} x {shape_name} x {mesh_name}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {arg_gb:.1f}GB temp {tmp_gb:.1f}GB (whole slice) | "
              f"flops {rep.hlo_flops:.3g} wire {rep.wire_bytes:.3g}B | "
              f"bottleneck={rep.bottleneck} "
              f"terms(c/m/n)={rep.compute_s:.3f}/{rep.memory_s:.3f}/"
              f"{rep.collective_s:.3f}s")
        print(compiled.memory_analysis())
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"__{variant}" if variant else ""
        out = ART_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--tp", type=int, default=None)
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--fsdp", default=None,
                    choices=[None, "on", "off"])
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--ep-axis", default="model", choices=["model", "data"])
    ap.add_argument("--moe-impl", default="einsum",
                    choices=["einsum", "sorted"])
    ap.add_argument("--rep", type=int, default=None)
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    cells = [(a, s, ok, why) for (a, s, ok, why) in all_cells()
             if (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape_name, ok, why in cells:
        if not ok:
            print(f"[SKIP] {arch} x {shape_name}: {why}")
            continue
        for mp in meshes:
            try:
                run_cell(arch, shape_name, multi_pod=mp, save=not args.no_save,
                         tp=args.tp, sp=args.sp, accum=args.accum,
                         fsdp=None if args.fsdp is None else args.fsdp == "on",
                         param_dtype=args.param_dtype, ep_axis=args.ep_axis,
                         moe_impl=args.moe_impl, rep=args.rep,
                         variant=args.variant)
            except Exception as e:  # a failing cell is a bug in the system
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"[FAIL] {arch} x {shape_name} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("all dry-run cells compiled")


if __name__ == "__main__":
    main()
