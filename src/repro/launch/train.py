"""Training launcher (CLI).

Runs real steps on the local devices (CPU here; the same code path drives
TPU slices — the mesh comes from ``jax.devices()``).  Fault tolerance,
checkpointing, straggler monitoring and deterministic data come from
``repro.runtime``; the parallelism policy can be chosen by the paper's
planner (``--use-planner``).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 200 --seq-len 128 --global-batch 8 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduced \
        --steps 50 --fail-at 20:crash --max-restarts 2
"""
from __future__ import annotations

import argparse
import json

from ..configs import get_config
from ..runtime import (FailureInjector, StragglerMonitor, TrainLoopConfig,
                       run_resilient, train_loop)


def parse_failures(specs: list[str]) -> FailureInjector | None:
    if not specs:
        return None
    sched = {}
    for s in specs:
        step, kind = s.split(":", 1)
        sched[int(step)] = kind
    return FailureInjector(sched)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config (smoke scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="bigram", choices=["bigram", "uniform"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-interval", type=int, default=10)
    ap.add_argument("--metrics", default=None, help="metrics JSONL path")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--use-planner", action="store_true",
                    help="let the space/time planner pick tp/dp for the "
                         "local device count")
    ap.add_argument("--fail-at", action="append", default=[],
                    metavar="STEP:KIND", help="inject failure, e.g. 20:crash "
                    "or 30:stall:2.0")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    tp = args.tp
    if args.use_planner:
        import jax

        from ..configs.base import ShapeCfg
        from ..core import planner
        shape = ShapeCfg("cli", args.seq_len, args.global_batch, "train")
        n = len(jax.devices())
        p = planner.plan(cfg, shape, chips=max(n, 2),
                         mb_seqs=max(1, args.global_batch // args.grad_accum))
        ex = planner.to_execution(p, cfg=cfg, chips=n)
        tp = ex.tp
        print(f"[planner] {p.summary()}")
        print(f"[planner] projected mesh {ex.mesh_shape}; tp={tp} "
              f"({ex.notes or 'homogeneous'})")

    loop = TrainLoopConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.global_batch,
        grad_accum=args.grad_accum, lr=args.lr, warmup=args.warmup,
        seed=args.seed, data_kind=args.data, ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval, log_interval=args.log_interval,
        metrics_path=args.metrics, tp=tp, fsdp=args.fsdp,
        failures=parse_failures(args.fail_at),
        straggler=StragglerMonitor(),
        on_metrics=lambda rec: print(f"step {rec['step']:6d}  "
                                     f"loss {rec['loss']:.4f}  "
                                     f"{rec['sec']*1e3:8.1f} ms"))
    if args.ckpt_dir:
        out = run_resilient(cfg, loop, max_restarts=args.max_restarts)
        print(json.dumps({k: out[k] for k in
                          ("restarts", "incarnations", "total_steps_run",
                           "final_step", "final_loss")}, indent=1))
    else:
        s = train_loop(cfg, loop)
        print(f"done: {s.steps_run} steps, final loss {s.final_loss:.4f}, "
              f"stragglers {s.straggler_events}")


if __name__ == "__main__":
    main()
