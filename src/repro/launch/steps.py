"""Step builders: train (grad-accum + optimizer), prefill, decode.

These are the functions the launcher jits with explicit in/out shardings
and the dry-run lowers AOT for every (arch x shape x mesh) cell."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCfg
from ..models import build_model
from ..optim import cosine_schedule, get_optimizer


@dataclass
class StepBundle:
    """A step function plus the abstract input values to lower it with."""
    fn: Callable
    arg_specs: tuple          # pytree of jax.ShapeDtypeStruct
    kind: str


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    warmup: int = 2000, total_steps: int = 100_000,
                    grad_accum: int | None = None, impl: str | None = None,
                    grad_shardings=None):
    """(params, opt_state, step, batch) -> (params, opt_state, metrics).

    batch leaves are shaped (accum, micro_batch, ...); gradients are
    accumulated over the leading dim with a lax.scan (fp32 accumulators),
    then a single optimizer update is applied.

    grad_shardings: optional NamedSharding tree matching params — pins the
    fp32 accumulator's layout (GSPMD sharding propagation through while-
    loop carries is weak; without this the accumulator replicates)."""
    model = build_model(cfg, impl=impl)
    opt = get_optimizer(cfg.optimizer, cosine_schedule(lr, warmup, total_steps))
    accum = grad_accum or cfg.grad_accum

    def loss_of(params, mb):
        return model.loss_fn(params, mb)[0]

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, grad_shardings)

    def train_step(params, opt_state, step, batch):
        if accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(loss_of)(params, mb)
            grads = pin(grads)   # FSDP shards: sync becomes reduce-scatter
        else:
            def body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                g32 = jax.tree.map(lambda x: x.astype(jnp.float32), g)
                return (pin(_tree_add(gsum, g32)), lsum + l), None

            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(body, (g0, 0.0), batch)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        new_params, new_state = opt.update(grads, opt_state, params, step)
        metrics = {"loss": loss, "step": step + 1}
        return new_params, new_state, metrics

    return model, opt, train_step


def make_prefill_step(cfg: ModelConfig, *, capacity: int | None = None,
                      impl: str | None = None):
    from ..models.lm import prefill

    model = build_model(cfg, impl=impl)

    def step(params, batch):
        return prefill(cfg, params, batch, capacity=capacity, impl=impl)

    return model, step


def make_decode_step(cfg: ModelConfig, *, impl: str | None = None):
    model = build_model(cfg, impl=impl)

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return model, decode


# ===========================================================================
# Abstract input specs (ShapeDtypeStruct stand-ins; no allocation)
# ===========================================================================
def batch_struct(cfg: ModelConfig, shape: ShapeCfg, *, accum: int | None = None,
                 dtype=jnp.int32):
    """Abstract training/prefill batch for a shape cell."""
    B, S = shape.global_batch, shape.seq_len
    n_text = S - (cfg.num_prefix if cfg.frontend == "vit_stub" else 0)
    lead = (accum, B // accum) if accum else (B,)
    sds = jax.ShapeDtypeStruct
    batch: dict[str, Any] = {
        "tokens": sds((*lead, n_text), jnp.int32),
    }
    if shape.kind == "train":
        batch["labels"] = sds((*lead, n_text), jnp.int32)
    if cfg.frontend == "vit_stub":
        batch["prefix_embeds"] = sds((*lead, cfg.num_prefix, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.encdec:
        batch["frames"] = sds((*lead, cfg.num_prefix, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(opt, params_struct):
    return jax.eval_shape(opt.init, params_struct)


def abstract_cache(model, cfg: ModelConfig, shape: ShapeCfg, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))


def input_specs(cfg: ModelConfig, shape: ShapeCfg, *, impl: str | None = None,
                grad_shardings=None):
    """The full abstract argument tuple for the cell's step function."""
    if shape.kind == "train":
        model, opt, fn = make_train_step(cfg, impl=impl,
                                         grad_shardings=grad_shardings)
        params = abstract_params(model)
        opt_state = abstract_opt_state(opt, params)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        batch = batch_struct(cfg, shape, accum=cfg.grad_accum)
        return StepBundle(fn, (params, opt_state, step, batch), "train")
    if shape.kind == "prefill":
        model, fn = make_prefill_step(cfg, capacity=shape.seq_len, impl=impl)
        params = abstract_params(model)
        batch = batch_struct(cfg, shape)
        return StepBundle(fn, (params, batch), "prefill")
    # decode
    model, fn = make_decode_step(cfg, impl=impl)
    params = abstract_params(model)
    cache = abstract_cache(model, cfg, shape)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return StepBundle(fn, (params, cache, tokens), "decode")
