"""Serving launcher (CLI): batched prefill+decode on local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 16 --max-new 24
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from ..configs import get_config
from ..runtime.server import LMServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab,
                                        rng.integers(4, args.prompt_len + 1))
                    .tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = LMServer(cfg, max_batch=args.max_batch, seed=args.seed,
                   temperature=args.temperature)
    outs = srv.serve(reqs)
    for c in outs[:4]:
        print(f"req {c.uid}: prompt {c.prompt_len} tok -> "
              f"{len(c.tokens)} new tok   {c.tokens[:10]}...")
    print(json.dumps(srv.stats.summary(), indent=1))


if __name__ == "__main__":
    main()
