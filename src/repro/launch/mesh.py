"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (required: smoke tests see 1 device; only
dryrun.py forces 512 host devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int | None = None,
                         rep: int | None = None):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: "data" carries batch (and FSDP param sharding), "model" carries
    tensor/expert parallelism, "pod" is the slow inter-pod (DCN) data axis.

    ``tp`` reshapes the pod's 256 chips to (256//tp, tp) — the planner's
    space/time knob (§Perf variants).  The canonical dry-run mesh is the
    default tp=16."""
    tp = 16 if tp is None else int(tp)
    assert 256 % tp == 0 and tp >= 1, f"bad tp={tp}"
    if rep:
        # three-axis pod: "data" keeps expert parallelism at width
        # 256//(tp*rep); "rep" is extra pure-DP; "model" is within-expert TP
        assert 256 % (tp * rep) == 0
        shape = (256 // (tp * rep), rep, tp)
        axes = ("data", "rep", "model")
        if multi_pod:
            shape = (2, *shape)
            axes = ("pod", *axes)
        return jax.make_mesh(shape, axes)
    shape = (2, 256 // tp, tp) if multi_pod else (256 // tp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes over which the batch is sharded (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis(mesh) -> str:
    return "model"


def mesh_device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def stage_device_slices(mesh_or_devices, stg, sel) -> dict:
    """Partition a mesh's device set into per-stage replica slices.

    The spatial alternative to the folded (data, model) layout: each stage
    of the plan gets tp-sized device tuples, one per replica, in topological
    order (runtime.pipeline pins stage params to these).  Accepts a jax
    Mesh or any device sequence.  ``stage_submeshes`` lifts the same
    partition to per-replica jax sub-meshes for tp-sharded stage params.
    """
    from ..runtime.pipeline.placement import place
    devs = _pool(mesh_or_devices)
    pl = place(stg, sel, devs)
    out: dict = {}
    for sl in pl.slices.values():
        out.setdefault(sl.stage, []).append((sl.replica, sl.devices))
    return {k: [d for _, d in sorted(v)] for k, v in out.items()}


def stage_submeshes(mesh_or_devices, stg, sel) -> dict:
    """Per-stage, per-replica ("data", "model") sub-meshes of shape (1, tp).

    The heterogeneous-mesh half of the spatial layout: each tp>1 replica
    slice becomes its own 1 x tp mesh so the stage's params shard over the
    slice (`launch/sharding.stage_param_specs`) instead of living on the
    slice's first device.  Entries are ``None`` where a sub-mesh cannot be
    built honestly: tp == 1 (nothing to shard) or a slice folded onto
    repeated devices by oversubscription (a mesh with duplicate devices is
    invalid — the executor falls back to single-device placement there).
    """
    from ..runtime.pipeline.placement import place
    devs = _pool(mesh_or_devices)
    pl = place(stg, sel, devs)
    out: dict = {}
    for sl in pl.slices.values():
        out.setdefault(sl.stage, []).append(
            (sl.replica, submesh_of(sl.resolve(devs))))
    return {k: [m for _, m in sorted(v, key=lambda t: t[0])]
            for k, v in out.items()}


def submesh_of(devices):
    """A (1, tp) ("data", "model") Mesh over one replica's device tuple, or
    None when no honest sub-mesh exists: tp == 1 (nothing to shard),
    repeated devices (a slice folded by oversubscription), or abstract
    integer handles (the interpreter's device model)."""
    import numpy as np
    if len(devices) < 2 or len(set(devices)) != len(devices):
        return None
    if not all(hasattr(d, "platform") for d in devices):
        return None
    return jax.sharding.Mesh(
        np.asarray(devices, dtype=object).reshape(1, len(devices)),
        ("data", "model"))


def _pool(mesh_or_devices) -> list:
    return (list(mesh_or_devices.devices.flat)
            if hasattr(mesh_or_devices, "devices") else list(mesh_or_devices))
