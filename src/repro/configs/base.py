"""Model/shape configuration system.

Every assigned architecture is a ``ModelConfig`` (src/repro/configs/<id>.py)
selectable via ``--arch``; shapes are the assigned (seq_len, global_batch)
grid.  ``reduced()`` returns a tiny same-family config for CPU smoke tests;
full configs are only ever lowered AOT (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace


VOCAB_PAD = 256  # Megatron-style padding so vocab shards over 16-way TP


def pad_vocab(v: int, multiple: int = VOCAB_PAD) -> int:
    return -(-v // multiple) * multiple


@dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int | None = None       # sliding-window attention (SWA) width
    qkv_bias: bool = False
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int
    shared_expert: bool = False
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    # block pattern: tuple of (mixer, mlp) pairs cycled over layers.
    #   mixer in {"attn", "mamba"}; mlp in {"dense", "moe"}
    block_pattern: tuple[tuple[str, str], ...] = (("attn", "dense"),)
    attn: AttnCfg | None = None
    mamba: MambaCfg | None = None
    moe: MoECfg | None = None
    act: str = "silu_glu"            # silu_glu | sq_relu | gelu
    norm_eps: float = 1e-5
    # encoder-decoder (audio family)
    encdec: bool = False
    enc_layers: int = 0
    # multimodal frontend stubs: prefix embeddings supplied as inputs
    frontend: str | None = None      # None | "vit_stub" | "audio_stub"
    num_prefix: int = 0              # patch/frame prefix length
    # numerics & training defaults
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"         # adamw | adafactor (for the 400B-class)
    grad_accum: int = 8
    remat: str = "full"              # full | dots | none
    tie_embeddings: bool = False
    # paper citation tag
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, \
            f"{self.name}: pattern of {len(self.block_pattern)} must divide {self.n_layers}"
        for mixer, mlp in self.block_pattern:
            assert mixer in ("attn", "mamba") and mlp in ("dense", "moe")
            if mixer == "attn":
                assert self.attn is not None
            if mixer == "mamba":
                assert self.mamba is not None
            if mlp == "moe":
                assert self.moe is not None

    # -- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is admissible (SSM / hybrid / SWA)."""
        if all(mixer == "mamba" for mixer, _ in self.block_pattern):
            return True
        if any(mixer == "mamba" for mixer, _ in self.block_pattern):
            return True  # hybrid
        return self.attn is not None and self.attn.window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f = self.d_model, self.d_ff
        total = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        for mixer, mlp in self.block_pattern:
            n = self.n_periods
            if mixer == "attn":
                a = self.attn
                qkv = d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
                o = a.n_heads * a.head_dim * d
                total += n * (qkv + o)
                if a.qkv_bias:
                    total += n * (a.n_heads + 2 * a.n_kv_heads) * a.head_dim
            else:
                m = self.mamba
                di = m.d_inner(d)
                h = m.n_ssm_heads(d)
                total += n * (d * 2 * di                       # xz in-proj
                              + d * (2 * m.n_groups * m.d_state + h)  # B, C, dt
                              + m.d_conv * di + di * d + 2 * h)       # conv, out, A/D
            if mlp == "dense":
                mult = 3 if self.act == "silu_glu" else 2
                total += n * mult * d * f
            else:
                e = self.moe
                mult = 3 if self.act == "silu_glu" else 2
                total += n * (e.n_experts * mult * d * e.d_ff + d * e.n_experts)
                if e.shared_expert:
                    total += n * mult * d * e.d_ff
            total += n * 2 * d  # norms
        if self.encdec:
            # decoder cross-attention + its norms (encoder counted above via
            # n_layers = enc; decoder layers counted separately by caller)
            pass
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE rooflines: 6*N_active*D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        mult = 3 if self.act == "silu_glu" else 2
        inactive = 0
        for mixer, mlp in self.block_pattern:
            if mlp == "moe":
                inactive += self.n_periods * (e.n_experts - e.top_k) * mult * d * e.d_ff
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_attn = None
        if self.attn is not None:
            small_attn = replace(self.attn, n_heads=4,
                                 n_kv_heads=max(1, min(self.attn.n_kv_heads, 2)),
                                 head_dim=16,
                                 window=64 if self.attn.window else None)
        small_mamba = None
        if self.mamba is not None:
            small_mamba = replace(self.mamba, d_state=16, head_dim=8)
        small_moe = None
        if self.moe is not None:
            small_moe = replace(self.moe, n_experts=4,
                                top_k=min(self.moe.top_k, 2), d_ff=64)
        return replace(
            self, name=self.name + "-smoke",
            n_layers=2 * len(self.block_pattern), d_model=64, d_ff=128,
            vocab=512, attn=small_attn, mamba=small_mamba, moe=small_moe,
            enc_layers=2 if self.encdec else 0,
            num_prefix=8 if self.frontend else 0,
            grad_accum=1, remat="none")


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """The assigned-cell applicability rule (skips noted in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; long-context decode skipped"
    return True, ""
