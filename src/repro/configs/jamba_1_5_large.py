"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave + MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887].  Period of 8 layers: 1 attention + 7 mamba; MoE on
alternate layers (4 MoE per period -> 36 MoE layers) which reproduces the
~398B total / ~94B active split.  Optimiser is Adafactor (400B-class AdamW
state does not fit a single 256-chip pod; see EXPERIMENTS.md §Dry-run)."""
from .base import AttnCfg, MambaCfg, ModelConfig, MoECfg

_P = (
    ("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab=65_536,
    block_pattern=_P,
    attn=AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128),
    mamba=MambaCfg(d_state=128, head_dim=64, expand=2, d_conv=4, n_groups=1),
    moe=MoECfg(n_experts=16, top_k=2, d_ff=24576),
    act="silu_glu",
    optimizer="adafactor",
    grad_accum=16,
    source="arXiv:2403.19887",
)
