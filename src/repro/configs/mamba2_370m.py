"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
Mamba2 blocks have no separate MLP (d_ff=0): the block IS the mixer, so the
pattern uses a mamba mixer with no MLP sublayer (we encode that as a dense
MLP of width 0 being skipped — see models/lm.py)."""
from .base import MambaCfg, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    d_ff=0,                      # attn-free SSD blocks carry no MLP
    vocab=50_280,                # GPT-NeoX tokenizer; padded to 50432
    block_pattern=(("mamba", "dense"),),
    mamba=MambaCfg(d_state=128, head_dim=64, expand=2, d_conv=4, n_groups=1),
    act="silu_glu",
    optimizer="adamw",
    grad_accum=4,
    tie_embeddings=True,         # as in the released 370m checkpoint
    source="arXiv:2405.21060",
)
