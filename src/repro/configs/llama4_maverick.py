"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4].  MoE on alternate layers (Maverick's interleaved
dense/MoE), shared expert always-on -> ~400B total / ~17B active.  The
vision "early fusion" frontend is a stub (patch embeddings as inputs) per
the assignment; text-only cells use no prefix."""
from .base import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab=202_048,
    block_pattern=(("attn", "dense"), ("attn", "moe")),
    attn=AttnCfg(n_heads=40, n_kv_heads=8, head_dim=128),
    moe=MoECfg(n_experts=128, top_k=1, d_ff=8192, shared_expert=True),
    act="silu_glu",
    optimizer="adafactor",
    grad_accum=16,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
