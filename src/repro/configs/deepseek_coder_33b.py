"""deepseek-coder-33b [dense] — llama-arch GQA.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 [arXiv:2401.14196]."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    d_ff=19200,
    vocab=32_256,
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=56, n_kv_heads=8, head_dim=128),
    act="silu_glu",
    optimizer="adamw",
    source="arXiv:2401.14196",
)
