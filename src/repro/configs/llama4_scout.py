"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 on
every layer -> ~109B total / ~17B active [hf:meta-llama/Llama-4-Scout]."""
from .base import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab=202_048,
    block_pattern=(("attn", "moe"),),
    attn=AttnCfg(n_heads=40, n_kv_heads=8, head_dim=128),
    moe=MoECfg(n_experts=16, top_k=1, d_ff=8192, shared_expert=True),
    act="silu_glu",
    optimizer="adamw",
    grad_accum=8,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
