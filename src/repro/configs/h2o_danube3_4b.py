"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 [arXiv:2401.16818].
SWA window 4096 makes long-context decode sub-quadratic (ring-buffer KV)."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab=32_000,
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=32, n_kv_heads=8, head_dim=120, window=4096),
    act="silu_glu",
    optimizer="adamw",
    source="arXiv:2401.16818",
)
