"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596].
12 encoder + 12 decoder layers; the speech frontend is a STUB supplying
1024 precomputed frame embeddings.  Decoder has a decode step (enc-dec, not
encoder-only), so decode shapes run; full attention => long_500k skipped."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers; enc_layers mirrors it
    d_model=1024,
    d_ff=4096,
    vocab=256_206,               # padded to 256256
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=16, n_kv_heads=16, head_dim=64),
    act="gelu",
    encdec=True,
    enc_layers=12,
    frontend="audio_stub",
    num_prefix=1024,             # encoder frame-embedding length
    optimizer="adamw",
    grad_accum=4,
    source="arXiv:2308.11596",
)
