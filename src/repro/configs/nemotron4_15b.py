"""nemotron-4-15b [dense] — GQA + squared-ReLU MLP (non-gated).

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819]."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    d_ff=24576,
    vocab=256_000,
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=48, n_kv_heads=8, head_dim=128),
    act="sq_relu",
    optimizer="adamw",
    source="arXiv:2402.16819",
)
