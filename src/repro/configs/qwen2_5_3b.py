"""qwen2.5-3b [dense] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936 [hf:Qwen/Qwen2.5]."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    d_ff=11008,
    vocab=151_936,
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=16, n_kv_heads=2, head_dim=128, qkv_bias=True),
    act="silu_glu",
    optimizer="adamw",
    grad_accum=4,
    source="hf:Qwen/Qwen2.5-0.5B",
)
