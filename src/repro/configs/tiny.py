"""tiny — a ~10-20M-param dense config for runnable CPU examples/tests."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="tiny",
    family="dense",
    n_layers=4,
    d_model=256,
    d_ff=1024,
    vocab=4096,
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=8, n_kv_heads=4, head_dim=32),
    act="silu_glu",
    optimizer="adamw",
    grad_accum=1,
    remat="none",
    source="(local)",
)
