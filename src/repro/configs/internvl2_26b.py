"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821].
The ViT is a frontend stub per the assignment: ``input_specs()`` supplies
256 precomputed patch embeddings prepended to the token sequence."""
from .base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    d_ff=16384,
    vocab=92_553,                # padded to 92672
    block_pattern=(("attn", "dense"),),
    attn=AttnCfg(n_heads=48, n_kv_heads=8, head_dim=128),
    act="silu_glu",
    frontend="vit_stub",
    num_prefix=256,
    optimizer="adamw",
    source="arXiv:2404.16821",
)
