"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeCfg, cell_is_runnable  # noqa: F401

from . import (deepseek_coder_33b, h2o_danube3_4b, internvl2_26b,  # noqa: E402
               jamba_1_5_large, llama4_maverick, llama4_scout, mamba2_370m,
               nemotron4_15b, qwen2_5_3b, seamless_m4t_medium, tiny)

_REGISTRY: dict[str, ModelConfig] = {}
for _m in (mamba2_370m, h2o_danube3_4b, deepseek_coder_33b, nemotron4_15b,
           qwen2_5_3b, jamba_1_5_large, llama4_maverick, llama4_scout,
           internvl2_26b, seamless_m4t_medium, tiny):
    _REGISTRY[_m.CONFIG.name] = _m.CONFIG

ARCHS = tuple(n for n in _REGISTRY if not n.startswith("tiny"))


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return _REGISTRY[name[:-6]].reduced()
    return _REGISTRY[name]


def all_cells():
    """All (arch, shape) dry-run cells with runnability flags."""
    out = []
    for a in ARCHS:
        cfg = _REGISTRY[a]
        for s in SHAPES.values():
            ok, why = cell_is_runnable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
