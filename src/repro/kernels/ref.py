"""Pure-jnp oracles for the Pallas kernels.

Three tiers per op:
  * ``*_reference`` — the simplest correct definition (the gold oracle used
    by kernel tests; materialises O(S^2) for attention, sequential scan for
    SSD).
  * ``*_chunked``  — memory-safe jnp implementation with the same blocking
    structure as the TPU kernel (online softmax / chunked state passing).
    This is what the models use on backends without Pallas (e.g. the CPU
    dry-run); its HLO exhibits the fused kernels' memory behaviour.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def mha_reference(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, kv_offset: int = 0):
    """Multi-head attention oracle.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H a multiple of KV (GQA).
    ``kv_offset``: absolute position of q[0] minus k[0] (decode: Sk-Sq).
    ``window``: sliding-window width (attend to the last `window` keys).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + kv_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, window: int | None = None,
                scale: float | None = None, kv_offset: int = 0,
                block_q: int = 512, block_k: int = 1024):
    """Flash-style online-softmax attention with q- and kv-blocking:
    an outer lax.map over q blocks and an inner lax.scan over KV blocks —
    O(block_q * block_k) live logits instead of O(Sq * Sk)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5

    block_k = min(block_k, sk)
    nkb = -(-sk // block_k)
    pad_k = nkb * block_k - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kb = k.reshape(b, nkb, block_k, kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nkb, block_k, kv, d).transpose(1, 0, 2, 3, 4)
    kstarts = jnp.arange(nkb) * block_k

    block_q = min(block_q, sq)
    nqb = -(-sq // block_q)
    pad_q = nqb * block_q - sq
    qf = q.astype(jnp.float32) * scale
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qb = qf.reshape(b, nqb, block_q, h, d).transpose(1, 0, 2, 3, 4)
    qstarts = jnp.arange(nqb) * block_q

    @jax.checkpoint  # flash-style: recompute block logits/masks in the bwd
    def q_block(args):
        qblk, q_start = args                     # (b, bq, h, d), ()
        qpos = q_start + jnp.arange(block_q) + kv_offset

        def step(carry, blk):
            acc, m, l = carry
            kblk, vblk, k_start = blk
            kblk = jnp.repeat(kblk.astype(jnp.float32), rep, axis=2)
            vblk = jnp.repeat(vblk.astype(jnp.float32), rep, axis=2)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk)
            kpos = k_start + jnp.arange(block_k)
            mask = kpos[None, :] < sk
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, block_q, d), jnp.float32)
        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kb, vb, kstarts))
        return acc / jnp.maximum(l[..., None], 1e-30)

    outs = jax.lax.map(q_block, (qb, qstarts))    # (nqb, b, h, bq, d)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nqb * block_q, h, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len, *,
                         window: int | None = None, scale: float | None = None):
    """Single-token decode attention over a (possibly ring-buffered) cache.

    q: (B, H, D); caches: (B, C, KV, D); cache_len: () int32 — number of
    valid entries.  For ring buffers, callers pass position-consistent
    masks via cache_len == capacity once wrapped.
    """
    b, h, d = q.shape
    _, c, kv, _ = k_cache.shape
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5
    kf = jnp.repeat(k_cache.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v_cache.astype(jnp.float32), rep, axis=2)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32) * scale, kf)
    idx = jnp.arange(c)
    mask = idx[None, :] < cache_len
    if window is not None:
        mask &= idx[None, :] >= cache_len - window
    logits = jnp.where(mask[:, None] if mask.ndim == 2 else mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vf)
    return out.astype(q.dtype)


def decode_attention_chunked(q, k_cache, v_cache, cache_len, *,
                             window: int | None = None,
                             scale: float | None = None, block_k: int = 128):
    """Decode attention with the TPU kernel's blocking, in plain jnp.

    Same shapes/semantics as `decode_attention_ref`, but GQA-aware with
    no head repeat — q reshapes to (B, KV, rep, hd) and the cache streams
    through an online softmax in ``block_k`` chunks, touching each cache
    element exactly once instead of rep-folding both caches per token.
    This is the models' hot decode path on backends without Pallas (the
    ``"fused"`` impl); allclose (not bitwise) to the oracle.  Accepts a
    scalar or per-batch ``cache_len`` ((B,) or the oracle's (B, 1)).
    """
    b, h, d = q.shape
    _, c, kv, _ = k_cache.shape
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5
    clen = jnp.asarray(cache_len)
    if clen.ndim:
        clen = clen.reshape(b)
    qr = q.astype(jnp.float32).reshape(b, kv, rep, d) * scale

    block_k = min(block_k, c)
    nk = -(-c // block_k)
    pad = nk * block_k - c
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if pad:                           # padded slots land past cache_len
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kf.reshape(b, nk, block_k, kv, d).transpose(1, 0, 3, 2, 4)
    vb = vf.reshape(b, nk, block_k, kv, d).transpose(1, 0, 3, 2, 4)
    starts = jnp.arange(nk, dtype=jnp.int32) * block_k

    def step(carry, blk):
        acc, m, l = carry
        kblk, vblk, k0 = blk                       # (B, KV, bk, d) x2, ()
        s = jnp.einsum("bgrd,bgkd->bgrk", qr, kblk)
        idx = k0 + jnp.arange(block_k)
        if clen.ndim:                              # per-batch lengths
            mask = idx[None, :] < clen[:, None]
            if window is not None:
                mask &= idx[None, :] >= clen[:, None] - window
            mask = mask[:, None, None, :]
        else:
            mask = idx < clen
            if window is not None:
                mask &= idx >= clen - window
            mask = mask[None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgrk,bgkd->bgrd", p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, rep, d), jnp.float32)
    m0 = jnp.full((b, kv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, rep), jnp.float32)
    if nk == 1:        # decode caches usually fit one block — skip the scan
        (acc, m, l), _ = step((acc0, m0, l0), (kb[0], vb[0], starts[0]))
    else:
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (kb, vb, starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) — arXiv:2405.21060
# --------------------------------------------------------------------------
def ssd_reference(x, dt, a, b, c, *, d_skip=None, init_state=None):
    """Sequential (token-by-token) SSD recurrence — the gold oracle.

    x:  (B, L, H, P)   inputs (post-conv, post-activation)
    dt: (B, L, H)      softplus-ed timestep
    a:  (H,)           negative decay rate (A = -exp(a_log))
    b:  (B, L, N)      input projection (n_groups=1, broadcast over heads)
    c:  (B, L, N)      output projection
    d_skip: (H,) optional skip connection weight
    Returns y: (B, L, H, P), final_state: (B, H, P, N)
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    s0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), jnp.float32)

    def step(s, t):
        xt, dtt, bt, ct = t
        decay = jnp.exp(dtt * a)[:, :, None, None]          # (B,H,1,1)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)     # discretised input
        s = s * decay + dbx
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3), dt.astype(jnp.float32).transpose(1, 0, 2),
          b.astype(jnp.float32).transpose(1, 0, 2), c.astype(jnp.float32).transpose(1, 0, 2))
    s, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)
    if d_skip is not None:
        y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), s


def ssd_chunked(x, dt, a, b, c, *, chunk: int = 128, d_skip=None, init_state=None):
    """Chunked SSD (the TPU kernel's algorithm, in jnp).

    Within a chunk: quadratic "attention-like" form with decay mask;
    across chunks: state carried by a lax.scan.  O(L*chunk) memory.
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    bf = b.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    cf = c.astype(jnp.float32).reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    s0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), jnp.float32)

    def chunk_step(s, t):
        xc, dtc, bc, cc = t                      # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        la = dtc * a                             # log-decay per step (B,Q,H)
        cs = jnp.cumsum(la, axis=1)              # inclusive cumsum (B,Q,H)
        # intra-chunk: y_i += sum_{j<=i} C_i.B_j * exp(cs_i - cs_j) * dt_j * x_j
        seg = cs[:, :, None, :] - cs[:, None, :, :]            # (B,Qi,Qj,H)
        i = jnp.arange(xc.shape[1])
        causal = (i[:, None] >= i[None, :])[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cc, bc)                # (B,Qi,Qj)
        w = cb[..., None] * decay * dtc[:, None, :, :]         # (B,Qi,Qj,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc)
        # inter-chunk: y_i += C_i . (exp(cs_i) * S_prev)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cc, s, jnp.exp(cs))
        # state update: S = exp(sum la) * S + sum_j exp(cs_last - cs_j) dt_j B_j x_j
        tot = cs[:, -1, :]                                     # (B,H)
        rem = jnp.exp(tot[:, None, :] - cs)                    # (B,Q,H)
        dbx = jnp.einsum("bjh,bjn,bjhp->bhpn", rem * dtc, bc, xc)
        s_new = s * jnp.exp(tot)[:, :, None, None] + dbx
        return s_new, y_intra + y_inter

    s, ys = jax.lax.scan(chunk_step, s0, (xf, dtf, bf, cf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, P)[:, :L]
    if d_skip is not None:
        y = y + x.astype(jnp.float32)[:, :L] * d_skip[None, None, :, None]
    return y.astype(x.dtype), s


def ssd_decode_step(s, xt, dtt, a, bt, ct, *, d_skip=None):
    """One-token SSD state update (serving): s (B,H,P,N) -> (y, s')."""
    decay = jnp.exp(dtt.astype(jnp.float32) * a)[:, :, None, None]
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
                     bt.astype(jnp.float32), xt.astype(jnp.float32))
    s = s * decay + dbx
    y = jnp.einsum("bhpn,bn->bhp", s, ct.astype(jnp.float32))
    if d_skip is not None:
        y = y + xt.astype(jnp.float32) * d_skip[None, :, None]
    return y.astype(xt.dtype), s


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_reference(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)
