"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU adaptation notes (vs. the CUDA flash-attention blocking):
  * Tiles live in VMEM; block shapes are (block_q, head_dim) / (block_k,
    head_dim) with head_dim padded to the 128-lane MXU width by the caller.
  * The KV axis is the innermost *sequential* grid dimension; the online
    softmax accumulators (acc, m, l) persist in VMEM scratch across those
    iterations (TPU grids execute in order — the idiomatic replacement for
    the CUDA intra-CTA loop).
  * Fully-masked KV blocks are skipped with pl.when rather than warp-level
    early exit.

Layouts: q (B, H, Sq, D); k, v (B, KV, Sk, D); out (B, H, Sq, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  kv_offset: int, block_q: int, block_k: int,
                  num_k_blocks: int, sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + kv_offset   # absolute position of this q block
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    if causal or window is not None:
        # Skip blocks that are entirely masked (block-level sparsity).
        lo = q_start - (window - 1) if window is not None else -1
        alive = (k_start <= q_start + block_q - 1)
        alive &= (k_start + block_k - 1 >= lo) if window is not None else True
        pl.when(alive)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "kv_offset", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, kv_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5

    qt = q.transpose(0, 2, 1, 3)       # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)       # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - sk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_offset=kv_offset, block_q=block_q, block_k=block_k,
        num_k_blocks=nk, sq=sq, sk=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # running max m
            pltpu.VMEM((block_q,), jnp.float32),     # running sum l
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return out.transpose(0, 2, 1, 3)
