"""Mamba2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

Algorithm (arXiv 2405.21060, §6): split the sequence into chunks of Q
tokens.  Within a chunk the output is a masked, decay-weighted quadratic
form (MXU-friendly (Q x Q) @ (Q x P) matmuls); across chunks a (P x N)
state is carried.

TPU adaptation: the chunk axis is the innermost sequential grid dimension
and the running state lives in a VMEM scratch buffer — the systolic-array
analogue of the paper's inter-chunk recurrence (on GPU this is a separate
kernel launch + rescan).  Block shapes keep the (Q, N) and (Q, P) tiles
resident in VMEM; N = 128 matches the MXU lane width.

Layouts: x (B, H, L, P); dt (B, H, L); b, c (B, L, N); y (B, H, L, P);
final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_out_ref,
                s_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[0]                                 # ()       decay rate (this head)
    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    bm = b_ref[0].astype(jnp.float32)            # (Q, N)
    cm = c_ref[0].astype(jnp.float32)            # (Q, N)

    la = dt * a                                  # per-step log decay (Q,)
    cs = jnp.cumsum(la)                          # inclusive cumsum (Q,)
    # intra-chunk quadratic form
    seg = cs[:, None] - cs[None, :]              # (Qi, Qj)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(iota_i >= iota_j, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))   # (Qi, Qj)
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))      # (Qi, P)
    # inter-chunk contribution from the carried state
    s = s_ref[...]                                               # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cm, s, (((1,), (1,)), ((), ())))                         # (Q, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update
    tot = cs[-1]
    rem = jnp.exp(tot - cs) * dt                                 # (Q,)
    dbx = jax.lax.dot_general(x, bm * rem[:, None],
                              (((0,), (0,)), ((), ())))          # (P, N)
    s_ref[...] = s * jnp.exp(tot) + dbx

    @pl.when(ci == num_chunks - 1)
    def _finish():
        s_out_ref[0, 0] = s_ref[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N).

    Returns y (B, L, H, P) and final state (B, H, P, N) in float32."""
    B, L, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, L)
    nc = -(-L // chunk)
    pad = nc * chunk - L
    xt = x.transpose(0, 2, 1, 3)                 # (B, H, L, P)
    dtt = dt.transpose(0, 2, 1)                  # (B, H, L)
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    grid = (B, H, nc)
    y, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),                    # a
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, h, ci: (bi, h, ci)),    # dt
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),    # b
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),    # c
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc * chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(a, xt, dtt, b, c)
    return y[:, :, :L].transpose(0, 2, 1, 3), s
