"""Kernel dispatch: pallas (TPU) / interpret (tests) / ref (CPU dry-run).

Model code calls these wrappers; the active implementation is selected by
``set_default_impl`` or per-call.  On the CPU dry-run the ``ref`` paths are
used — `ref.mha_chunked` / `ref.ssd_chunked` share the kernels' blocking
structure so the lowered HLO shows the same memory behaviour.
"""
from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

_DEFAULT_IMPL: str | None = None  # None => auto


def set_default_impl(impl: str | None) -> None:
    """impl in {None, 'pallas', 'interpret', 'ref'}."""
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl in ("pallas", "interpret", "ref"):
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, kv_offset: int = 0,
              impl: str | None = None, block_q: int = 128, block_k: int = 128):
    """Multi-head (GQA) attention. q: (B,Sq,H,D), k/v: (B,Sk,KV,D)."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               scale=scale, kv_offset=kv_offset,
                               block_k=block_k)
    return _flash_pallas(q, k, v, causal=causal, window=window, scale=scale,
                         kv_offset=kv_offset, block_q=block_q, block_k=block_k,
                         interpret=(mode == "interpret"))


def ssd(x, dt, a, b, c, *, chunk: int = 128, impl: str | None = None):
    """Mamba2 SSD scan. Returns (y, final_state)."""
    mode = resolve_impl(impl)
    if mode == "ref":
        return ref.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    return _ssd_pallas(x, dt, a, b, c, chunk=chunk,
                       interpret=(mode == "interpret"))


def rmsnorm(x, w, *, eps: float = 1e-5, impl: str | None = None):
    mode = resolve_impl(impl)
    if mode == "ref":
        return ref.rmsnorm_reference(x, w, eps=eps)
    return _rmsnorm_pallas(x, w, eps=eps, interpret=(mode == "interpret"))
