"""Kernel dispatch: pallas (TPU) / interpret (tests) / fused / ref.

Model code calls these wrappers; the active implementation is selected
per-call, by ``set_default_impl``, by the ``REPRO_KERNEL_IMPL`` env var
(benches/CI force an impl without code edits), or automatically —
``"pallas"`` on TPU, ``"fused"`` elsewhere.

The tiers:

  * ``"pallas"`` — real Pallas TPU kernels.
  * ``"interpret"`` — the same kernels under the Pallas interpreter
    (CPU-testable, same blocking).
  * ``"fused"`` — the fast portable path: prefill/training wrappers
    (`attention`/`ssd`/`rmsnorm`) behave exactly like ``"ref"``, but the
    *decode* entry points use the fused step / GQA-no-repeat chunked
    attention (`kernels.fused_decode`, `ref.decode_attention_chunked`).
  * ``"ref"`` — the bitwise-historical oracle everywhere, including the
    op-by-op `blocks.attn_decode` body.  Serving parity tests pin this.
"""
from __future__ import annotations

import os

import jax

from . import ref
from .decode_attention import decode_attention as _decode_attn_pallas
from .flash_attention import flash_attention as _flash_pallas
from .fused_decode import attn_decode_step as _attn_decode_step
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

_IMPLS = ("pallas", "interpret", "fused", "ref")
_DEFAULT_IMPL: str | None = None  # None => env var, then auto


def set_default_impl(impl: str | None) -> None:
    """impl in {None, 'pallas', 'interpret', 'fused', 'ref'}."""
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or _DEFAULT_IMPL or os.environ.get("REPRO_KERNEL_IMPL")
    if impl in _IMPLS:
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "fused"


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, kv_offset: int = 0,
              impl: str | None = None, block_q: int = 128, block_k: int = 128):
    """Multi-head (GQA) attention. q: (B,Sq,H,D), k/v: (B,Sk,KV,D)."""
    mode = resolve_impl(impl)
    if mode in ("ref", "fused"):
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               scale=scale, kv_offset=kv_offset,
                               block_k=block_k)
    return _flash_pallas(q, k, v, causal=causal, window=window, scale=scale,
                         kv_offset=kv_offset, block_q=block_q, block_k=block_k,
                         interpret=(mode == "interpret"))


def ssd(x, dt, a, b, c, *, chunk: int = 128, impl: str | None = None):
    """Mamba2 SSD scan. Returns (y, final_state)."""
    mode = resolve_impl(impl)
    if mode in ("ref", "fused"):
        return ref.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    return _ssd_pallas(x, dt, a, b, c, chunk=chunk,
                       interpret=(mode == "interpret"))


def rmsnorm(x, w, *, eps: float = 1e-5, impl: str | None = None):
    mode = resolve_impl(impl)
    if mode in ("ref", "fused"):
        return ref.rmsnorm_reference(x, w, eps=eps)
    return _rmsnorm_pallas(x, w, eps=eps, interpret=(mode == "interpret"))


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: int | None = None, scale: float | None = None,
                     impl: str | None = None, block_k: int = 128):
    """Single-token decode attention over a resident (ring) cache.

    q: (B, H, hd); caches: (B, C, KV, hd); cache_len: () or (B,) valid
    slots.  ``"ref"`` is the historical oracle (`decode_attention_ref`);
    ``"fused"`` the GQA-no-repeat chunked path; ``"pallas"``/
    ``"interpret"`` the `kernels.decode_attention` Pallas kernel
    (scalar ``cache_len`` only — per-batch lengths fall back to the
    chunked path).
    """
    mode = resolve_impl(impl)
    if mode == "ref":
        return ref.decode_attention_ref(q, k_cache, v_cache, cache_len,
                                        window=window, scale=scale)
    if mode == "fused" or getattr(cache_len, "ndim", 0):
        return ref.decode_attention_chunked(q, k_cache, v_cache, cache_len,
                                            window=window, scale=scale,
                                            block_k=block_k)
    return _decode_attn_pallas(q, k_cache, v_cache, cache_len, window=window,
                               scale=scale, block_k=block_k,
                               interpret=(mode == "interpret"))


def attn_decode_step(x, k_cache, v_cache, pos, *, norm, wq, wk, wv, wo,
                     bq=None, bk=None, bv=None, n_heads: int,
                     head_dim: int, eps: float = 1e-5,
                     rope_theta: float = 10_000.0, impl: str | None = None,
                     block_k: int = 128):
    """Fused one-token attention sublayer step (see `kernels.fused_decode`).

    Returns (out (B,1,D), k_cache, v_cache) with the ring slot freshly
    written and cache avals unchanged leaf-for-leaf (donation contract).
    ``"ref"`` does not route here — `blocks.attn_decode` keeps the
    historical op-by-op body for that impl.
    """
    mode = resolve_impl(impl)
    return _attn_decode_step(
        x, k_cache, v_cache, pos, norm=norm, wq=wq, wk=wk, wv=wv, wo=wo,
        bq=bq, bk=bk, bv=bv, n_heads=n_heads, head_dim=head_dim, eps=eps,
        rope_theta=rope_theta, mode=mode, block_k=block_k)
