"""Fused RMSNorm Pallas kernel (row-tiled, f32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D), w: (D,) — fused normalise + scale."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = max(1, min(block_rows, rows))
    nb = -(-rows // block_rows)
    pad = nb * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(shape)
