"""Fused single-token attention-sublayer step for decode.

One decode token through an attention sublayer is rmsnorm -> QKV -> rope
-> ring-buffer cache write -> decode attention -> output proj -> residual.
The historical path (`blocks.attn_decode`, kept verbatim under the
``"ref"`` impl) dispatches those as separate XLA ops and rep-folds the
GQA cache; this module fuses them:

  * `_composed_step` — kernel-composed XLA: the same op sequence but with
    the decode attention swapped for `ref.decode_attention_chunked` (the
    no-repeat online-softmax blocking) or the Pallas
    `decode_attention` kernel.  This is the ``"fused"`` CPU hot path and
    the universal fallback.
  * `_fused_pallas_step` — the whole sublayer in ONE Pallas kernel
    (grid over batch rows, scalar-prefetched position): norm, QKV, rope,
    attention with *stale-slot masking*, output proj, residual.  The
    cache write stays OUTSIDE the kernel as a `dynamic_update_slice` so
    XLA's donation aliasing still updates the ring buffer in place —
    pushing the write inside via input/output aliasing would force a
    full-cache copy per token.  Instead the kernel masks the (stale)
    slot about to be overwritten and appends the fresh token's logit as
    an explicit extra column: attention over {old entries != slot} plus
    the current token is exactly attention over the *updated* cache at
    ``cache_len = min(pos+1, C)``, for both the growing (pos < C) and
    wrapped (pos >= C) ring states.

Weight-stationarity note: the fused kernel re-streams the projection
weights once per batch row — the right trade at decode batch sizes,
where the cache and weights dominate bytes anyway; `_fits_vmem` guards
the per-row working set and falls back to `_composed_step` when the
sublayer would not fit.

The rope/rmsnorm math is replicated locally from `models.common`
(kernels must not import models); `tests/test_kernels.py` pins the
step against the historical op-by-op body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .decode_attention import decode_attention

NEG_INF = -1e30

# per-kernel-instance VMEM working-set ceiling for the fully-fused step
# (weights + both cache rows + activations, f32); beyond this we compose
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _rope_tables(pos, d2, theta):
    """cos/sin rows (1, d2) for one absolute position (f32)."""
    # mirrors models.common.rope's frequency layout; 2D iota for TPU
    exp = jax.lax.broadcasted_iota(jnp.float32, (1, d2), 1) / d2
    freq = theta ** (-exp)
    ang = pos.astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    """x: (rows, hd); rotate the first 2*d2 dims, pass the odd tail."""
    d = x.shape[-1]
    d2 = cos.shape[-1]
    x1, x2 = x[:, :d2], x[:, d2:2 * d2]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * d2 < d:
        rot = jnp.concatenate([rot, x[:, 2 * d2:]], axis=-1)
    return rot


def _rope_host(x, positions, theta):
    """(B, S, heads, hd) rope — local copy of models.common.rope math."""
    d = x.shape[-1]
    d2 = d // 2
    freq = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    ang = positions[..., None].astype(jnp.float32) * freq
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * d2 < d:
        rot = jnp.concatenate([rot, x[..., 2 * d2:]], axis=-1)
    return rot.astype(x.dtype)


def _fused_kernel(pos_ref, x_ref, kc_ref, vc_ref, norm_ref, wq_ref, wk_ref,
                  wv_ref, wo_ref, bq_ref, bk_ref, bv_ref,
                  o_ref, kn_ref, vn_ref, *,
                  n_heads, kv_heads, head_dim, cap, eps, theta, scale,
                  has_bias):
    f32 = jnp.float32
    rep = n_heads // kv_heads
    d2 = head_dim // 2
    pos = pos_ref[0]

    x = x_ref[...].astype(f32)                     # (1, D)
    w = norm_ref[...].astype(f32)                  # (1, D)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    h = x * rms * w                                # (1, D)

    def proj(w_ref, b_ref, rows):
        y = jax.lax.dot_general(
            h, w_ref[...].astype(f32), (((1,), (0,)), ((), ())))
        if has_bias:
            y = y + b_ref[...].astype(f32)
        return y.reshape(rows, head_dim)

    q = proj(wq_ref, bq_ref, n_heads)              # (H, hd)
    k = proj(wk_ref, bk_ref, kv_heads)             # (KV, hd)
    v = proj(wv_ref, bv_ref, kv_heads)             # (KV, hd)

    cos, sin = _rope_tables(pos, d2, theta)
    q = _apply_rope(q, cos, sin) * scale
    k = _apply_rope(k, cos, sin)

    slot = jnp.mod(pos, cap)
    live = jnp.minimum(pos, cap)      # valid OLD entries (slot is stale)
    idx = jax.lax.broadcasted_iota(jnp.int32, (rep, cap), 1)
    mask = (idx < live) & (idx != slot)

    # static loop over KV groups keeps every in-kernel op a 2D matmul /
    # elementwise (no 3D transposes for Mosaic to lower)
    outs = []
    for g in range(kv_heads):
        qg = q[g * rep:(g + 1) * rep]              # (rep, hd)
        kg = kc_ref[0, :, g, :].astype(f32)        # (cap, hd)
        vg = vc_ref[0, :, g, :].astype(f32)
        s = jax.lax.dot_general(qg, kg, (((1,), (1,)), ((), ())))
        s = jnp.where(mask, s, NEG_INF)            # (rep, cap)
        s_cur = jax.lax.dot_general(               # fresh token's column
            qg, k[g:g + 1], (((1,), (1,)), ((), ())))       # (rep, 1)
        m = jnp.maximum(s.max(axis=1, keepdims=True), s_cur)
        p = jnp.exp(s - m)
        p_cur = jnp.exp(s_cur - m)
        l = p.sum(axis=1, keepdims=True) + p_cur
        og = jax.lax.dot_general(p, vg, (((1,), (0,)), ((), ())))
        og = (og + p_cur * v[g:g + 1]) / l         # (rep, hd)
        outs.append(og)
    o = jnp.concatenate(outs, axis=0) if kv_heads > 1 else outs[0]

    orow = jax.lax.dot_general(
        o.reshape(1, n_heads * head_dim), wo_ref[...].astype(f32),
        (((1,), (0,)), ((), ())))
    o_ref[...] = (x + orow).astype(o_ref.dtype)
    kn_ref[0] = k.astype(kn_ref.dtype)
    vn_ref[0] = v.astype(vn_ref.dtype)


def _fits_vmem(d_model, n_heads, kv_heads, head_dim, cap) -> bool:
    qkvo = d_model * (2 * n_heads + 2 * kv_heads) * head_dim
    cache = 2 * cap * kv_heads * head_dim
    act = 4 * d_model + 2 * n_heads * head_dim + cap * max(8, n_heads)
    return 4 * (qkvo + cache + act) <= _VMEM_BUDGET_BYTES


def _fused_pallas_step(x2, k_cache, v_cache, pos, *, norm, wq, wk, wv, wo,
                       bq, bk, bv, n_heads, head_dim, eps, theta, scale,
                       interpret):
    B, D = x2.shape
    _, cap, kv_heads, _ = k_cache.shape
    has_bias = bq is not None
    hdim = n_heads * head_dim
    kdim = kv_heads * head_dim
    zb = jnp.zeros((1, 1), x2.dtype)   # bias placeholders keep arity fixed
    biases = ((bq.reshape(1, hdim), bk.reshape(1, kdim),
               bv.reshape(1, kdim)) if has_bias else (zb, zb, zb))
    bspecs = ([pl.BlockSpec((1, hdim), lambda b, _p: (0, 0)),
               pl.BlockSpec((1, kdim), lambda b, _p: (0, 0)),
               pl.BlockSpec((1, kdim), lambda b, _p: (0, 0))] if has_bias
              else [pl.BlockSpec((1, 1), lambda b, _p: (0, 0))] * 3)

    kernel = functools.partial(
        _fused_kernel, n_heads=n_heads, kv_heads=kv_heads,
        head_dim=head_dim, cap=cap, eps=eps, theta=theta, scale=scale,
        has_bias=has_bias)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, _p: (b, 0)),
            pl.BlockSpec((1, cap, kv_heads, head_dim),
                         lambda b, _p: (b, 0, 0, 0)),
            pl.BlockSpec((1, cap, kv_heads, head_dim),
                         lambda b, _p: (b, 0, 0, 0)),
            pl.BlockSpec((1, D), lambda b, _p: (0, 0)),
            pl.BlockSpec((D, hdim), lambda b, _p: (0, 0)),
            pl.BlockSpec((D, kdim), lambda b, _p: (0, 0)),
            pl.BlockSpec((D, kdim), lambda b, _p: (0, 0)),
            pl.BlockSpec((hdim, D), lambda b, _p: (0, 0)),
            *bspecs,
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda b, _p: (b, 0)),
            pl.BlockSpec((1, kv_heads, head_dim), lambda b, _p: (b, 0, 0)),
            pl.BlockSpec((1, kv_heads, head_dim), lambda b, _p: (b, 0, 0)),
        ],
    )
    posv = jnp.asarray(pos, jnp.int32).reshape((1,))
    out, k_new, v_new = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, D), x2.dtype),
            jax.ShapeDtypeStruct((B, kv_heads, head_dim), k_cache.dtype),
            jax.ShapeDtypeStruct((B, kv_heads, head_dim), v_cache.dtype),
        ],
        interpret=interpret,
    )(posv, x2, k_cache, v_cache, norm.reshape(1, D), wq, wk, wv, wo,
      *biases)
    slot = jnp.mod(pos, cap)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new[:, None], (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new[:, None], (0, slot, 0, 0))
    return out[:, None], k_cache, v_cache


def _composed_step(x, k_cache, v_cache, pos, *, norm, wq, wk, wv, wo,
                   bq, bk, bv, n_heads, head_dim, eps, theta, scale,
                   attn_mode, block_k):
    B = x.shape[0]
    cap = k_cache.shape[1]
    kv_heads = wk.shape[1] // head_dim
    h = ref.rmsnorm_reference(x, norm, eps=eps)
    q = h @ wq.astype(x.dtype)
    k = h @ wk.astype(x.dtype)
    v = h @ wv.astype(x.dtype)
    if bq is not None:
        q = q + bq.astype(x.dtype)
        k = k + bk.astype(x.dtype)
        v = v + bv.astype(x.dtype)
    positions = jnp.full((1,), pos)
    q = _rope_host(q.reshape(B, 1, n_heads, head_dim), positions, theta)
    k = _rope_host(k.reshape(B, 1, kv_heads, head_dim), positions, theta)
    v = v.reshape(B, 1, kv_heads, head_dim)
    slot = jnp.mod(pos, cap)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    cache_len = jnp.minimum(pos + 1, cap)
    if attn_mode in ("pallas", "interpret"):
        o = decode_attention(q[:, 0], k_cache, v_cache, cache_len,
                             scale=scale, block_k=block_k,
                             interpret=attn_mode == "interpret")
    else:
        o = ref.decode_attention_chunked(q[:, 0], k_cache, v_cache,
                                         cache_len, scale=scale,
                                         block_k=block_k)
    out = x + o.reshape(B, 1, -1) @ wo.astype(x.dtype)
    return out, k_cache, v_cache


def attn_decode_step(x, k_cache, v_cache, pos, *, norm, wq, wk, wv, wo,
                     bq=None, bk=None, bv=None, n_heads, head_dim,
                     eps=1e-5, rope_theta=10_000.0, mode="fused",
                     block_k: int = 128):
    """One-token attention sublayer: (B, 1, D) in, (out, k_cache, v_cache)
    out, ring slot ``pos % C`` freshly written.  Cache outputs keep the
    input avals leaf-for-leaf (the `lm.decode_cache_structs` donation
    contract).  ``mode``: "pallas"/"interpret" try the single fused
    Pallas kernel (VMEM permitting) and fall back to the kernel-composed
    step; "fused" (CPU default) composes around the chunked no-repeat
    attention; "ref" is handled by `blocks.attn_decode` upstream and
    never reaches here.
    """
    B, _, D = x.shape
    cap, kv_heads = k_cache.shape[1], k_cache.shape[2]
    scale = head_dim ** -0.5
    if mode in ("pallas", "interpret") and _fits_vmem(
            D, n_heads, kv_heads, head_dim, cap):
        return _fused_pallas_step(
            x[:, 0], k_cache, v_cache, pos, norm=norm, wq=wq, wk=wk, wv=wv,
            wo=wo, bq=bq, bk=bk, bv=bv, n_heads=n_heads, head_dim=head_dim,
            eps=eps, theta=rope_theta, scale=scale,
            interpret=mode == "interpret")
    return _composed_step(
        x, k_cache, v_cache, pos, norm=norm, wq=wq, wk=wk, wv=wv, wo=wo,
        bq=bq, bk=bk, bv=bv, n_heads=n_heads, head_dim=head_dim, eps=eps,
        theta=rope_theta, scale=scale, attn_mode=mode, block_k=block_k)
