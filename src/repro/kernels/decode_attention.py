"""Decode-attention Pallas TPU kernel: one token against a resident cache.

A decode step attends one query row per (batch, head) against the whole
ring-buffered KV cache — a masked softmax-weighted *gather*, so the step
is memory-bound by construction: the only real work is streaming the
cache past the accumulators once.  The kernel therefore

  * never materialises the GQA head repeat (`ref.decode_attention_ref`
    pays a rep-fold copy of BOTH caches per token): q is reshaped to
    (B, KV, rep, hd) — group g owns query heads [g*rep, (g+1)*rep) —
    and the caches transpose to (B, KV, C, hd), so each grid cell
    (b, g, j) contracts a (rep, hd) query tile against one (block_k, hd)
    cache block;
  * keeps the KV-block axis as the innermost *sequential* grid dimension
    with the online-softmax accumulators (acc, m, l) persisting in VMEM
    scratch across it (`flash_attention`'s idiom, degenerate q block);
  * takes ``cache_len`` as a *traced* scalar in scalar-prefetch SMEM
    (`PrefetchScalarGridSpec`): it masks ``idx < cache_len`` (plus the
    optional sliding window) and skips blocks entirely past the live
    prefix with `pl.when`, so a short cache in a long buffer costs only
    the blocks it occupies.

Ring wraparound needs no index arithmetic here: `blocks.attn_decode`
writes slot ``pos % C`` and passes ``cache_len = min(pos + 1, C)`` —
once the buffer wraps every slot is live and the mask is all-true, and
softmax attention is permutation-invariant over the key axis, so slot
*order* is irrelevant.  `ref.decode_attention_chunked` is the same
blocking in plain jnp (the CPU hot path); `ref.decode_attention_ref`
is the gold oracle.

Layouts: q (B, H, hd); k_cache, v_cache (B, C, KV, hd); out (B, H, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, window: int | None, block_k: int,
                   num_k_blocks: int, rep_pad: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    clen = len_ref[0]
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (rep_pad, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        idx = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (rep_pad, block_k), 1)
        mask = idx < clen
        if window is not None:
            mask &= idx >= clen - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    # block-level sparsity on the TRACED length: blocks entirely past the
    # live prefix (or entirely before the window) contribute nothing.
    # Block 0 is always alive without a window (cache_len >= 1 in decode).
    alive = k_start < clen
    if window is not None:
        alive &= k_start + block_k - 1 >= clen - window
    pl.when(alive)(_compute)

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: int | None = None, scale: float | None = None,
                     block_k: int = 128, interpret: bool = False):
    """q: (B, H, hd) + cache (B, C, KV, hd) + cache_len () -> (B, H, hd).

    ``cache_len`` is a traced scalar (number of valid slots); per-batch
    lengths are a `ref.decode_attention_chunked` capability only.
    """
    b, h, d = q.shape
    _, c, kv, _ = k_cache.shape
    assert h % kv == 0, f"{h} query heads not a multiple of {kv} kv heads"
    rep = h // kv
    scale = scale if scale is not None else d ** -0.5

    rep_pad = max(8, rep)              # f32 min sublane tile is 8 rows
    qr = q.reshape(b, kv, rep, d)
    if rep_pad > rep:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rep_pad - rep), (0, 0)))
    kt = k_cache.transpose(0, 2, 1, 3)     # (B, KV, C, hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    block_k = min(block_k, c)
    nk = -(-c // block_k)
    pad_k = nk * block_k - c
    if pad_k:                          # padded slots mask as idx >= cache_len
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    clen = jnp.asarray(cache_len, jnp.int32).reshape((1,))
    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, block_k=block_k,
        num_k_blocks=nk, rep_pad=rep_pad)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, rep_pad, d),
                         lambda b, g, j, _len: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, g, j, _len: (b, g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, g, j, _len: (b, g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep_pad, d),
                               lambda b, g, j, _len: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep_pad, d), jnp.float32),   # acc
            pltpu.VMEM((rep_pad,), jnp.float32),     # running max m
            pltpu.VMEM((rep_pad,), jnp.float32),     # running sum l
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, rep_pad, d), q.dtype),
        interpret=interpret,
    )(clen, qr, kt, vt)
    return out[:, :, :rep].reshape(b, h, d)
