"""Transformer / Mamba2 / MoE blocks: init + forward + single-token decode.

All block params are plain dict pytrees; callers stack them over layer
periods and scan.  Forward functions take and return (B, S, D) activations
in the compute dtype; decode functions operate on one token with explicit
cache state (functional, no mutation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import AttnCfg, MambaCfg, ModelConfig, MoECfg
from ..kernels import ops, ref
from .common import KeyGen, activation, dense_init, rmsnorm, rope
from .. import sharding_ctx as sc


# ===========================================================================
# Attention
# ===========================================================================
def init_attn(kg: KeyGen, cfg: ModelConfig, tag: str, cross: bool = False):
    a = cfg.attn
    d, hd = cfg.d_model, a.head_dim
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    p = {
        "norm": jnp.ones((d,), jnp.float32),
        "wq": dense_init(kg(tag, "wq"), (d, a.n_heads * hd), dt),
        "wk": dense_init(kg(tag, "wk"), (d, a.n_kv_heads * hd), dt),
        "wv": dense_init(kg(tag, "wv"), (d, a.n_kv_heads * hd), dt),
        "wo": dense_init(kg(tag, "wo"), (a.n_heads * hd, d), dt),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((a.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((a.n_kv_heads * hd,), dt)
    return p


def _qkv(p, cfg: ModelConfig, x, positions, *, rope_q=True):
    a = cfg.attn
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = sc.act(q.reshape(B, S, a.n_heads, a.head_dim), "dp", None, "tp", None)
    k = sc.act(k.reshape(B, S, a.n_kv_heads, a.head_dim), "dp", None, "tp", None)
    v = sc.act(v.reshape(B, S, a.n_kv_heads, a.head_dim), "dp", None, "tp", None)
    if rope_q:
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)
    return q, k, v


def attn_forward(p, cfg: ModelConfig, x, positions, *, causal=True,
                 impl=None, return_kv=False):
    """Self-attention sublayer (pre-norm, residual)."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h, positions)
    o = ops.attention(q, k, v, causal=causal,
                      window=cfg.attn.window if causal else None, impl=impl)
    B, S, _ = x.shape
    out = sc.act(x + o.reshape(B, S, -1) @ p["wo"].astype(x.dtype),
                 "dp", "sp", None)
    if return_kv:
        return out, (k, v)
    return out


def cross_attn_forward(p, cfg: ModelConfig, x, enc_kv, *, impl=None):
    """Cross-attention sublayer; enc_kv = (k, v) precomputed from encoder."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    a = cfg.attn
    B, S, _ = x.shape
    q = (h @ p["wq"].astype(x.dtype))
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, a.n_heads, a.head_dim)
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False, impl=impl)
    return sc.act(x + o.reshape(B, S, -1) @ p["wo"].astype(x.dtype),
                  "dp", "sp", None)


def cross_kv(p, cfg: ModelConfig, enc_out):
    """Precompute cross-attention K/V from encoder output (B, Se, D)."""
    a = cfg.attn
    B, Se, _ = enc_out.shape
    k = enc_out @ p["wk"].astype(enc_out.dtype)
    v = enc_out @ p["wv"].astype(enc_out.dtype)
    if a.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return (k.reshape(B, Se, a.n_kv_heads, a.head_dim),
            v.reshape(B, Se, a.n_kv_heads, a.head_dim))


def attn_cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    w = cfg.attn.window if cfg.attn else None
    return min(seq_len, w) if w else seq_len


def init_attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    a = cfg.attn
    shape = (batch, capacity, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p, cfg: ModelConfig, x, cache, pos, *, impl=None):
    """One-token self-attention.  x: (B, 1, D); cache {k,v}: (B, C, KV, hd);
    pos: () int32 absolute position.  Ring-buffered for SWA.

    Every impl except ``"ref"`` routes through the fused step
    (`kernels.ops.attn_decode_step`: rmsnorm + QKV + rope + cache write +
    decode attention + output proj in one call); ``"ref"`` keeps the
    historical op-by-op body verbatim — the bitwise oracle the serving
    parity tests pin.  Both return caches with the input avals
    leaf-for-leaf (the `lm.decode_cache_structs` donation contract)."""
    a = cfg.attn
    B = x.shape[0]
    mode = ops.resolve_impl(impl)
    if mode != "ref":
        o, k_cache, v_cache = ops.attn_decode_step(
            x, cache["k"], cache["v"], pos,
            norm=p["norm"], wq=p["wq"], wk=p["wk"], wv=p["wv"], wo=p["wo"],
            bq=p.get("bq"), bk=p.get("bk"), bv=p.get("bv"),
            n_heads=a.n_heads, head_dim=a.head_dim, eps=cfg.norm_eps,
            rope_theta=a.rope_theta, impl=mode)
        return sc.act(o, "dp", "sp", None), {"k": k_cache, "v": v_cache}
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h, jnp.full((1,), pos))
    C = cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, slot, 0, 0))
    cache_len = jnp.minimum(pos + 1, C)
    o = ref.decode_attention_ref(q[:, 0], k_cache, v_cache, cache_len)
    out = sc.act(x + o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype),
                 "dp", "sp", None)
    return out, {"k": k_cache, "v": v_cache}


def cross_attn_decode(p, cfg: ModelConfig, x, enc_kv, *, impl=None):
    a = cfg.attn
    B = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = h @ p["wq"].astype(x.dtype)
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, a.n_heads, a.head_dim)
    k, v = enc_kv
    # impl-dispatched like every other attention site (`set_default_impl`
    # / REPRO_KERNEL_IMPL govern this one too); "ref" is the old call
    o = ops.decode_attention(q, k, v, k.shape[1], impl=impl)
    return x + o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def init_mamba(kg: KeyGen, cfg: ModelConfig, tag: str):
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    H = m.n_ssm_heads(d)
    N = m.d_state
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_xz": dense_init(kg(tag, "w_xz"), (d, 2 * di), dt),
        "w_bcdt": dense_init(kg(tag, "w_bcdt"), (d, 2 * m.n_groups * N + H), dt),
        "conv_w": dense_init(kg(tag, "conv"), (m.d_conv, di), dt, scale=0.5),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),           # A = -exp(a_log) = -1
        "d_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(kg(tag, "w_out"), (di, d), dt),
    }


def _mamba_proj(p, cfg: ModelConfig, h):
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    H = m.n_ssm_heads(d)
    N = m.d_state
    xz = h @ p["w_xz"].astype(h.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = sc.act(x_in, "dp", None, "tp")
    z = sc.act(z, "dp", None, "tp")
    bcdt = sc.act(h @ p["w_bcdt"].astype(h.dtype), "dp", "sp", None)
    b = bcdt[..., :N]
    c = bcdt[..., N:2 * N]
    dt_raw = bcdt[..., 2 * N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return x_in, z, b, c, dt


def mamba_forward(p, cfg: ModelConfig, x, *, impl=None, chunk=128):
    """Mamba2 block (pre-norm, residual).  x: (B, S, D)."""
    m = cfg.mamba
    B, S, _ = x.shape
    di = m.d_inner(cfg.d_model)
    H = m.n_ssm_heads(cfg.d_model)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    x_in, z, b, c, dt = _mamba_proj(p, cfg, h)
    # depthwise causal conv (d_conv taps) as shifted adds
    w = p["conv_w"].astype(x_in.dtype)
    conv = jnp.zeros_like(x_in)
    for k in range(m.d_conv):
        shift = m.d_conv - 1 - k
        sl = x_in if shift == 0 else jnp.pad(x_in, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        conv = conv + sl * w[k]
    xh = sc.act(jax.nn.silu(conv).reshape(B, S, H, m.head_dim),
                "dp", None, "tp", None)
    a = -jnp.exp(p["a_log"])
    y, _ = ops.ssd(xh, dt, a, b, c, chunk=chunk, impl=impl)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    return sc.act(x + y @ p["w_out"].astype(x.dtype), "dp", "sp", None)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    H = m.n_ssm_heads(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, H, m.head_dim, m.d_state), jnp.float32),
    }


def mamba_decode(p, cfg: ModelConfig, x, cache, *, impl=None):
    """One-token Mamba2 step.  x: (B, 1, D)."""
    m = cfg.mamba
    B = x.shape[0]
    di = m.d_inner(cfg.d_model)
    H = m.n_ssm_heads(cfg.d_model)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    x_in, z, b, c, dt = _mamba_proj(p, cfg, h)
    x_in, z, b, c, dt = x_in[:, 0], z[:, 0], b[:, 0], c[:, 0], dt[:, 0]
    w = p["conv_w"].astype(x_in.dtype)
    hist = cache["conv"]                                  # (B, d_conv-1, di)
    conv = x_in * w[-1] + jnp.einsum("bkd,kd->bd", hist.astype(x_in.dtype), w[:-1])
    conv_new = jnp.concatenate([hist[:, 1:], x_in[:, None].astype(hist.dtype)], axis=1)
    xh = jax.nn.silu(conv).reshape(B, H, m.head_dim)
    a = -jnp.exp(p["a_log"])
    y, ssm_new = ref.ssd_decode_step(cache["ssm"], xh, dt, a, b, c)
    y = y + xh * p["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(B, di) * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    out = sc.act(x + (y @ p["w_out"].astype(x.dtype))[:, None],
                 "dp", "sp", None)
    return out, {"conv": conv_new, "ssm": sc.act(ssm_new, "dp", "tp", None, None)}


# ===========================================================================
# MLP / MoE
# ===========================================================================
def _init_ffn(kg: KeyGen, cfg: ModelConfig, tag: str, d_ff: int, dt,
              expert_dims: tuple[int, ...] = ()):
    d = cfg.d_model
    gated = cfg.act == "silu_glu"
    p = {}
    if gated:
        p["w_gate"] = dense_init(kg(tag, "w_gate"), (*expert_dims, d, d_ff), dt)
    p["w_up"] = dense_init(kg(tag, "w_up"), (*expert_dims, d, d_ff), dt)
    p["w_down"] = dense_init(kg(tag, "w_down"), (*expert_dims, d_ff, d), dt)
    return p


def _ffn(p, cfg: ModelConfig, h):
    if cfg.act == "silu_glu":
        act = sc.act(jax.nn.silu(h @ p["w_gate"].astype(h.dtype)),
                     "dp", None, "tp")
        up = sc.act(h @ p["w_up"].astype(h.dtype), "dp", None, "tp")
        return (act * up) @ p["w_down"].astype(h.dtype)
    act = sc.act(activation(cfg.act)(h @ p["w_up"].astype(h.dtype)),
                 "dp", None, "tp")
    return act @ p["w_down"].astype(h.dtype)


def init_mlp(kg: KeyGen, cfg: ModelConfig, tag: str):
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    if cfg.d_ff == 0:  # attn-free Mamba2 stacks carry no MLP sublayer
        return {"norm": jnp.ones((cfg.d_model,), jnp.float32)}
    p = {"norm": jnp.ones((cfg.d_model,), jnp.float32)}
    p.update(_init_ffn(kg, cfg, tag, cfg.d_ff, dt))
    return p


def mlp_forward(p, cfg: ModelConfig, x):
    if cfg.d_ff == 0:
        return x
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return sc.act(x + _ffn(p, cfg, h), "dp", "sp", None)


def init_moe(kg: KeyGen, cfg: ModelConfig, tag: str):
    e = cfg.moe
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    p = {"norm": jnp.ones((cfg.d_model,), jnp.float32),
         "router": dense_init(kg(tag, "router"), (cfg.d_model, e.n_experts),
                              jnp.float32, scale=0.02)}
    p["experts"] = _init_ffn(kg, cfg, tag + ".experts", e.d_ff, dt,
                             expert_dims=(e.n_experts,))
    if e.shared_expert:
        p["shared"] = _init_ffn(kg, cfg, tag + ".shared", e.d_ff, dt)
    return p


def _expert_ffn(p, cfg: ModelConfig, xe):
    """xe: (B, E, C, D) -> (B, E, C, D) via per-expert FFN weights."""
    if cfg.act == "silu_glu":
        act = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                                     p["w_gate"].astype(xe.dtype)))
        up = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(xe.dtype))
        return jnp.einsum("becf,efd->becd", act * up, p["w_down"].astype(xe.dtype))
    act = activation(cfg.act)(jnp.einsum("becd,edf->becf", xe,
                                         p["w_up"].astype(xe.dtype)))
    return jnp.einsum("becf,efd->becd", act, p["w_down"].astype(xe.dtype))


MOE_IMPL = "einsum"     # "einsum" (GShard dense) | "sorted" (ragged a2a)


def set_moe_impl(name: str) -> None:
    global MOE_IMPL
    assert name in ("einsum", "sorted")
    MOE_IMPL = name


def moe_forward(p, cfg: ModelConfig, x):
    """GShard-style top-k dispatch with capacity (einsum dispatch/combine).

    Token dim shards over data axes; expert dim shards over the model axis
    (expert parallelism).  x: (B, S, D).  ``set_moe_impl("sorted")``
    switches to the ragged sorted-dispatch path (moe_forward_sorted)."""
    if MOE_IMPL == "sorted":
        return moe_forward_sorted(p, cfg, x)
    e = cfg.moe
    B, S, D = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    logits = (h.astype(jnp.float32) @ p["router"])           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    cap = max(1, int(S * e.capacity_factor * e.top_k / e.n_experts))

    out = jnp.zeros_like(h)
    remaining = probs
    occupancy = jnp.zeros((B, e.n_experts), jnp.int32)
    for _ in range(e.top_k):
        idx = jnp.argmax(remaining, axis=-1)                  # (B, S)
        gate = jnp.take_along_axis(remaining, idx[..., None], axis=-1)[..., 0]
        onehot = jax.nn.one_hot(idx, e.n_experts, dtype=jnp.int32)  # (B,S,E)
        pos = jnp.cumsum(onehot, axis=1) - onehot + occupancy[:, None, :]
        pos_tok = jnp.sum(pos * onehot, axis=-1)              # (B, S)
        keep = pos_tok < cap
        disp = (jax.nn.one_hot(idx, e.n_experts, dtype=h.dtype)[..., :, None]
                * jax.nn.one_hot(pos_tok, cap, dtype=h.dtype)[..., None, :]
                * keep[..., None, None].astype(h.dtype))      # (B,S,E,C)
        # dispatched tensor: expert dim on the EP axis.  ep_data: tokens
        # all-to-all to the data row owning their expert (expert weights
        # are NEVER gathered); ep_model: experts on the model axis (naive).
        xe = sc.act(jnp.einsum("bsd,bsec->becd", h, disp),
                    "ep_tok", "ep", None, None)
        ye = _expert_ffn(p["experts"], cfg, xe)
        ye = sc.act(ye, "ep_tok", "ep", None, None)
        out = out + jnp.einsum("becd,bsec->bsd", ye,
                               disp * gate[..., None, None].astype(h.dtype))
        occupancy = occupancy + jnp.sum(onehot * keep[..., None].astype(jnp.int32), axis=1)
        remaining = remaining * (1.0 - jax.nn.one_hot(idx, e.n_experts))
    if e.shared_expert:
        out = out + _ffn(p["shared"], cfg, h)
    return sc.act(x + out.astype(x.dtype), "dp", "sp", None)




# ---------------------------------------------------------------------------
# Sorted (ragged) MoE dispatch — Switch/Tutel-style, beyond-paper (§Perf B)
# ---------------------------------------------------------------------------
def _ffn2(wg, wu, wd, cfg: ModelConfig, h):
    """Per-expert FFN on (E, C, D) buffers with local weight shards."""
    if cfg.act == "silu_glu":
        a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype)))
        u = jnp.einsum("ecd,edf->ecf", h, wu.astype(h.dtype))
        return jnp.einsum("ecf,efd->ecd", a * u, wd.astype(h.dtype))
    a = activation(cfg.act)(jnp.einsum("ecd,edf->ecf", h, wu.astype(h.dtype)))
    return jnp.einsum("ecf,efd->ecd", a, wd.astype(h.dtype))


def _sorted_dispatch_local(h2, probs, experts, cfg: ModelConfig, cap: int,
                           *, ep_axes=None, tp_axis=None, n_ep: int = 1):
    """Token-sorted top-k dispatch on one shard (or globally when no mesh).

    h2: (N, D) normed tokens; probs: (N, E) router probabilities.
    experts: dict of LOCAL expert weight shards (E or E/n_ep on dim 0).
    Inside shard_map: ep_axes carries the all-to-all (expert parallelism),
    tp_axis the within-expert psum (F sharded).  The (B,S,E,C) one-hot of
    the einsum path is never built: per round the traffic is one (E,C,D)
    buffer each way — measured 5.4 GB -> 52 MB per layer-pass on
    llama4-maverick (EXPERIMENTS.md §Perf Cell B).
    """
    e = cfg.moe
    N, D = h2.shape
    E = e.n_experts
    out = jnp.zeros((N, D), h2.dtype)
    remaining = probs
    for _ in range(e.top_k):
        ids = jnp.argmax(remaining, axis=-1)                    # (N,)
        gate = jnp.take_along_axis(remaining, ids[:, None], axis=-1)[:, 0]
        order = jnp.argsort(ids, stable=True)                   # tokens by expert
        ids_s = ids[order]
        counts = jnp.bincount(ids, length=E)
        starts = jnp.cumsum(counts) - counts                    # (E,)
        slot = jnp.arange(N) - starts[ids_s]                    # rank in expert
        slot = jnp.where(slot < cap, slot, cap)                 # cap -> dropped
        buf = jnp.zeros((E, cap, D), h2.dtype)
        buf = buf.at[ids_s, slot].set(h2[order], mode="drop")
        if ep_axes is not None:
            # exchange expert-major slices: (E, C, D) -> (E/n_ep, n_ep*C, D)
            buf = jax.lax.all_to_all(buf, ep_axes, split_axis=0,
                                     concat_axis=1, tiled=True)
        ye = _ffn2(experts["w_gate"], experts["w_up"], experts["w_down"],
                   cfg, buf) if "w_gate" in experts else             _ffn2(experts["w_up"], experts["w_up"], experts["w_down"],
                  cfg, buf)
        if tp_axis is not None:
            ye = jax.lax.psum(ye, tp_axis)                      # row-parallel F
        if ep_axes is not None:
            ye = jax.lax.all_to_all(ye, ep_axes, split_axis=1,
                                    concat_axis=0, tiled=True)
        tok = ye.at[ids_s, slot].get(mode="fill", fill_value=0)  # (N, D)
        contrib = jnp.zeros((N, D), h2.dtype).at[order].set(tok)
        out = out + contrib * gate[:, None].astype(h2.dtype)
        remaining = remaining * (1.0 - jax.nn.one_hot(ids, E,
                                                      dtype=remaining.dtype))
    return out


def moe_forward_sorted(p, cfg: ModelConfig, x):
    """Sorted-dispatch MoE block.  Under an active sharding context the
    dispatch runs in shard_map with explicit all_to_all/psum (experts on
    the data axes, F on the model axis — requires ep_axis="data" param
    layout); without a context it runs locally (CPU tests)."""
    e = cfg.moe
    B, S, D = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    logits = (h.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    cap = max(1, int(S * e.capacity_factor * e.top_k / e.n_experts))
    ctx = sc.current()

    if ctx is None or ctx.mesh.shape[ctx.tp] * _prod_axes(ctx) == 1:
        out = _sorted_dispatch_local(
            h.reshape(B * S, D), probs.reshape(B * S, e.n_experts),
            p["experts"], cfg, cap)
        out = out.reshape(B, S, D)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = ctx.mesh
        dp = ctx.dp
        ep_axes = ("data",)            # expert-parallel axis (a2a)
        n_ep = mesh.shape["data"]
        assert e.n_experts % n_ep == 0, (
            f"sorted MoE: {e.n_experts} experts must divide axis 'data' ({n_ep})")
        # per-shard capacity: local tokens only
        w_specs = {k: P(ep_axes, None, "model") if k in ("w_gate", "w_up")
                   else P(ep_axes, "model", None) for k in p["experts"]}

        def body(hl, pl, experts):
            N = hl.shape[0] * hl.shape[1]
            # per-shard capacity: proportional to LOCAL tokens
            capl = max(1, int(N * e.capacity_factor * e.top_k / e.n_experts))
            out = _sorted_dispatch_local(
                hl.reshape(N, D), pl.reshape(N, e.n_experts), experts, cfg,
                capl, ep_axes=ep_axes, tp_axis="model", n_ep=n_ep)
            return out.reshape(hl.shape)

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, None, None), w_specs),
            out_specs=P(dp, None, None), check_rep=False,
        )(h, probs.astype(jnp.float32), p["experts"])
    if e.shared_expert:
        out = out + _ffn(p["shared"], cfg, h).astype(out.dtype)
    return sc.act(x + out.astype(x.dtype), "dp", "sp", None)


def _prod_axes(ctx) -> int:
    n = 1
    for a in ctx.dp:
        n *= ctx.mesh.shape[a]
    return n


def moe_decode(p, cfg: ModelConfig, x):
    """One-token MoE.  Tokens are routed independently (per-token capacity
    = top_k; no cross-batch competition) so the batch dim stays dp-sharded —
    flattening the batch into one token group would force a replicated
    dispatch (all tokens on every data row)."""
    return moe_forward(p, cfg, x)
