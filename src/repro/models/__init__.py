from .lm import build_model  # noqa: F401
