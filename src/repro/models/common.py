"""Shared model utilities: init, norms, rope, activations, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .. import sharding_ctx as sc


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


class KeyGen:
    """Deterministic per-path key derivation (stable across processes —
    crc32, not the salted builtin hash)."""

    def __init__(self, key):
        self.key = key

    def __call__(self, *path) -> jax.Array:
        import zlib
        k = self.key
        for p in path:
            k = jax.random.fold_in(k, zlib.crc32(str(p).encode()) % (2 ** 31))
        return k


def rmsnorm(x, w, eps: float = 1e-5, impl: str | None = None):
    return ops.rmsnorm(x, w, eps=eps, impl=impl)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding.  x: (..., S, H, D) or (..., H, D) with positions
    broadcastable to x.shape[:-2]'s sequence dim."""
    d = x.shape[-1]
    d2 = d // 2
    freq = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    angles = positions[..., None].astype(jnp.float32) * freq   # (..., S, d2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, d2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * d2 < d:  # odd head_dim tail passes through
        rot = jnp.concatenate([rot, x[..., 2 * d2:]], axis=-1)
    return rot.astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def softmax_cross_entropy(logits, labels, mask=None):
    """logits (..., V) fp32-stable CE; labels int; mask 0/1 per position."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_lm_loss(x, head, labels, mask=None, chunk: int = 512):
    """LM cross-entropy without materialising (B, S, V) logits.

    x: (B, S, D) final hidden states; head: (D, V); labels: (B, S).
    Sequence is processed in chunks (lax.map), computing per-chunk logits,
    logsumexp and label log-prob; peak logits memory = (B, chunk, V).
    Chunk logits are pinned to (dp, None, tp) via the active sharding
    context so the head matmul never becomes a partial-sum all-reduce of
    replicated logits."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    nb = -(-S // chunk)
    pad = nb * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None \
            else jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    xb = x.reshape(B, nb, chunk, D).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, nb, chunk).transpose(1, 0, 2)
    mb = mask.reshape(B, nb, chunk).transpose(1, 0, 2).astype(jnp.float32)

    # Hoist ONE compute-dtype copy of the head out of the chunk loop: under
    # FSDP this is gathered once per step instead of once per chunk (and in
    # bf16, not f32) — measured 5.1TB -> 0.7TB wire on qwen train_4k tp1
    # (EXPERIMENTS.md §Perf iteration 3).
    head_c = head.astype(x.dtype)

    @jax.checkpoint  # recompute chunk logits in the bwd; never stash (B,chunk,V)
    def chunk_loss(xc, lc, mc):
        xc = sc.act(xc, "dp", None, None)
        logits = sc.act((xc @ head_c).astype(jnp.float32), "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return ((lse - ll) * mc).sum(), mc.sum()

    # Python-unrolled chunk loop (nb is small): XLA accumulates the head
    # gradient locally across chunks and syncs ONCE, instead of one
    # all-reduce per lax.map iteration.
    nll = 0.0
    cnt = 0.0
    for i in range(nb):
        a, b = chunk_loss(xb[i], lb[i], mb[i])
        nll += a
        cnt += b
    return nll / jnp.maximum(cnt, 1.0)
