"""Model assembly: decoder-only LM and encoder-decoder, over block patterns.

Structure: layers are grouped into *periods* (one cycle of
``cfg.block_pattern``); parameters of each pattern position are stacked over
periods and the stack is traversed with ``jax.lax.scan`` (O(1) HLO in depth)
with optional rematerialisation — both essential for compiling 60+-layer
configs AOT on 512 partitions.

Public API (all pure functions over plain-dict pytrees):
    m = build_model(cfg)
    params = m.init(rng)
    loss, metrics = m.loss_fn(params, batch)
    logits, cache = m.prefill(params, batch)          # serving: prompt pass
    logits, cache = m.decode_step(params, cache, tokens)
    cache = m.init_cache(batch, capacity, dtype)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import KeyGen, chunked_lm_loss, dense_init, dtype_of, rmsnorm, rope
from . import blocks
from .. import sharding_ctx as sc


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save only period boundaries


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    forward: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


# ===========================================================================
# parameter init
# ===========================================================================
def _init_period(kg: KeyGen, cfg: ModelConfig, tag: str, with_cross: bool):
    period = {}
    for i, (mixer, mlp) in enumerate(cfg.block_pattern):
        pos = {}
        if mixer == "attn":
            pos["mixer"] = blocks.init_attn(kg, cfg, f"{tag}.p{i}.attn")
        else:
            pos["mixer"] = blocks.init_mamba(kg, cfg, f"{tag}.p{i}.mamba")
        if with_cross:
            pos["cross"] = blocks.init_attn(kg, cfg, f"{tag}.p{i}.cross")
        if mlp == "moe":
            pos["mlp"] = blocks.init_moe(kg, cfg, f"{tag}.p{i}.moe")
        else:
            pos["mlp"] = blocks.init_mlp(kg, cfg, f"{tag}.p{i}.mlp")
        period[f"pos{i}"] = pos
    return period


def _stack_periods(init_one: Callable, n: int):
    """Initialise n periods and stack leaves along axis 0."""
    trees = [init_one(j) for j in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_params(cfg: ModelConfig, rng) -> dict:
    kg = KeyGen(rng)
    dt = dtype_of(cfg.param_dtype)
    d, vp = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": dense_init(kg("embed"), (vp, d), dt, scale=0.02),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kg("head"), (d, vp), dt)
    params["layers"] = _stack_periods(
        lambda j: _init_period(KeyGen(kg("layers", j)), cfg, f"l{j}",
                               with_cross=cfg.encdec),
        cfg.n_periods)
    if cfg.encdec:
        assert cfg.enc_layers % len(cfg.block_pattern) == 0
        n_enc = cfg.enc_layers // len(cfg.block_pattern)
        params["enc_layers"] = _stack_periods(
            lambda j: _init_period(KeyGen(kg("enc_layers", j)), cfg, f"e{j}",
                                   with_cross=False),
            n_enc)
        params["enc_norm"] = jnp.ones((d,), jnp.float32)
    return params


# ===========================================================================
# forward passes
# ===========================================================================
def _apply_period(cfg: ModelConfig, period_params, x, positions, *,
                  causal: bool, enc_out=None, impl=None):
    for i, (mixer, mlp) in enumerate(cfg.block_pattern):
        pp = period_params[f"pos{i}"]
        if mixer == "attn":
            x = blocks.attn_forward(pp["mixer"], cfg, x, positions,
                                    causal=causal, impl=impl)
        else:
            x = blocks.mamba_forward(pp["mixer"], cfg, x, impl=impl)
        if enc_out is not None:
            kv = blocks.cross_kv(pp["cross"], cfg, enc_out)
            x = blocks.cross_attn_forward(pp["cross"], cfg, x, kv, impl=impl)
        if mlp == "moe":
            x = blocks.moe_forward(pp["mlp"], cfg, x)
        else:
            x = blocks.mlp_forward(pp["mlp"], cfg, x)
    return x


def _run_stack(cfg: ModelConfig, stacked, x, positions, *, causal: bool,
               enc_out=None, impl=None, remat: str | None = None):
    def body(h, period_params):
        h = _apply_period(cfg, period_params, h, positions,
                          causal=causal, enc_out=enc_out, impl=impl)
        return h, None

    body = _remat(body, remat if remat is not None else cfg.remat)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _embed_inputs(cfg: ModelConfig, params, batch, compute_dt):
    """Token embeddings (+ optional multimodal prefix)."""
    tok = batch["tokens"]
    x = sc.act(jnp.take(params["embed"], tok, axis=0).astype(compute_dt),
               "dp", "sp", None)
    n_prefix = 0
    if cfg.frontend == "vit_stub" and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(compute_dt)
        x = jnp.concatenate([pre, x], axis=1)
        n_prefix = pre.shape[1]
    return x, n_prefix


def forward(cfg: ModelConfig, params, batch, *, impl=None, last_only=False,
            remat: str | None = None):
    """Full-sequence forward.  Returns hidden states (B, S, D) (post-norm)
    and the prefix length that was prepended."""
    compute_dt = dtype_of(cfg.compute_dtype)
    enc_out = None
    if cfg.encdec:
        frames = sc.act(batch["frames"].astype(compute_dt), "dp", "sp", None)
        pos_e = jnp.arange(frames.shape[1])
        enc = _run_stack(cfg, params["enc_layers"], frames, pos_e,
                         causal=False, impl=impl, remat=remat)
        enc_out = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)
    x, n_prefix = _embed_inputs(cfg, params, batch, compute_dt)
    positions = jnp.arange(x.shape[1])
    x = _run_stack(cfg, params["layers"], x, positions, causal=True,
                   enc_out=enc_out, impl=impl, remat=remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, n_prefix


def _head(cfg: ModelConfig, params):
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


def loss_fn(cfg: ModelConfig, params, batch, *, impl=None):
    x, n_prefix = forward(cfg, params, batch, impl=impl)
    if n_prefix:
        x = x[:, n_prefix:]
    labels = batch["labels"]
    mask = batch.get("mask")
    loss = chunked_lm_loss(x, _head(cfg, params), labels, mask)
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, batch, *, impl=None, last_only=True):
    x, n_prefix = forward(cfg, params, batch, impl=impl, remat="none")
    h = x[:, -1:] if last_only else x
    return h @ _head(cfg, params).astype(x.dtype)


# ===========================================================================
# serving: prefill + decode
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16,
               enc_len: int | None = None):
    cap = blocks.attn_cache_capacity(cfg, capacity)

    def one_period(_):
        period = {}
        for i, (mixer, _) in enumerate(cfg.block_pattern):
            if mixer == "attn":
                c = blocks.init_attn_cache(cfg, batch, cap, dtype)
            else:
                c = blocks.init_mamba_cache(cfg, batch, dtype)
            if cfg.encdec:
                a = cfg.attn
                se = enc_len or cfg.num_prefix
                c = {"self": c,
                     "cross_k": jnp.zeros((batch, se, a.n_kv_heads, a.head_dim), dtype),
                     "cross_v": jnp.zeros((batch, se, a.n_kv_heads, a.head_dim), dtype)}
            period[f"pos{i}"] = c
        return period

    caches = _stack_periods(one_period, cfg.n_periods)
    return {"pos": jnp.zeros((), jnp.int32), "layers": caches}


def slice_periods(stacked, lo: int, hi: int):
    """Periods [lo, hi) of a stacked-period pytree (params or caches).

    The per-stage cache-plumbing primitive: a pipeline stage that owns a
    contiguous run of periods slices its parameters *and* its KV/SSM
    cache out of the stacked representation with the same arithmetic, so
    `prefill_blocks`/`decode_blocks` run unchanged over the sub-stack —
    the staged computation is the same scan body the whole-model path
    compiles, just over fewer periods."""
    return jax.tree.map(lambda leaf: leaf[lo:hi], stacked)


def prefill_blocks(cfg: ModelConfig, stacked_params, x, positions, *,
                   cap: int, enc_out=None, impl=None):
    """Prompt pass over a (sub-)stack of periods: scan the prefill body
    (attention/mamba with cache construction) over ``stacked_params``.
    Returns (hidden, stacked per-period caches).  The whole-model
    `prefill` is embed -> this over ``params["layers"]`` -> norm/head; a
    pipeline block stage is this over `slice_periods` of the stack."""
    B, S, _ = x.shape

    def body(h, period_params):
        period_cache = {}
        for i, (mixer, mlp) in enumerate(cfg.block_pattern):
            pp = period_params[f"pos{i}"]
            if mixer == "attn":
                h2 = rmsnorm(h, pp["mixer"]["norm"], cfg.norm_eps)
                q, k, v = blocks._qkv(pp["mixer"], cfg, h2, positions)
                from ..kernels import ops
                o = ops.attention(q, k, v, causal=True, window=cfg.attn.window,
                                  impl=impl)
                h = h + o.reshape(B, S, -1) @ pp["mixer"]["wo"].astype(h.dtype)
                # ring-layout: position p lands in slot p % cap
                if S >= cap:
                    shift = (S - cap) % cap
                    c = {"k": jnp.roll(k[:, -cap:], shift, axis=1),
                         "v": jnp.roll(v[:, -cap:], shift, axis=1)}
                else:
                    pad = ((0, 0), (0, cap - S), (0, 0), (0, 0))
                    c = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
                c = {"k": sc.act(c["k"], "dp", None, "tp", None),
                     "v": sc.act(c["v"], "dp", None, "tp", None)}
            else:
                m = cfg.mamba
                h2 = rmsnorm(h, pp["mixer"]["norm"], cfg.norm_eps)
                x_in, z, bb, cc, dt = blocks._mamba_proj(pp["mixer"], cfg, h2)
                w = pp["mixer"]["conv_w"].astype(x_in.dtype)
                conv = jnp.zeros_like(x_in)
                for kk in range(m.d_conv):
                    sh = m.d_conv - 1 - kk
                    sl = x_in if sh == 0 else jnp.pad(
                        x_in, ((0, 0), (sh, 0), (0, 0)))[:, :S]
                    conv = conv + sl * w[kk]
                H = m.n_ssm_heads(cfg.d_model)
                xh = jax.nn.silu(conv).reshape(B, S, H, m.head_dim)
                a = -jnp.exp(pp["mixer"]["a_log"])
                from ..kernels import ops
                y, ssm_state = ops.ssd(xh, dt, a, bb, cc, impl=impl)
                y = y + xh * pp["mixer"]["d_skip"][None, None, :, None].astype(xh.dtype)
                y = y.reshape(B, S, -1) * jax.nn.silu(z)
                y = rmsnorm(y, pp["mixer"]["gate_norm"], cfg.norm_eps)
                h = h + y @ pp["mixer"]["w_out"].astype(h.dtype)
                c = {"conv": x_in[:, S - (m.d_conv - 1):].astype(h.dtype),
                     "ssm": sc.act(ssm_state, "dp", "tp", None, None)}
            if cfg.encdec:
                ck, cv = blocks.cross_kv(pp["cross"], cfg, enc_out)
                h = blocks.cross_attn_forward(pp["cross"], cfg, h, (ck, cv),
                                              impl=impl)
                c = {"self": c, "cross_k": ck.astype(h.dtype),
                     "cross_v": cv.astype(h.dtype)}
            if mlp == "moe":
                h = blocks.moe_forward(pp["mlp"], cfg, h)
            else:
                h = blocks.mlp_forward(pp["mlp"], cfg, h)
            period_cache[f"pos{i}"] = c
        return h, period_cache

    return jax.lax.scan(body, x, stacked_params)


def prefill(cfg: ModelConfig, params, batch, *, capacity: int | None = None,
            impl=None):
    """Prompt pass: returns last-token logits + a decode-ready cache.

    ``capacity``: total cache length to allocate (prompt + tokens still to
    be generated); defaults to the prompt length (no headroom).  SWA archs
    cap it at the attention window (ring buffer)."""
    compute_dt = dtype_of(cfg.compute_dtype)
    enc_out = None
    if cfg.encdec:
        frames = sc.act(batch["frames"].astype(compute_dt), "dp", "sp", None)
        pos_e = jnp.arange(frames.shape[1])
        enc = _run_stack(cfg, params["enc_layers"], frames, pos_e,
                         causal=False, impl=impl, remat="none")
        enc_out = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)
    x, n_prefix = _embed_inputs(cfg, params, batch, compute_dt)
    S = x.shape[1]
    positions = jnp.arange(S)
    cap = blocks.attn_cache_capacity(cfg, capacity or S)
    x, caches = prefill_blocks(cfg, params["layers"], x, positions, cap=cap,
                               enc_out=enc_out, impl=impl)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1:] @ _head(cfg, params).astype(x.dtype)
    return logits, {"pos": jnp.asarray(S, jnp.int32), "layers": caches}


def decode_blocks(cfg: ModelConfig, stacked_params, stacked_cache, x, pos, *,
                  impl=None):
    """One decode step over a (sub-)stack of periods: scan the decode body
    over (params, cache) period pairs.  Returns (hidden, new caches).
    The whole-model `decode_step` is embed -> this -> norm/head; a
    pipeline block stage runs it over its resident cache slice.

    ``impl`` threads straight to `kernels.ops` dispatch: every impl
    except ``"ref"`` runs attention blocks through the fused decode step
    (`kernels.fused_decode.attn_decode_step` — one rmsnorm+QKV+rope+
    attention+residual call per block instead of the op-by-op chain);
    ``"ref"`` keeps the historical body, the bitwise oracle for parity
    tests.  None resolves via `REPRO_KERNEL_IMPL` / platform default.

    **Donation-safe cache signature**: the returned cache pytree matches
    ``stacked_cache`` leaf for leaf — same structure, shapes, and dtypes
    (cache writes `.astype` back to the stored dtype; the SSM state stays
    float32) — so an executor compiling this step with the cache donated
    (``donate_argnums``) aliases EVERY leaf onto the resident buffers:
    zero new cache allocations per token.  `decode_cache_structs` is the
    checkable form of this contract."""
    def body(h, xs):
        period_params, period_cache = xs
        new_cache = {}
        for i, (mixer, mlp) in enumerate(cfg.block_pattern):
            pp = period_params[f"pos{i}"]
            pc = period_cache[f"pos{i}"]
            self_c = pc["self"] if cfg.encdec else pc
            if mixer == "attn":
                h, c = blocks.attn_decode(pp["mixer"], cfg, h, self_c, pos,
                                          impl=impl)
            else:
                h, c = blocks.mamba_decode(pp["mixer"], cfg, h, self_c,
                                           impl=impl)
            if cfg.encdec:
                h = blocks.cross_attn_decode(
                    pp["cross"], cfg, h, (pc["cross_k"], pc["cross_v"]),
                    impl=impl)
                c = {"self": c, "cross_k": pc["cross_k"],
                     "cross_v": pc["cross_v"]}
            if mlp == "moe":
                h = blocks.moe_decode(pp["mlp"], cfg, h)
            else:
                h = blocks.mlp_forward(pp["mlp"], cfg, h)
            new_cache[f"pos{i}"] = c
        return h, new_cache

    return jax.lax.scan(body, x, (stacked_params, stacked_cache))


def decode_cache_structs(cfg: ModelConfig, stacked_params, batch: int,
                         prompt: int, cap: int):
    """(cache-in, cache-out) avals of one `decode_blocks` step over a
    (sub-)stack — the donation contract as data: the two pytrees must be
    identical leaf for leaf (structure, shape, dtype) or a donated decode
    step silently falls back to allocating the mismatched leaves.
    Executors precompile against these structs; tests assert equality."""
    dt = dtype_of(cfg.compute_dtype)
    d = cfg.d_model
    x = jax.ShapeDtypeStruct((batch, prompt, d), dt)
    _, cache_in = jax.eval_shape(
        lambda p, xx: prefill_blocks(cfg, p, xx, jnp.arange(prompt), cap=cap),
        stacked_params, x)
    _, cache_out = jax.eval_shape(
        lambda p, c, xx, pp: decode_blocks(cfg, p, c, xx, pp),
        stacked_params, cache_in,
        jax.ShapeDtypeStruct((batch, 1, d), dt),
        jax.ShapeDtypeStruct((), jnp.int32))
    return cache_in, cache_out


def decode_step(cfg: ModelConfig, params, cache, tokens, *, impl=None):
    """One token for every sequence in the batch.  tokens: (B, 1) int32.

    Donation-safe like `decode_blocks`: the returned cache (including the
    ``pos`` scalar, which aliases onto ``pos + 1``) matches the input
    cache aval for aval, so single-device servers may donate it too."""
    compute_dt = dtype_of(cfg.compute_dtype)
    x = sc.act(jnp.take(params["embed"], tokens, axis=0).astype(compute_dt),
               "dp", None, None)
    pos = cache["pos"]
    x, new_caches = decode_blocks(cfg, params["layers"], cache["layers"], x,
                                  pos, impl=impl)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ _head(cfg, params).astype(x.dtype)
    return logits, {"pos": pos + 1, "layers": new_caches}


# ===========================================================================
def build_model(cfg: ModelConfig, impl: str | None = None) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        loss_fn=functools.partial(loss_fn, cfg, impl=impl),
        forward=functools.partial(logits_fn, cfg, impl=impl),
        prefill=functools.partial(prefill, cfg, impl=impl),
        decode_step=functools.partial(decode_step, cfg, impl=impl),
        init_cache=functools.partial(init_cache, cfg),
    )
