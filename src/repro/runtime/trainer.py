"""Fault-tolerant training loop.

Layers (bottom-up): data pipeline -> jitted train step (launch.steps) ->
checkpointing (async, atomic) -> failure handling.  ``train_loop`` runs
one incarnation of the job; ``run_resilient`` is the job-controller
contract: restart incarnations from the last committed checkpoint until
the step budget is met (exactly what a pod-scale controller does after a
node failure — here in-process so it is testable in CI).

Determinism contract: data batch ``i`` is a pure function of (seed, i), so
a restart replays the exact token stream from the restored step; training
curves across failures are bitwise-reproducible on the same topology.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from .. import sharding_ctx as sctx
from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs.base import ModelConfig, ShapeCfg
from ..data import DataState, make_pipeline
from ..launch import sharding as shd
from ..launch.steps import abstract_params, abstract_opt_state, make_train_step
from ..models import build_model
from .failures import FailureInjector
from .straggler import StragglerMonitor


def local_mesh(tp: int = 1):
    """Mesh over this process's devices: ("data", "model")."""
    n = len(jax.devices())
    assert n % tp == 0, f"{n} devices not divisible by tp={tp}"
    return jax.make_mesh((n // tp, tp), ("data", "model"))


@dataclass
class TrainLoopConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    grad_accum: int = 1
    lr: float = 3e-4
    warmup: int = 50
    seed: int = 0
    data_kind: str = "bigram"
    ckpt_dir: str | None = None
    ckpt_interval: int = 50
    keep: int = 3
    log_interval: int = 10
    restore: bool = True
    tp: int = 1
    fsdp: bool = False
    failures: FailureInjector | None = None
    straggler: StragglerMonitor | None = None
    on_metrics: Callable[[dict], None] | None = None
    metrics_path: str | None = None


@dataclass
class TrainSummary:
    steps_run: int
    final_step: int
    losses: dict[int, float] = field(default_factory=dict)
    straggler_events: int = 0
    restored_from: int | None = None
    checkpoints: list[int] = field(default_factory=list)

    @property
    def final_loss(self) -> float:
        return self.losses[max(self.losses)] if self.losses else float("nan")


def _writer(path: str | None):
    if path is None:
        return lambda rec: None
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    fh = p.open("a")

    def write(rec: dict):
        fh.write(json.dumps(rec) + "\n")
        fh.flush()
    return write


def train_loop(cfg: ModelConfig, loop: TrainLoopConfig, *,
               mesh=None) -> TrainSummary:
    """One incarnation: restore -> step until loop.steps or failure."""
    mesh = mesh if mesh is not None else local_mesh(loop.tp)
    shape = ShapeCfg("custom", loop.seq_len, loop.global_batch, "train")
    policy = shd.ShardingPolicy(fsdp=loop.fsdp, tp=loop.tp > 1)
    ctx = sctx.from_mesh(mesh)

    model, opt, step_fn = make_train_step(
        cfg, lr=loop.lr, warmup=loop.warmup, total_steps=loop.steps,
        grad_accum=loop.grad_accum)
    params_s = abstract_params(model)
    opt_s = abstract_opt_state(opt, params_s)
    param_sh = shd.tree_shardings(params_s, mesh, cfg, policy)
    opt_sh = shd.tree_shardings(opt_s, mesh, cfg, policy)

    pipe = make_pipeline(loop.data_kind, cfg, shape, seed=loop.seed,
                         accum=loop.grad_accum)
    data_state = pipe.init_state()

    start_step = 0
    restored_from = None
    if loop.restore and loop.ckpt_dir and latest_step(loop.ckpt_dir) is not None:
        like = {"params": params_s, "opt_state": opt_s,
                "step": jax.ShapeDtypeStruct((), np.int64),
                "data_step": jax.ShapeDtypeStruct((), np.int64)}
        tree, _meta = restore_checkpoint(loop.ckpt_dir, like)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree["params"], param_sh)
        opt_state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree["opt_state"], opt_sh)
        start_step = int(tree["step"])
        data_state = DataState(step=int(tree["data_step"]), seed=loop.seed)
        restored_from = start_step
    else:
        with mesh, sctx.activate(ctx):
            params = jax.jit(model.init,
                             out_shardings=param_sh)(jax.random.PRNGKey(loop.seed))
            opt_state = jax.jit(opt.init, out_shardings=opt_sh)(params)

    batch_sh = None
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    write = _writer(loop.metrics_path)
    summary = TrainSummary(steps_run=0, final_step=start_step,
                           restored_from=restored_from)
    ckpt = AsyncCheckpointer(loop.ckpt_dir, keep=loop.keep) \
        if loop.ckpt_dir else None

    def save(step_i, params, opt_state, data_state):
        if ckpt is None:
            return
        ckpt.save(step_i, {
            "params": params, "opt_state": opt_state,
            "step": np.int64(step_i), "data_step": np.int64(data_state.step),
        }, metadata={"cfg": cfg.name})
        summary.checkpoints.append(step_i)

    try:
        if loop.straggler is not None:
            loop.straggler.new_incarnation()
        step_arr = np.int32(start_step)
        for i in range(start_step, loop.steps):
            batch = pipe.host_batch(data_state)
            if batch_sh is None:
                specs = shd.batch_specs(mesh, batch, accum=True)
                batch_sh = shd.named(mesh, specs)
            batch = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                 batch, batch_sh)
            t0 = time.perf_counter()
            if loop.failures is not None:
                loop.failures.maybe_fail(i)   # crash raises; stall is timed
            with mesh, sctx.activate(ctx):
                params, opt_state, metrics = jitted(
                    params, opt_state, step_arr, batch)
            loss = float(metrics["loss"])            # blocks = step barrier
            dt = time.perf_counter() - t0
            if loop.straggler is not None:
                loop.straggler.observe(i, dt)
            data_state = data_state.advance()
            step_arr = np.int32(i + 1)
            summary.steps_run += 1
            summary.final_step = i + 1
            if i % loop.log_interval == 0 or i == loop.steps - 1:
                summary.losses[i] = loss
                rec = {"step": i, "loss": loss, "sec": round(dt, 4)}
                write(rec)
                if loop.on_metrics is not None:
                    loop.on_metrics(rec)
            if loop.ckpt_interval and (i + 1) % loop.ckpt_interval == 0:
                save(i + 1, params, opt_state, data_state)
        if loop.ckpt_interval and loop.steps % loop.ckpt_interval != 0:
            save(loop.steps, params, opt_state, data_state)
    finally:
        if ckpt is not None:
            ckpt.close()
        if loop.straggler is not None:
            summary.straggler_events = len(loop.straggler.events)
    return summary


def run_resilient(cfg: ModelConfig, loop: TrainLoopConfig, *,
                  max_restarts: int = 3, mesh=None) -> dict:
    """The job-controller contract: restart from the last committed
    checkpoint on (simulated) node failure, up to ``max_restarts``."""
    from .failures import SimulatedNodeFailure

    assert loop.ckpt_dir, "resilient training requires a checkpoint dir"
    incarnations: list[TrainSummary] = []
    restarts = 0
    while True:
        try:
            s = train_loop(cfg, loop, mesh=mesh)
            incarnations.append(s)
            break
        except SimulatedNodeFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            # next incarnation restores from the last committed step
            continue
    total_steps = sum(s.steps_run for s in incarnations)
    return {
        "restarts": restarts,
        "incarnations": len(incarnations),
        "total_steps_run": total_steps,
        "final_step": incarnations[-1].final_step,
        "final_loss": incarnations[-1].final_loss,
        "losses": {k: v for s in incarnations for k, v in s.losses.items()},
        "summaries": incarnations,
    }
