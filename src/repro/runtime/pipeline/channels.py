"""Bounded, double-buffered FIFO channels with backpressure.

The KPN simulator (`core/simulate.py`) uses unbounded FIFOs — fine for
functional validation, wrong for execution: real inter-stage buffers hold a
couple of rate-blocks (double buffering: the consumer drains block ``i``
while the producer fills ``i+1``), and a full buffer *stalls the producer*
(backpressure).  The streaming executor uses these channels, so a plan
whose stage rates are mismatched shows the stall where it would really
happen instead of growing a queue without bound.

Tokens are timestamped with their *visibility* time (producer firing time +
implementation latency); capacity is counted in rate-blocks of the
consumer's port rate.  Stall/occupancy counters feed the measurement layer.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class FifoStats:
    pushes: int = 0
    pops: int = 0
    producer_stalls: int = 0      # firings deferred because the fifo was full
    high_water: int = 0           # max tokens resident


class Fifo:
    """Bounded FIFO of (token, ready_time) with block-granular accounting.

    ``block`` is the consumer's port rate (tokens consumed per firing);
    ``capacity_blocks`` defaults to 2 — double buffering.
    """

    def __init__(self, block: int = 1, capacity_blocks: int = 2,
                 min_capacity: int = 0):
        """``min_capacity`` floors the token capacity — rate-changing
        channels need room for the *producer's* burst (out_rate tokens per
        firing), which can exceed consumer-block sizing."""
        if block < 1 or capacity_blocks < 1:
            raise ValueError(f"bad fifo shape: block={block} "
                             f"capacity_blocks={capacity_blocks}")
        self.block = block
        self.capacity = max(block * capacity_blocks, min_capacity)
        self._q: deque = deque()
        self.stats = FifoStats()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def free(self) -> int:
        return self.capacity - len(self._q)

    def can_push(self, n: int) -> bool:
        return self.free >= n

    def push(self, tokens, ready_time: float) -> None:
        if not self.can_push(len(tokens)):
            raise OverflowError(
                f"fifo overflow: pushing {len(tokens)} into {self.free} free "
                f"slots — producer fired without space (backpressure bug)")
        for t in tokens:
            self._q.append((t, ready_time))
        self.stats.pushes += len(tokens)
        self.stats.high_water = max(self.stats.high_water, len(self._q))

    def can_pop(self, n: int | None = None) -> bool:
        return len(self._q) >= (self.block if n is None else n)

    def ready_time(self, n: int | None = None) -> float | None:
        """Visibility time of the n-th oldest token (None if not present)."""
        n = self.block if n is None else n
        if len(self._q) < n:
            return None
        return max(self._q[i][1] for i in range(n))

    def pop(self, n: int | None = None) -> list:
        n = self.block if n is None else n
        if len(self._q) < n:
            raise IndexError(f"fifo underflow: want {n}, have {len(self._q)}")
        self.stats.pops += n
        return [self._q.popleft()[0] for _ in range(n)]

    def note_stall(self) -> None:
        self.stats.producer_stalls += 1


@dataclass
class ChannelSet:
    """All fifos of one materialised graph, keyed by Channel.key()."""
    fifos: dict[tuple, Fifo] = field(default_factory=dict)

    @classmethod
    def for_graph(cls, stg, capacity_blocks: int = 2) -> "ChannelSet":
        cs = cls()
        for ch in stg.channels:
            block = stg.nodes[ch.dst].in_rates[ch.dst_port]
            out_rate = stg.nodes[ch.src].out_rates[ch.src_port]
            cs.fifos[ch.key()] = Fifo(
                block=max(1, block), capacity_blocks=capacity_blocks,
                # multirate: hold capacity_blocks bursts of the larger side
                min_capacity=max(1, out_rate) * capacity_blocks)
        return cs

    def __getitem__(self, key: tuple) -> Fifo:
        return self.fifos[key]

    def total_stalls(self) -> int:
        return sum(f.stats.producer_stalls for f in self.fifos.values())

    def occupancy(self) -> dict[tuple, int]:
        return {k: f.stats.high_water for k, f in self.fifos.items()}
