"""Bounded FIFO channels: host queue + on-device staging, with backpressure.

The KPN simulator (`core/simulate.py`) uses unbounded FIFOs — fine for
functional validation, wrong for execution: real inter-stage buffers hold a
couple of rate-blocks (double buffering: the consumer drains block ``i``
while the producer fills ``i+1``), and a full buffer *stalls the producer*
(backpressure).  The streaming executor uses these channels, so a plan
whose stage rates are mismatched shows the stall where it would really
happen instead of growing a queue without bound.

Two-level buffering (the async jax path):

  * **host level** — the bounded queue itself.  Under asynchronous
    dispatch a slot is occupied from the moment the producer's op is
    *dispatched* until the consumer's op that ate the token *completes* on
    device: ``reserve()`` claims a slot at producer dispatch,
    ``push_reserved()`` fills it, ``pop_hold()`` hands the token to the
    consumer while keeping the slot occupied, and ``release()`` frees it at
    consumer retirement.  Capacity therefore bounds total in-flight work
    (queued + executing) per edge — device memory cannot grow without
    bound no matter how far ahead the host runs.
  * **device level** — an optional ``prefetch_fn`` stages the first
    ``prefetch_depth`` queued tokens onto the consumer's device slice as
    soon as they are enqueued (an async ``device_put``), so the transfer
    overlaps the consumer's current microbatch instead of serialising with
    its next one.

Donation discipline: prefetch *reads* queued buffers, so nothing that
crosses a FIFO may ever be donated — the executors donate only buffers
that stay resident inside one stage (KV-cache slices, the grad
accumulator), never inter-stage activations, and their staging functions
assert the invariant (a deleted buffer in a queue raises a descriptive
error instead of XLA's use-after-free).  Note also that ``device_put`` to
the producer's own device is an *alias*, not a copy: a staged token can
share its buffer with the producer's output, which is exactly why queue
traffic must stay donation-free.

The synchronous interpreter path uses the plain ``push``/``pop`` subset,
where dispatch and completion coincide and the two levels collapse to the
old double-buffered FIFO semantics.

Tokens are timestamped with their *visibility* time (producer firing time +
implementation latency); capacity is counted in rate-blocks of the
consumer's port rate.  Stall/occupancy/prefetch counters feed the
measurement layer.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from math import gcd


def check_not_donated(leaf, context: str) -> None:
    """The staging-side donation guard: raise a descriptive error if a
    queued buffer was deleted (donated) while still owned by a fifo —
    only stage-resident buffers may be donated, never queue traffic (see
    the module docstring's donation discipline)."""
    if getattr(leaf, "is_deleted", lambda: False)():
        raise RuntimeError(
            f"prefetch on {context}: queued buffer was deleted (donated) "
            f"while still in the fifo — only stage-resident buffers "
            f"(cache slices, grad accumulators) may be donated")


@dataclass
class FifoStats:
    pushes: int = 0
    pops: int = 0
    producer_stalls: int = 0      # firings deferred because the fifo was full
    high_water: int = 0           # max tokens resident in the host queue
    inflight_high_water: int = 0  # max slots occupied incl. reserved + held
    prefetches: int = 0           # tokens staged on device ahead of pop


class Fifo:
    """Bounded FIFO of (token, ready_time) with block-granular accounting.

    ``block`` is the consumer's port rate (tokens consumed per firing);
    ``capacity_blocks`` defaults to 2 — double buffering.  ``prefetch_fn``
    (token -> token), when set, is applied to at most ``prefetch_depth``
    tokens at the head of the queue ahead of their pop — the jax path uses
    it to issue the consumer-side device transfer early.
    """

    # set via `trace.Tracer.watch_fifo`: a watched fifo emits an
    # occupancy counter event on every push/pop (class-level None keeps
    # the unwatched hot path to one attribute load per operation)
    tracer = None
    label: str | None = None

    def __init__(self, block: int = 1, capacity_blocks: int = 2,
                 min_capacity: int = 0, prefetch_fn=None,
                 prefetch_depth: int = 1):
        """``min_capacity`` floors the token capacity — rate-changing
        channels need room for the *producer's* burst (out_rate tokens per
        firing), which can exceed consumer-block sizing."""
        if block < 1 or capacity_blocks < 1:
            raise ValueError(f"bad fifo shape: block={block} "
                             f"capacity_blocks={capacity_blocks}")
        self.block = block
        self.capacity = max(block * capacity_blocks, min_capacity)
        self.prefetch_fn = prefetch_fn
        self.prefetch_depth = max(0, prefetch_depth)
        self._q: deque = deque()
        self._reserved = 0        # slots claimed by dispatched producers
        self._held = 0            # slots kept by executing consumers
        self._prefetched = 0      # head tokens already staged on device
        self.stats = FifoStats()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def inflight_slots(self) -> int:
        """Slots occupied beyond the queue itself (producer-reserved +
        consumer-held) — the device-side in-flight work on this edge."""
        return self._reserved + self._held

    @property
    def free(self) -> int:
        return self.capacity - len(self._q) - self._reserved - self._held

    def can_push(self, n: int) -> bool:
        return self.free >= n

    # -- producer side ------------------------------------------------------
    def reserve(self, n: int) -> None:
        """Claim ``n`` slots at producer *dispatch* time (async path); fill
        them with ``push_reserved`` when the tokens materialise."""
        if not self.can_push(n):
            raise OverflowError(
                f"fifo overflow: reserving {n} of {self.free} free slots — "
                f"producer dispatched without space (backpressure bug)")
        self._reserved += n
        self._note_inflight()

    def push_reserved(self, tokens, ready_time: float) -> None:
        """Fill previously reserved slots (completion of an async push)."""
        if len(tokens) > self._reserved:
            raise OverflowError(
                f"push_reserved of {len(tokens)} exceeds {self._reserved} "
                f"reserved slots")
        self._reserved -= len(tokens)
        self._append(tokens, ready_time)

    def push(self, tokens, ready_time: float) -> None:
        if not self.can_push(len(tokens)):
            raise OverflowError(
                f"fifo overflow: pushing {len(tokens)} into {self.free} free "
                f"slots — producer fired without space (backpressure bug)")
        self._append(tokens, ready_time)

    def _append(self, tokens, ready_time: float) -> None:
        for t in tokens:
            self._q.append((t, ready_time))
        self.stats.pushes += len(tokens)
        self.stats.high_water = max(self.stats.high_water, len(self._q))
        if self.tracer is not None:
            self.tracer.fifo_event("push", self.label or "fifo",
                                   len(self._q))
        self._note_inflight()
        self._maybe_prefetch()

    # -- consumer side ------------------------------------------------------
    def can_pop(self, n: int | None = None) -> bool:
        return len(self._q) >= (self.block if n is None else n)

    def ready_time(self, n: int | None = None) -> float | None:
        """Visibility time of the n-th oldest token (None if not present)."""
        n = self.block if n is None else n
        if len(self._q) < n:
            return None
        return max(self._q[i][1] for i in range(n))

    def pop(self, n: int | None = None) -> list:
        n = self.block if n is None else n
        if len(self._q) < n:
            raise IndexError(f"fifo underflow: want {n}, have {len(self._q)}")
        self.stats.pops += n
        self._prefetched = max(0, self._prefetched - n)
        out = [self._q.popleft()[0] for _ in range(n)]
        if self.tracer is not None:
            self.tracer.fifo_event("pop", self.label or "fifo",
                                   len(self._q))
        self._maybe_prefetch()
        return out

    def pop_hold(self, n: int | None = None) -> list:
        """Pop tokens but keep their slots occupied until ``release`` —
        the consumer's op is dispatched but not yet complete, so the edge's
        in-flight budget still owns this work."""
        n = self.block if n is None else n
        out = self.pop(n)
        self._held += n
        self._note_inflight()
        return out

    def release(self, n: int) -> None:
        """Free slots held by ``pop_hold`` (consumer op retired)."""
        if n > self._held:
            raise ValueError(f"release of {n} exceeds {self._held} held slots")
        self._held -= n
        self._maybe_prefetch()

    def note_stall(self) -> None:
        self.stats.producer_stalls += 1

    # -- device staging ------------------------------------------------------
    def _maybe_prefetch(self) -> None:
        """Stage head tokens on device.  A raising ``prefetch_fn`` leaves
        the queue consistent: the failing token stays un-staged and
        poppable, nothing is dropped, and no slot accounting moved — the
        exception propagates to the caller, but the channel cannot leak
        capacity or wedge its consumers."""
        if self.prefetch_fn is None:
            return
        while self._prefetched < min(len(self._q), self.prefetch_depth):
            tok, t = self._q[self._prefetched]
            staged = self.prefetch_fn(tok)      # may raise: state untouched
            self._q[self._prefetched] = (staged, t)
            self._prefetched += 1
            self.stats.prefetches += 1

    def _note_inflight(self) -> None:
        occ = len(self._q) + self._reserved + self._held
        self.stats.inflight_high_water = max(
            self.stats.inflight_high_water, occ)


class StreamChannel(Fifo):
    """A Fifo carrying an *open-ended* token stream.

    Microbatch pipelines know their traffic up front (a fixed list of
    microbatches -> a fixed op schedule); serving pipelines do not — decode
    tokens keep arriving as long as any request slot is live, and the
    consumer must distinguish "empty right now" (more tokens coming; keep
    polling) from "ended" (the producer closed the stream; drain and
    stop).  The decode pipeline's head->embed feedback edge is the
    canonical user: sampled tokens stream back continuously until every
    serving slot hits EOS or its budget, then the head closes the stream.

    ``close()`` is the producer-side end-of-stream marker; pushing after
    close is a protocol error.  ``exhausted`` is the consumer-side
    termination test (closed *and* drained).
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.closed = False

    def close(self) -> None:
        self.closed = True

    @property
    def exhausted(self) -> bool:
        return self.closed and not len(self._q)

    def _append(self, tokens, ready_time: float) -> None:
        if self.closed:
            raise RuntimeError(
                f"push of {len(tokens)} token(s) after close() — the "
                f"producer declared end-of-stream")
        super()._append(tokens, ready_time)


@dataclass
class ChannelSet:
    """All fifos of one materialised graph, keyed by Channel.key()."""
    fifos: dict[tuple, Fifo] = field(default_factory=dict)

    @classmethod
    def for_graph(cls, stg, capacity_blocks: int = 2) -> "ChannelSet":
        cs = cls()
        for ch in stg.channels:
            block = max(1, stg.nodes[ch.dst].in_rates[ch.dst_port])
            out_rate = max(1, stg.nodes[ch.src].out_rates[ch.src_port])
            # multirate floors: capacity_blocks bursts of the larger side,
            # and never below the two-actor SDF liveness bound
            # block + burst - gcd(block, burst) — below it a rate-changing
            # edge wedges with the producer short of free slots and the
            # consumer short of a full block (core.verify proves this
            # statically; capacity_blocks=1 used to violate it)
            floor = block + out_rate - gcd(block, out_rate)
            cs.fifos[ch.key()] = Fifo(
                block=block, capacity_blocks=capacity_blocks,
                min_capacity=max(out_rate * capacity_blocks, floor))
        return cs

    def __getitem__(self, key: tuple) -> Fifo:
        return self.fifos[key]

    def total_stalls(self) -> int:
        return sum(f.stats.producer_stalls for f in self.fifos.values())

    def occupancy(self) -> dict[tuple, int]:
        return {k: f.stats.high_water for k, f in self.fifos.items()}
