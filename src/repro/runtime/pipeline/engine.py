"""Graph-generic executor core: one scheduler, many stage-program backends.

The streaming executors used to carry their own event loops — `jax_pipe`
had a 150-line non-blocking dispatch/retire loop and `interpreter` a
discrete-event heap — duplicating the parts that are actually
graph-generic: FIFO credit accounting, per-edge reorder buffers, per-op
completion timing, replica busy budgets, and deadlock/wedge detection.
This module owns those parts once, in two clock domains:

  * **`Engine`** (wall clock) — the asynchronous overlapped scheduler.
    A `StageProgram` per pipeline stage exposes dispatch/retire/readiness
    hooks; the engine scans programs downstream-first, hands dispatched
    ops to a worker pool (or runs them inline under ``overlap=False``),
    retires them on completion events, releases their channel credits,
    and records the completion-time streams the measurement layer reads.
    Backends: `jax_pipe.LMPipeline` (microbatch F/B over jax devices) and
    `decode.DecodePipeline` (prefill/decode serving with KV-cache
    residency and a token feedback stream).  Programs may *grow* their op
    queues while the engine runs (decode steps are scheduled as sampled
    tokens stream back), so termination is pending-or-inflight, not a
    precomputed op count.

  * **`run_event_loop`** (virtual clock) — the discrete-event driver the
    host interpreter runs on.  An `EventProgram` per materialised node
    exposes ``ready_time``/``fire``; the loop owns the heap, candidate
    re-queueing, wake-set propagation, and the firing/cycle caps.  Node
    semantics (rates, FORK/JOIN state, source streams, device busy
    clocks) stay in the backend — the loop never inspects tokens.

Both domains emit the same measurement surface: per-stage streams of
completion (or firing) times whose steady-state gap is the stage's
measured inverse throughput (`steady_inverse`).  A replicated stage's
streams merge, so the measured value reads ii/nr in either domain — one
`measure.compare` core serves every executor instead of special-casing
the two runs.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

from .channels import Fifo


def steady_inverse(samples: Iterable[float], warmup_frac: float = 0.25,
                   min_samples: int = 4) -> float:
    """Steady-state gap of one completion/firing-time stream: drop the
    pipeline-fill ramp, then average the remaining inter-event gaps.
    Raises ValueError below ``min_samples`` — callers decide their own
    degraded fallback (or skip the stage)."""
    ts = sorted(samples)
    if len(ts) < min_samples:
        raise ValueError(f"too few samples ({len(ts)} < {min_samples})")
    k = max(1, int(len(ts) * warmup_frac))
    window = ts[k:]
    if len(window) < 2 or window[-1] <= window[0]:
        raise ValueError("degenerate completion stream (no measurable gap)")
    return (window[-1] - window[0]) / (len(window) - 1)


# ===========================================================================
# wall-clock domain: asynchronous overlapped scheduler
# ===========================================================================
@dataclass
class Op:
    """One dispatched firing, in flight between dispatch and retirement.

    ``seq`` orders the op on every edge it crosses (microbatch index for
    LM pipelines, global stream index for decode); ``releases`` lists
    (fifo, n) credits the engine frees at retirement — also on *failed*
    ops, so a raising stage body cannot leak channel slots."""
    stage: int
    kind: str
    seq: int
    rep: int
    t_dispatch: float = 0.0
    releases: list = field(default_factory=list)       # (Fifo, n)
    is_firing: bool = True       # contributes to the stage's completion
    #                              stream (jax path: F ops only)


@runtime_checkable
class StageProgram(Protocol):
    """Per-stage hooks the wall-clock engine drives.

    The engine owns *when*; the program owns *what*: which op comes next
    (``peek``), whether its data/credits are available (``ready`` — claim
    nothing, count producer stalls), how to run it (``dispatch`` —
    consume inputs, reserve output credits, return a thunk safe to run on
    a worker thread), and what its completion means (``retire`` — push
    outputs via ``engine.ordered_push``, return the op's completion
    timestamp)."""

    name: str
    n_replicas: int

    def pending(self) -> int: ...
    def peek(self) -> Op | None: ...
    def ready(self, op: Op) -> bool: ...
    def dispatch(self, op: Op) -> tuple[Callable, tuple]: ...
    def retire(self, op: Op, result: Any, engine: "Engine") -> float: ...

    def describe(self) -> str:              # deadlock diagnostics
        ...


@dataclass
class EngineResult:
    """The generic half of an execution's result: per-stage timing streams
    and op bookkeeping.  Backends embed/alias these fields into their own
    result types (`LMPipelineResult`, `ServeRunResult`)."""
    stage_seconds: dict[str, float] = field(default_factory=dict)
    stage_firings: dict[str, int] = field(default_factory=dict)
    stage_done_s: dict[str, list[float]] = field(default_factory=dict)
    op_trace: list = field(default_factory=list)
    # (stage, kind, seq, replica, t_dispatch, t_done) run-relative
    max_inflight: int = 0
    wall_s: float = 0.0

    def stage_inverse_us(self, name: str) -> float:
        """Steady-state microseconds per firing of one stage (merged
        replica completion streams -> effective ii/nr).  Runs too short
        for a steady state fall back to mean in-flight latency per op —
        a degraded mode callers should not calibrate on."""
        try:
            return steady_inverse(self.stage_done_s.get(name, ())) * 1e6
        except ValueError:
            n = self.stage_firings.get(name, 0)
            return (self.stage_seconds.get(name, 0.0) / n * 1e6
                    if n else float("nan"))


class Engine:
    """Non-blocking scheduler over a list of `StageProgram`s.

    ``overlap=True`` hands dispatched ops to a thread pool and retires
    them on completion; ``overlap=False`` is the serial A/B baseline
    (dispatch, block, advance).  ``replica_queue`` caps in-flight ops per
    stage replica (1 = strict serial worker, 2 = short device queue).
    The engine owns the per-edge reorder buffers (`ordered_push`): slots
    are reserved at dispatch, so deferred pushes cannot overflow, and
    each fifo stays seq-sorted no matter which replica retires first.
    """

    def __init__(self, programs: list, *, overlap: bool = True,
                 workers: int = 8, replica_queue: int = 2):
        self.programs = list(programs)
        self.overlap = overlap
        self.workers = max(1, workers)
        self.replica_queue = max(1, replica_queue)
        self.result = EngineResult()
        self.t0 = 0.0
        self._busy = [[0] * max(1, p.n_replicas) for p in self.programs]
        self._reorder: dict[int, tuple[dict, list]] = {}
        for p in self.programs:
            self.result.stage_seconds[p.name] = 0.0
            self.result.stage_firings[p.name] = 0
            self.result.stage_done_s[p.name] = []

    def ordered_push(self, fifo: Fifo, seq: int, tok, t_done: float) -> None:
        """Stage an out-of-order completion so ``fifo`` receives tokens in
        seq order (slots were reserved at dispatch; cannot overflow)."""
        pend, nxt = self._reorder.setdefault(id(fifo), ({}, [0]))
        pend[seq] = (tok, t_done)
        while nxt[0] in pend:
            tok_i, t_i = pend.pop(nxt[0])
            fifo.push_reserved([(nxt[0], tok_i)], t_i)
            nxt[0] += 1

    def _retire(self, op: Op, result) -> None:
        prog = self.programs[op.stage]
        t_done = prog.retire(op, result, self)
        for fifo, n in op.releases:
            fifo.release(n)
        self._busy[op.stage][op.rep] -= 1
        res = self.result
        if op.is_firing:
            res.stage_done_s[prog.name].append(t_done - self.t0)
        res.stage_seconds[prog.name] += t_done - op.t_dispatch
        res.stage_firings[prog.name] += 1
        res.op_trace.append((prog.name, op.kind, op.seq, op.rep,
                             op.t_dispatch - self.t0, t_done - self.t0))

    def _abort(self, op: Op) -> None:
        """An op's body raised: free its channel credits and busy slot so
        the failure surfaces as the exception, not as a leaked-slot
        deadlock in some later run."""
        for fifo, n in op.releases:
            fifo.release(n)
        self._busy[op.stage][op.rep] -= 1

    def run(self) -> EngineResult:
        from concurrent.futures import (FIRST_COMPLETED, ThreadPoolExecutor,
                                        wait)
        self.t0 = time.perf_counter()
        inflight: dict = {}                 # future -> Op
        pool = ThreadPoolExecutor(max_workers=self.workers) \
            if self.overlap else None
        try:
            while any(p.pending() for p in self.programs) or inflight:
                progressed = False
                # downstream-first: consumers drain fifos before producers
                for s in reversed(range(len(self.programs))):
                    prog = self.programs[s]
                    op = prog.peek()
                    if op is None:
                        continue
                    if self._busy[s][op.rep] >= self.replica_queue:
                        continue
                    if not prog.ready(op):
                        continue
                    fn, args = prog.dispatch(op)
                    op.t_dispatch = time.perf_counter()
                    self._busy[s][op.rep] += 1
                    progressed = True
                    if pool is None:
                        try:
                            result = fn(*args)
                        except BaseException:
                            self._abort(op)
                            raise
                        self._retire(op, result)
                    else:
                        inflight[pool.submit(fn, *args)] = op
                        self.result.max_inflight = max(
                            self.result.max_inflight, len(inflight))
                done = [f for f in inflight if f.done()]
                if not progressed and not done and inflight:
                    done, _ = wait(list(inflight),
                                   return_when=FIRST_COMPLETED)
                for f in done:
                    op = inflight.pop(f)
                    try:
                        result = f.result()
                    except BaseException:
                        self._abort(op)
                        raise
                    self._retire(op, result)
                    progressed = True
                if not progressed:
                    state = "; ".join(p.describe() for p in self.programs)
                    raise RuntimeError(
                        f"pipeline deadlock: no program can dispatch and "
                        f"nothing is in flight — schedule/backpressure "
                        f"bug ({state})")
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        self.result.wall_s = time.perf_counter() - self.t0
        return self.result


# ===========================================================================
# virtual-clock domain: discrete-event loop (host interpreter backend)
# ===========================================================================
@runtime_checkable
class EventProgram(Protocol):
    """One materialised node driven by the virtual-clock loop.

    ``ready_time`` returns the earliest virtual time the node could fire
    (None = blocked on tokens/space; ``count_stall`` marks the heap-pop
    re-check, where a deferral is a real producer stall, not a readiness
    probe).  ``fire`` consumes/computes/produces at ``now`` and returns
    (done_time, busy_cycles, wake) — the nodes whose readiness may have
    changed."""

    name: str

    def ready_time(self, count_stall: bool = False) -> float | None: ...
    def fire(self, now: float) -> tuple[float, float, Iterable[str]]: ...


@dataclass
class EventLoopStats:
    fire_times: dict[str, list[float]] = field(default_factory=dict)
    fired: dict[str, int] = field(default_factory=dict)
    busy_cycles: dict[str, float] = field(default_factory=dict)
    cycles: float = 0.0
    total_fired: int = 0
    hit_cycle_cap: bool = False


def run_event_loop(programs: dict[str, EventProgram], *,
                   max_firings: int = 1_000_000,
                   max_cycles: float = 1e12) -> EventLoopStats:
    """Drive `EventProgram`s to quiescence under a virtual clock.

    Deterministic: among fireable nodes the earliest (t, insertion seq)
    fires.  A popped candidate is re-checked (it may have been blocked by
    an earlier firing) and either fires, re-queues at its new ready time,
    or is dropped — a later pop/firing of a waker re-queues it.
    """
    stats = EventLoopStats()
    for n in programs:
        stats.fire_times[n] = []
        stats.fired[n] = 0
        stats.busy_cycles[n] = 0.0

    seq = 0
    heap: list[tuple[float, int, str]] = []

    def push_candidate(name: str) -> None:
        nonlocal seq
        t = programs[name].ready_time()
        if t is not None:
            heapq.heappush(heap, (t, seq, name))
            seq += 1

    for n in programs:
        push_candidate(n)

    while heap and stats.total_fired < max_firings:
        now, _, name = heapq.heappop(heap)
        if now > max_cycles:
            stats.hit_cycle_cap = True
            break
        t = programs[name].ready_time(count_stall=True)
        if t is None:
            continue            # became blocked; a pop/firing requeues it
        if t > now:
            heapq.heappush(heap, (t, seq, name))
            seq += 1
            continue
        done, busy, wake = programs[name].fire(now)
        stats.fired[name] += 1
        stats.fire_times[name].append(now)
        stats.busy_cycles[name] += busy
        stats.total_fired += 1
        stats.cycles = max(stats.cycles, done)
        for c in set(wake) | {name}:
            push_candidate(c)
    return stats
