"""Graph-generic executor core: one Program protocol, two clock drivers.

The streaming executors used to carry their own event loops — and then
their own *protocols*: the wall-clock `Engine` drove a ``StageProgram``
while the virtual-clock loop drove a separate ``EventProgram``, so a
backend was written against one clock domain and stuck there.  This
module owns one **`Program`** protocol and two drivers of it:

  * A `Program` is an op stream with ``ready``/``dispatch``/``retire``
    semantics: ``peek`` exposes the next scheduled `Op`, ``ready``
    answers *when* it could run (a timestamp under the virtual clock,
    any non-None under the wall clock; ``None`` = blocked on
    tokens/credits), ``dispatch`` consumes inputs, reserves output
    credits, and returns a thunk, and ``retire`` pushes outputs and
    returns the op's completion timestamp.  The driver owns *when*; the
    program owns *what*.  Op queues may grow while a driver runs
    (decode schedules ops as sampled tokens stream back), so wall-clock
    termination is pending-or-inflight, not a precomputed op count.

  * **`Engine`** (wall clock) — the asynchronous overlapped scheduler.
    Scans programs downstream-first, hands dispatched ops to a worker
    pool (or runs them inline under ``overlap=False``), retires them on
    completion events, releases channel credits (also on failure — no
    leaked slots), and records completion-time streams.  An op body may
    return an `AsyncResult` — "dispatched to the device, not complete":
    the worker returns immediately (no per-op ``block_until_ready`` host
    sync) and the engine retires the op when its watch set reports ready
    (`jax.Array.is_ready` completion futures), so a worker dispatches
    the next op while the previous one's transfer/compute is still in
    flight.  Backends: `jax_pipe.LMPipeline` (microbatch F/B over jax
    devices) and `decode.DecodePipeline` (prefill/decode serving).

  * **`run_event_loop`** (virtual clock) — the discrete-event driver.
    Owns the heap, candidate re-queueing, wake-set propagation, and the
    firing/cycle caps; programs own rates, busy clocks, and token
    semantics.  Backends: the host interpreter's per-node programs and
    `schedule.ScheduleProgram` (schedules simulated as data).

Both drivers extend one `Driver` base — per-edge reorder buffers
(`ordered_push`), wake hooks, busy accounting — so a program written
once runs under either clock (`schedule.ScheduleProgram` is the tested
example).  Both emit the same measurement surface: per-stage streams of
completion/firing times whose steady-state gap is the stage's measured
inverse throughput (`steady_inverse`); a replicated stage's streams
merge, so the measured value reads ii/nr in either domain — one
`measure.compare` core serves every executor.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

from ..failures import PipelineFailure, ReplicaFault
from .channels import Fifo


def steady_inverse(samples: Iterable[float], warmup_frac: float = 0.25,
                   min_samples: int = 4) -> float:
    """Steady-state gap of one completion/firing-time stream: drop the
    pipeline-fill ramp, then average the remaining inter-event gaps.
    Raises ValueError below ``min_samples`` — callers decide their own
    degraded fallback (or skip the stage)."""
    ts = sorted(samples)
    if len(ts) < min_samples:
        raise ValueError(f"too few samples ({len(ts)} < {min_samples})")
    k = max(1, int(len(ts) * warmup_frac))
    window = ts[k:]
    if len(window) < 2 or window[-1] <= window[0]:
        raise ValueError("degenerate completion stream (no measurable gap)")
    return (window[-1] - window[0]) / (len(window) - 1)


# ===========================================================================
# the one protocol
# ===========================================================================
@dataclass
class Op:
    """One dispatched firing, in flight between dispatch and retirement.

    ``seq`` orders the op on every edge it crosses (microbatch index for
    LM pipelines, global stream index for decode); ``chunk`` is the
    virtual-stage index for interleaved schedules (0 for plain ones);
    ``releases`` lists (fifo, n) credits the driver frees at retirement —
    also on *failed* ops, so a raising stage body cannot leak channel
    slots."""
    stage: int
    kind: str
    seq: int
    rep: int
    chunk: int = 0
    t_dispatch: float = 0.0
    releases: list = field(default_factory=list)       # (Fifo, n)
    is_firing: bool = True       # contributes to the stage's completion
    #                              stream (jax path: F ops only)
    recover: tuple | None = None  # program-defined replay payload: what
    #                               `fail_replica` needs to re-issue this
    #                               op on a surviving replica (inputs were
    #                               consumed at dispatch; a lost op cannot
    #                               re-pop them)


@runtime_checkable
class Program(Protocol):
    """The one per-stage interface both clock domains drive.

    The driver owns *when*; the program owns *what*: which op comes next
    (``peek``), when its data/credits allow it to run (``ready`` — claim
    nothing; return the earliest feasible time under a virtual clock,
    any non-None under the wall clock, None when blocked;
    ``count_stall`` marks re-checks where a deferral is a real producer
    stall, not a readiness probe), how to run it (``dispatch`` — consume
    inputs, reserve output credits, return a thunk safe to run on a
    worker thread), and what its completion means (``retire`` — push
    outputs via ``driver.ordered_push``, return the op's completion
    timestamp).  ``describe`` is the deadlock/wedge diagnostic: it names
    the stage's schedule position — next op index and (kind, mb, chunk)
    — so a stall points at the schedule line, not just a FIFO."""

    name: str
    n_replicas: int

    def pending(self) -> int: ...
    def peek(self) -> Op | None: ...
    def ready(self, op: Op, count_stall: bool = False) -> float | None: ...
    def dispatch(self, op: Op, driver: "Driver") -> tuple[Callable, tuple]: ...
    def retire(self, op: Op, result: Any, driver: "Driver") -> float: ...
    def describe(self) -> str: ...


# the historical name for wall-clock programs; same protocol now
StageProgram = Program


class AsyncResult:
    """An op body's non-blocking return: device work was *dispatched* but
    not awaited.  ``payload`` is the tuple ``retire`` expects minus its
    trailing completion timestamp (the engine appends one when completion
    is observed); ``watch`` is a small list of duck-typed completion
    futures — objects with ``is_ready()`` / ``block_until_ready()``
    (`jax.Array` natively) whose readiness marks the op complete.  Watch
    one representative output per executable, not every pytree leaf: an
    executable's outputs materialise together, and the engine polls the
    watch set every sweep."""

    __slots__ = ("payload", "watch")

    def __init__(self, payload: tuple, watch: list):
        self.payload = payload
        # non-device values (host numpy, float0 cotangents of integer
        # inputs) are complete by construction — drop them from the watch
        self.watch = [w for w in watch if hasattr(w, "is_ready")]

    def is_ready(self) -> bool:
        return all(w.is_ready() for w in self.watch)

    def block(self) -> None:
        for w in self.watch:
            w.block_until_ready()


def describe_position(name: str, pos: int, ops, fmt: Callable) -> str:
    """The shared ``Program.describe`` diagnostic line: a stage's schedule
    position — next op index and the op itself (``fmt``-rendered) — so
    every backend's deadlock/wedge report points at the same place."""
    if pos >= len(ops):
        return f"{name}: done {pos}/{len(ops)}"
    return f"{name}: op {pos}/{len(ops)} next={fmt(ops[pos])}"


class Driver:
    """What every clock domain offers its programs: per-edge reorder
    buffers (slots are reserved at dispatch, so deferred pushes cannot
    overflow, and each fifo stays seq-sorted no matter which replica
    retires first), wake hooks (virtual domain: which programs to
    re-examine after a retirement; wall domain: a no-op — the engine
    rescans every sweep), busy accounting, and the shared tracing hook:
    a `trace.Tracer` attached here makes BOTH drivers emit the same
    typed event stream (op dispatch/retire spans, credit/starve/reorder
    waits) for the same `Program`."""

    virtual: bool = False

    def __init__(self, tracer=None):
        self._reorder: dict[int, tuple[dict, list]] = {}
        self.t0 = 0.0
        self.tracer = tracer

    def ordered_push(self, fifo: Fifo, seq: int, tok, t_done: float) -> None:
        """Stage an out-of-order completion so ``fifo`` receives tokens in
        seq order (slots were reserved at dispatch; cannot overflow)."""
        pend, nxt = self._reorder.setdefault(id(fifo), ({}, [0]))
        pend[seq] = (tok, t_done)
        while nxt[0] in pend:
            tok_i, t_i = pend.pop(nxt[0])
            fifo.push_reserved([(nxt[0], tok_i)], t_i)
            nxt[0] += 1

    def wake(self, *names: str) -> None:
        pass

    def note_busy(self, name: str, amount: float) -> None:
        pass

    def reorder_occupancy(self) -> int:
        """Tokens parked in reorder buffers across every edge — 0 at
        quiescence.  A permanently missing seq (a dead replica whose op
        was never replayed) shows up here as a stuck nonzero count, which
        is why failover re-issues lost ops under their *original*
        sequence numbers."""
        return sum(len(pend) for pend, _ in self._reorder.values())

    def wait_reason_of(self, prog) -> tuple[str, str]:
        """Classify why ``prog`` just deferred: programs leave a
        ``wait_reason = (reason, fifo)`` breadcrumb when ``ready``
        returns None; the driver refines an input-empty wait into a
        *reorder* wait when the tokens exist but sit in its reorder
        buffer (an out-of-order replica retirement, not a rate
        mismatch).  Returns ``(reason, edge_label)``."""
        r = getattr(prog, "wait_reason", None)
        if not r:
            return ("blocked", "")
        reason, fifo = r
        label = getattr(fifo, "label", None) or "" if fifo is not None else ""
        if reason == "starve" and fifo is not None:
            pend = self._reorder.get(id(fifo))
            if pend and pend[0]:
                reason = "reorder"
        return (reason, label)

    def idle_reason_of(self, prog) -> tuple[str, str] | None:
        """Why ``prog``'s op queue is *empty* (vs ``wait_reason_of``,
        which explains a deferred nonempty queue).  Programs whose ops
        are scheduled by upstream traffic (decode stages waiting on the
        head's token loop) expose an optional ``idle_reason()`` hook
        returning ``(reason, fifo)`` or None; without it — or once the
        program reports the stream over — an empty queue is not a wait.
        This is what puts the *source* stage (embed) into
        ``stage_wait_s``: its queue refills and its feedback token land
        in the same head retirement, so the nonempty-queue wait path
        never fires for it."""
        hook = getattr(prog, "idle_reason", None)
        if hook is None:
            return None
        r = hook()
        if r is None:
            return None
        reason, fifo = r
        label = getattr(fifo, "label", None) or "" if fifo is not None else ""
        return (reason, label)


# ===========================================================================
# wall-clock driver: asynchronous overlapped scheduler
# ===========================================================================
@dataclass
class EngineResult:
    """The generic half of an execution's result: per-stage timing streams
    and op bookkeeping.  Backends embed/alias these fields into their own
    result types (`LMPipelineResult`, `ServeRunResult`)."""
    stage_seconds: dict[str, float] = field(default_factory=dict)
    stage_firings: dict[str, int] = field(default_factory=dict)
    stage_done_s: dict[str, list[float]] = field(default_factory=dict)
    stage_dispatch_s: dict[str, float] = field(default_factory=dict)
    # host wall time spent *inside* op bodies (device_put + program
    # dispatch) per stage — the host-overhead share of stage time, kept
    # separate so dispatch cost is visible data, not folded into the
    # measured inverse throughput
    op_trace: list = field(default_factory=list)
    # (stage, kind, seq, replica, t_dispatch, t_done) run-relative
    max_inflight: int = 0
    wall_s: float = 0.0
    stage_wait_s: dict[str, dict[str, float]] = field(default_factory=dict)
    # stage -> {reason: seconds blocked} — credit (output full) vs starve
    # (input empty) vs reorder attribution; populated only when the run
    # was traced (the accounting rides the tracer's enable flag so the
    # default path stays untouched)
    failovers: list = field(default_factory=list)
    # one dict per survived replica fault: {stage, replica, kind,
    # t_fault_s, recovery_s, replayed_ops} — the drill's recovery-time
    # and tokens-lost evidence

    def stage_inverse_us(self, name: str) -> float:
        """Steady-state microseconds per firing of one stage (merged
        replica completion streams -> effective ii/nr).  Runs too short
        for a steady state fall back to mean in-flight latency per op —
        a degraded mode callers should not calibrate on."""
        try:
            return steady_inverse(self.stage_done_s.get(name, ())) * 1e6
        except ValueError:
            n = self.stage_firings.get(name, 0)
            return (self.stage_seconds.get(name, 0.0) / n * 1e6
                    if n else float("nan"))

    def stage_host_us(self, name: str) -> float:
        """Host-side dispatch microseconds per firing of one stage: wall
        time its op bodies spent on the host (transfers issued, program
        dispatched) divided by firings — the overhead the async executor
        hides under device compute, surfaced as its own number."""
        n = self.stage_firings.get(name, 0)
        return (self.stage_dispatch_s.get(name, 0.0) / n * 1e6
                if n else float("nan"))


def _stalled(fn: Callable, stall_s: float) -> Callable:
    """Wrap an op body in a host-side sleep — the injected-straggler
    shape: the replica is alive but every firing it runs is slow."""
    def wrapped(*args):
        time.sleep(stall_s)
        return fn(*args)
    return wrapped


class Engine(Driver):
    """Wall-clock driver: non-blocking scheduler over a list of `Program`s.

    ``overlap=True`` hands dispatched ops to a thread pool and retires
    them on completion; ``overlap=False`` is the serial A/B baseline
    (dispatch, block, advance).  ``replica_queue`` caps in-flight ops per
    stage replica (1 = strict serial worker, 2 = short device queue).
    """

    # how long a no-progress sweep waits on worker futures before
    # re-polling the device-completion watch sets (seconds)
    POLL_S = 5e-4

    def __init__(self, programs: list, *, overlap: bool = True,
                 workers: int = 8, replica_queue: int = 2,
                 tracer=None, fifos: dict | None = None,
                 injector=None, on_tick: Callable | None = None,
                 tick_every: int = 64, static_report=None):
        """``tracer``: optional `trace.Tracer` — op spans, wait spans, and
        per-stage stall/starve accounting (off = zero-cost path).
        ``fifos``: {label: Fifo} for the deadlock report's occupancy
        snapshot (independent of tracing).  ``injector``: optional
        `failures.ReplicaFaultPlan` consulted before every dispatch —
        a firing ``crash`` marks the op's replica dead and triggers
        failover, a ``stall`` wraps the op body in a host-side sleep.
        ``on_tick(engine)``: optional health hook invoked every
        ``tick_every`` retirements from the scheduler thread (the
        `HealthController` attachment point).  ``static_report``: the
        `core.verify.VerificationReport` this run was preflighted with
        (None = preflight skipped) — a runtime deadlock cross-references
        it so the report says whether the wedge matches a static finding
        or the plan was proven deadlock-free."""
        super().__init__(tracer)
        self.programs = list(programs)
        self.fifos = dict(fifos or {})
        self.static_report = static_report
        self.overlap = overlap
        self.workers = max(1, workers)
        self.replica_queue = max(1, replica_queue)
        self.injector = injector
        self.on_tick = on_tick
        self.tick_every = max(1, tick_every)
        self._retired_n = 0
        self.result = EngineResult()
        self._busy = [[0] * max(1, p.n_replicas) for p in self.programs]
        self._inflight: dict = {}     # future -> Op (worker running)
        self._pending: list = []      # (Op, AsyncResult): device in flight
        for p in self.programs:
            self.result.stage_seconds[p.name] = 0.0
            self.result.stage_firings[p.name] = 0
            self.result.stage_done_s[p.name] = []
            self.result.stage_dispatch_s[p.name] = 0.0

    def _retire(self, op: Op, result) -> None:
        prog = self.programs[op.stage]
        t_done = prog.retire(op, result, self)
        for fifo, n in op.releases:
            fifo.release(n)
        self._busy[op.stage][op.rep] -= 1
        res = self.result
        if op.is_firing:
            res.stage_done_s[prog.name].append(t_done - self.t0)
        res.stage_seconds[prog.name] += t_done - op.t_dispatch
        res.stage_firings[prog.name] += 1
        res.op_trace.append((prog.name, op.kind, op.seq, op.rep,
                             op.t_dispatch - self.t0, t_done - self.t0))
        if self.tracer is not None:
            self.tracer.op_retire(prog.name, op.rep, op.kind, op.seq,
                                  op.chunk, op.t_dispatch - self.t0,
                                  t_done - self.t0)
        self._retired_n += 1
        if self.on_tick is not None \
                and self._retired_n % self.tick_every == 0:
            self.on_tick(self)

    def _settle(self, op: Op, result, t_done: float) -> None:
        """Retire a completed op, unwrapping an `AsyncResult` by appending
        the observed completion timestamp to its payload."""
        if isinstance(result, AsyncResult):
            result = result.payload + (t_done,)
        self._retire(op, result)

    def _abort(self, op: Op) -> None:
        """An op's body raised: free its channel credits and busy slot so
        the failure surfaces as the exception, not as a leaked-slot
        deadlock in some later run."""
        for fifo, n in op.releases:
            fifo.release(n)
        self._busy[op.stage][op.rep] -= 1

    def diagnostic_bundle(self) -> dict:
        """The deadlock report's forensics as structured data — what a
        `PipelineFailure` carries out of the run: every registered
        fifo's occupancy, each stuck program's wait reason and schedule
        position, reorder-buffer depth, failover history, trace tail."""
        bundle: dict = {
            "fifo_occupancy": {
                label: {"len": len(f), "capacity": f.capacity,
                        "inflight_slots": f.inflight_slots}
                for label, f in sorted(self.fifos.items())},
            "waiting": {p.name: self.wait_reason_of(p)
                        for p in self.programs if p.pending()},
            "schedule": [p.describe() for p in self.programs],
            "reorder_occupancy": self.reorder_occupancy(),
            "failovers": list(self.result.failovers),
            "static_preflight": (self.static_report.summary()
                                 if self.static_report is not None
                                 else {"ran": False}),
        }
        if self.tracer is not None:
            bundle["trace_tail"] = [
                f"{e.track}:{e.kind} {e.name}{e.seq if e.seq >= 0 else ''}"
                f"@{e.t:.4g}" for e in self.tracer.tail(n=12)]
        return bundle

    def _replica_fault(self, s: int, rep: int, kind: str, lost0=()) -> None:
        """Whole-replica abort + failover: replica ``rep`` of stage ``s``
        died.  Drain its in-flight ops (results discarded — the device is
        gone), release every credit they held, and hand the lost ops —
        sorted by seq, each carrying its ``recover`` payload — to the
        program's ``fail_replica`` hook, which remaps routing and queues
        the replay.  A program without the hook, or whose last replica
        died, escalates to `PipelineFailure` with the diagnostic bundle
        attached — a structured failure, never a wedged reorder buffer."""
        prog = self.programs[s]
        t_fault = time.perf_counter() - self.t0
        lost = list(lost0)
        for f in [f for f, o in self._inflight.items()
                  if o.stage == s and o.rep == rep]:
            op = self._inflight.pop(f)
            try:
                f.result()          # wait the body home; discard its output
            except BaseException:
                pass
            self._abort(op)
            lost.append(op)
        for op, ar in [(o, a) for o, a in self._pending
                       if o.stage == s and o.rep == rep]:
            self._pending.remove((op, ar))
            self._abort(op)
            lost.append(op)
        lost.sort(key=lambda o: o.seq)
        fail = getattr(prog, "fail_replica", None)
        try:
            if fail is None:
                raise PipelineFailure(
                    f"stage {prog.name}: replica r{rep} died ({kind}) and "
                    f"the program has no failover hook",
                    stage=prog.name, replica=rep, reason=kind)
            fail(rep, self, lost)
        except PipelineFailure as e:
            e.reason = e.reason or kind
            for key, val in self.diagnostic_bundle().items():
                e.diagnostics.setdefault(key, val)
            e.diagnostics.setdefault(
                "lost_ops", [(o.kind, o.seq) for o in lost])
            raise
        t_rec = time.perf_counter() - self.t0
        self.result.failovers.append({
            "stage": prog.name, "replica": rep, "kind": kind,
            "t_fault_s": t_fault, "recovery_s": t_rec - t_fault,
            "replayed_ops": len(lost)})
        if self.tracer is not None:
            self.tracer.failover(prog.name, rep, kind, t_fault, t_rec,
                                 len(lost))

    def _deadlock_detail(self) -> str:
        """Hang forensics appended to the deadlock error: what each party
        was *waiting on* — every registered fifo's occupancy (queued/cap
        plus in-flight slots) and, when traced, the last few events per
        stuck stage — not just the schedule position."""
        lines: list[str] = []
        if self.fifos:
            occ = []
            for label, f in sorted(self.fifos.items()):
                s = f"{label}={len(f)}/{f.capacity}"
                if f.inflight_slots:
                    s += f"(+{f.inflight_slots} in flight)"
                occ.append(s)
            lines.append("fifo occupancy: " + ", ".join(occ))
        elif self.tracer is not None and self.tracer.fifo_watch:
            lines.append("fifo occupancy: "
                         + ", ".join(self.tracer.fifo_snapshot()))
        for p in self.programs:
            if not p.pending():
                continue
            reason, edge = self.wait_reason_of(p)
            lines.append(f"{p.name} waiting: {reason}"
                         + (f" on {edge}" if edge else ""))
            if self.tracer is not None:
                tail = self.tracer.tail(p.name, n=4)
                if tail:
                    lines.append(f"last events {p.name}: " + "; ".join(
                        f"{e.kind} {e.name}{e.seq if e.seq >= 0 else ''}"
                        f"@{e.t:.4g}" for e in tail))
        lines.extend(self._static_crossref())
        return "".join("\n  " + ln for ln in lines)

    def _static_crossref(self) -> list[str]:
        """Tie the runtime wedge back to the static analysis: either the
        plan skipped preflight (say so — the wedge may be a statically
        catchable sizing bug), or a static finding already predicted a
        deadlock on some edge (name it), or the plan was verified
        deadlock-free (so suspect the executor, a fault injection, or an
        external stall, not the plan)."""
        rep = self.static_report
        if rep is None:
            return ["static preflight: not run for this drive — "
                    "rerun with preflight=True (or tools/stg_lint.py) "
                    "to check whether this wedge is statically provable"]
        hits = rep.deadlock_findings()
        if hits:
            out = ["static preflight: runtime wedge matches "
                   f"{len(hits)} static finding(s):"]
            out += ["  " + f.describe() for f in hits[:4]]
            return out
        return ["static preflight: plan was verified deadlock-free "
                f"(checks: {', '.join(rep.checks)}) — suspect an "
                "executor bug, fault injection, or external stall, "
                "not the plan's channel sizing"]

    @staticmethod
    def _timed(fn, args):
        """Worker-side wrapper: run the op body and measure the host wall
        time it spent (the dispatch-overhead sample for ``stage_host_us``;
        under async bodies this is pure host work — the device part is in
        flight when the body returns)."""
        t0 = time.perf_counter()
        result = fn(*args)
        return result, time.perf_counter() - t0

    def run(self) -> EngineResult:
        from concurrent.futures import (FIRST_COMPLETED, ThreadPoolExecutor,
                                        wait)
        self.t0 = time.perf_counter()
        inflight = self._inflight           # future -> Op (worker running)
        pending = self._pending             # (Op, AsyncResult): body returned,
        #                                     device work still in flight
        pool = ThreadPoolExecutor(max_workers=self.workers) \
            if self.overlap else None
        dispatch_s = self.result.stage_dispatch_s
        tr = self.tracer
        if tr is not None:
            tr.bind_wall(self.t0)
        # per-stage open blocked span: (t_blocked, (reason, edge)) — set
        # the first sweep a stage's next op defers, closed (one wait
        # event + stall/starve seconds) when the op finally dispatches
        wait_since: list = [None] * len(self.programs)
        try:
            while (any(p.pending() for p in self.programs)
                   or inflight or pending):
                progressed = False
                # downstream-first: consumers drain fifos before producers
                for s in reversed(range(len(self.programs))):
                    prog = self.programs[s]
                    op = prog.peek()
                    if op is None:
                        if tr is not None and wait_since[s] is None:
                            r = self.idle_reason_of(prog)
                            if r is not None:
                                wait_since[s] = (
                                    time.perf_counter() - self.t0, r)
                        continue
                    if self._busy[s][op.rep] >= self.replica_queue:
                        continue
                    if prog.ready(op) is None:
                        if tr is not None and wait_since[s] is None:
                            wait_since[s] = (time.perf_counter() - self.t0,
                                             self.wait_reason_of(prog))
                        continue
                    stall_s = 0.0
                    if self.injector is not None:
                        spec = self.injector.check(prog.name, op.rep, op.seq)
                        if spec is not None and spec.kind == "crash":
                            # the op consumed nothing yet: failover remaps
                            # its routing and the next sweep re-peeks it
                            # onto a surviving replica
                            self._replica_fault(s, op.rep, spec.kind)
                            progressed = True
                            continue
                        elif spec is not None:
                            stall_s = spec.stall_s
                    fn, args = prog.dispatch(op, self)
                    if stall_s > 0.0:
                        fn = _stalled(fn, stall_s)
                    op.t_dispatch = time.perf_counter()
                    self._busy[s][op.rep] += 1
                    progressed = True
                    if tr is not None:
                        td = op.t_dispatch - self.t0
                        if wait_since[s] is not None:
                            t_w, (reason, edge) = wait_since[s]
                            wait_since[s] = None
                            tr.wait(prog.name, reason, edge, t_w, td)
                            d = self.result.stage_wait_s.setdefault(
                                prog.name, {})
                            d[reason] = d.get(reason, 0.0) + (td - t_w)
                        tr.op_dispatch(prog.name, op.rep, op.kind,
                                       op.seq, op.chunk, td)
                    if pool is None:
                        # serial A/B baseline: dispatch, await, advance
                        try:
                            result, host_s = self._timed(fn, args)
                        except ReplicaFault:
                            self._abort(op)     # the op itself is lost too:
                            self._replica_fault(s, op.rep, "crash",
                                                lost0=(op,))
                            progressed = True
                            continue
                        except BaseException:
                            self._abort(op)
                            raise
                        dispatch_s[prog.name] += host_s
                        if isinstance(result, AsyncResult):
                            try:        # a device error surfaces here —
                                result.block()   # free credits like the
                            except ReplicaFault:
                                self._abort(op)
                                self._replica_fault(s, op.rep, "crash",
                                                    lost0=(op,))
                                progressed = True
                                continue
                            except BaseException:  # old in-body sync did
                                self._abort(op)
                                raise
                        self._settle(op, result, time.perf_counter())
                    else:
                        inflight[pool.submit(self._timed, fn, args)] = op
                        self.result.max_inflight = max(
                            self.result.max_inflight,
                            len(inflight) + len(pending))
                # drain worker futures: a body either completed its op
                # synchronously (host compute) or handed back an
                # AsyncResult whose device work we watch below
                for f in [f for f in inflight if f.done()]:
                    op = inflight.pop(f)
                    try:
                        result, host_s = f.result()
                    except ReplicaFault:
                        self._abort(op)
                        self._replica_fault(op.stage, op.rep, "crash",
                                            lost0=(op,))
                        progressed = True
                        continue
                    except BaseException:
                        self._abort(op)
                        raise
                    dispatch_s[self.programs[op.stage].name] += host_s
                    if isinstance(result, AsyncResult):
                        pending.append((op, result))
                    else:
                        self._settle(op, result, time.perf_counter())
                        progressed = True
                # retire device completions (completion futures, no host
                # sync): ready watch sets observed this sweep
                if pending:
                    now = time.perf_counter()
                    still = []
                    for op, ar in pending:
                        if ar.is_ready():
                            self._settle(op, ar, now)
                            progressed = True
                        else:
                            still.append((op, ar))
                    pending = self._pending = still
                if not progressed:
                    if inflight:
                        # with device work pending, wait bounded (a watch
                        # set may become ready before any worker future);
                        # with none, block until a worker finishes — no
                        # busy-poll stealing host CPU from the op bodies
                        wait(list(inflight),
                             timeout=self.POLL_S if pending else None,
                             return_when=FIRST_COMPLETED)
                    elif pending:
                        # nothing dispatchable, no workers running: block
                        # on the oldest in-flight device op for an
                        # accurate completion timestamp
                        op, ar = pending.pop(0)
                        try:
                            ar.block()
                        except ReplicaFault:
                            self._abort(op)
                            self._replica_fault(op.stage, op.rep, "crash",
                                                lost0=(op,))
                            continue
                        except BaseException:
                            self._abort(op)
                            raise
                        self._settle(op, ar, time.perf_counter())
                    else:
                        state = "; ".join(p.describe()
                                          for p in self.programs)
                        raise RuntimeError(
                            f"pipeline deadlock: no program can dispatch "
                            f"and nothing is in flight — "
                            f"schedule/backpressure bug ({state})"
                            + self._deadlock_detail())
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        self.result.wall_s = time.perf_counter() - self.t0
        return self.result


# ===========================================================================
# virtual-clock driver: discrete-event loop
# ===========================================================================
@dataclass
class EventLoopStats:
    fire_times: dict[str, list[float]] = field(default_factory=dict)
    fired: dict[str, int] = field(default_factory=dict)
    busy_cycles: dict[str, float] = field(default_factory=dict)
    cycles: float = 0.0
    total_fired: int = 0
    hit_cycle_cap: bool = False
    wait_cycles: dict[str, dict[str, float]] = field(default_factory=dict)
    # stage -> {reason: cycles blocked} — the virtual-clock twin of
    # `EngineResult.stage_wait_s`; populated only under a tracer
    failovers: list = field(default_factory=list)
    # survived replica faults, as in `EngineResult.failovers` (virtual
    # clock: recovery is instantaneous and nothing is in flight, so the
    # entries carry t_fault_cycles and replayed_ops only)
    skipped_faults: list = field(default_factory=list)
    # stall specs the virtual clock cannot honor (no host time to burn)


class EventLoop(Driver):
    """Virtual-clock driver of the same `Program` protocol.

    Deterministic: among fireable programs the earliest (t, insertion
    seq) fires.  A popped candidate is re-checked (it may have been
    blocked by an earlier firing) and either fires, re-queues at its new
    ready time, or is dropped — a wake from a later retirement re-queues
    it.  Programs call ``driver.wake(names...)`` in ``retire`` to name
    whose readiness may have changed, read ``driver.now`` for the firing
    time, and report ``driver.note_busy`` cycles for the utilisation
    stats."""

    virtual = True

    def __init__(self, programs: dict[str, Program], tracer=None,
                 injector=None):
        """``injector``: optional `failures.ReplicaFaultPlan` — same
        dispatch-time consultation as the wall-clock engine, so a chaos
        drill fires at the identical op coordinate on the simulator.
        Crash faults fail over synchronously (the virtual clock has no
        in-flight ops to drain); stall faults are recorded in
        ``stats.skipped_faults`` — there is no host time to burn."""
        super().__init__(tracer)
        self.programs = dict(programs)
        self.injector = injector
        self.now = 0.0
        self._wake: set[str] = set()

    def wake(self, *names: str) -> None:
        self._wake.update(names)

    def note_busy(self, name: str, amount: float) -> None:
        self.stats.busy_cycles[name] += amount

    def _replica_fault(self, name: str, rep: int, kind: str) -> None:
        """Virtual-clock failover: nothing is ever in flight (dispatch
        and retire are one synchronous step), so a fault only remaps
        routing — the about-to-fire op re-peeks onto a survivor."""
        prog = self.programs[name]
        fail = getattr(prog, "fail_replica", None)
        try:
            if fail is None:
                raise PipelineFailure(
                    f"stage {name}: replica r{rep} died ({kind}) and "
                    f"the program has no failover hook",
                    stage=name, replica=rep, reason=kind)
            fail(rep, self, [])
        except PipelineFailure as e:
            e.reason = e.reason or kind
            e.diagnostics.setdefault(
                "schedule", [p.describe() for p in self.programs.values()])
            e.diagnostics.setdefault("reorder_occupancy",
                                     self.reorder_occupancy())
            e.diagnostics.setdefault("failovers",
                                     list(self.stats.failovers))
            raise
        self.stats.failovers.append({
            "stage": name, "replica": rep, "kind": kind,
            "t_fault_cycles": self.now, "replayed_ops": 0})
        if self.tracer is not None:
            self.tracer.failover(name, rep, kind, self.now, self.now, 0)

    def run(self, *, max_firings: int = 1_000_000,
            max_cycles: float = 1e12) -> EventLoopStats:
        programs = self.programs
        self.stats = stats = EventLoopStats()
        tr = self.tracer
        if tr is not None:
            tr.bind_virtual(self)
        # open blocked spans, as in the wall-clock engine: set on the
        # heap-pop re-check (a *real* deferral, same count_stall
        # semantics as FifoStats), closed at the next fire
        wait_since: dict[str, tuple] = {}
        for n in programs:
            stats.fire_times[n] = []
            stats.fired[n] = 0
            stats.busy_cycles[n] = 0.0

        seq = 0
        heap: list[tuple[float, int, str]] = []

        def push_candidate(name: str) -> None:
            nonlocal seq
            prog = programs[name]
            op = prog.peek()
            if op is None:
                if tr is not None and name not in wait_since:
                    r = self.idle_reason_of(prog)
                    if r is not None:
                        wait_since[name] = (self.now, r)
                return
            t = prog.ready(op)
            if t is not None:
                heapq.heappush(heap, (t, seq, name))
                seq += 1
            elif tr is not None and name not in wait_since:
                # blocked at wake time: open its wait span now — a later
                # wake (or pop re-check) requeues it and the span closes
                # at its next fire
                wait_since[name] = (self.now, self.wait_reason_of(prog))

        for n in programs:
            push_candidate(n)

        while heap and stats.total_fired < max_firings:
            now, _, name = heapq.heappop(heap)
            if now > max_cycles:
                stats.hit_cycle_cap = True
                break
            prog = programs[name]
            op = prog.peek()
            if op is None:
                continue        # completed since queueing
            t = prog.ready(op, count_stall=True)
            if t is None:
                if tr is not None and name not in wait_since:
                    wait_since[name] = (now, self.wait_reason_of(prog))
                continue        # became blocked; a wake requeues it
            if t > now:
                heapq.heappush(heap, (t, seq, name))
                seq += 1
                continue
            self.now = now
            self._wake = set()
            if self.injector is not None:
                spec = self.injector.check(name, op.rep, op.seq)
                if spec is not None and spec.kind == "crash":
                    self._replica_fault(name, op.rep, spec.kind)
                    for c in self._wake | {name}:
                        if c in programs:
                            push_candidate(c)
                    continue
                elif spec is not None:
                    stats.skipped_faults.append((name, op.rep, spec.kind))
            fn, args = prog.dispatch(op, self)
            op.t_dispatch = now
            if tr is not None:
                ws = wait_since.pop(name, None)
                if ws is not None:
                    t_w, (reason, edge) = ws
                    tr.wait(name, reason, edge, t_w, now)
                    d = stats.wait_cycles.setdefault(name, {})
                    d[reason] = d.get(reason, 0.0) + (now - t_w)
                tr.op_dispatch(name, op.rep, op.kind, op.seq, op.chunk, now)
            result = fn(*args)
            done = prog.retire(op, result, self)
            if tr is not None:
                tr.op_retire(name, op.rep, op.kind, op.seq, op.chunk,
                             now, done)
            for fifo, n_rel in op.releases:
                fifo.release(n_rel)
            stats.fired[name] += 1
            stats.fire_times[name].append(now)
            stats.total_fired += 1
            stats.cycles = max(stats.cycles, done)
            for c in self._wake | {name}:
                if c in programs:
                    push_candidate(c)
        return stats


def run_event_loop(programs: dict[str, Program], *,
                   max_firings: int = 1_000_000,
                   max_cycles: float = 1e12,
                   tracer=None, injector=None) -> EventLoopStats:
    """Drive `Program`s to quiescence under a virtual clock (the
    functional entry point over `EventLoop`)."""
    return EventLoop(programs, tracer, injector).run(max_firings=max_firings,
                                                     max_cycles=max_cycles)
