"""Decode-shape serving pipelines: prefill + token streams over placed stages.

The jax microbatch pipeline (`jax_pipe`) exercises train/prefill-style
traffic: a fixed list of microbatches, a schedule known up front.  Serving
is the other shape the planner prices (`SHAPES["decode_32k"]`): request
groups prefill once, then emit one token per step until every slot hits
EOS or its budget — traffic whose length is decided *by the pipeline's own
output*.  This module runs that shape on the same executor core:

  * stages are built from the *same model code* the single-device server
    runs — `models/lm.prefill_blocks` / `decode_blocks` over
    `slice_periods` of the stacked parameters — so a pipelined serve is
    token-identical to `LMServer.serve_round` under greedy sampling;
  * every block stage keeps its **KV/SSM cache slice resident on its
    placement slice**: the prefill op constructs the stage's cache shard
    on the stage's device, decode ops update it **in place** — the
    decode program donates the incoming cache (``donate_argnums``), so
    every leaf aliases onto the resident buffers and a token step
    allocates no new cache memory — and only the (B, 1, d_model) hidden
    state crosses inter-stage FIFOs;
  * request groups map to stage replicas by ``gid % nr`` (cache
    affinity), so a replicated stage serves groups concurrently exactly
    like the plan's round-robin replication;
  * the head stage samples on retirement and feeds the token back to the
    embed stage over a `channels.StreamChannel` — the continuous
    token-stream mode: decode ops are *scheduled as tokens arrive* (the
    engine's pending-or-inflight termination), and the stream closes when
    the last group drains;
  * all stage programs are `aot.AotProgram`s, AOT-compiled against each
    group's concrete shapes before the engine's clock starts
    (``warmup=``), and op bodies dispatch without host syncs — the
    engine retires them off completion futures — so no served request
    ever sees a compile or a per-op ``block_until_ready`` stall.

Placement folds tp > 1 slices onto their first device (decode stage
bodies are single-device jits; sharding decode over a sub-mesh is a
ROADMAP item) — the plan's replica structure, not its intra-stage
sharding, is what this backend executes.  Encoder-decoder and multimodal
frontends are rejected: the pipeline runs embed -> blocks -> head only.

`runtime/server.LMServer` uses this as its pipelined backend
(``LMServer(cfg, pipeline=DecodePipeline(...))``); see
`examples/serve_lm.py --pipeline` and `benchmarks/bench_serve.py`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ...configs.base import ModelConfig
from ...core.stg import STG
from ...models import blocks, lm
from ...models.common import dtype_of, rmsnorm
from ..server import _bucket            # one bucketing rule: token parity
from .aot import AotProgram, CompileStats
from .channels import Fifo, StreamChannel, check_not_donated
from .engine import AsyncResult, Engine, EngineResult, Op, describe_position
from .placement import Placement, place


# ===========================================================================
# stage computation (models/lm over period slices)
# ===========================================================================
def _embed_prefill_fn(cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)

    def fn(p, tokens):
        return jnp.take(p["embed"], tokens, axis=0).astype(dt)
    return fn


def _block_prefill_fn(cfg: ModelConfig, impl: str | None = None):
    def fn(p, x, cap):
        S = x.shape[1]
        return lm.prefill_blocks(cfg, p, x, jnp.arange(S), cap=cap, impl=impl)
    return fn


def _block_decode_fn(cfg: ModelConfig, impl: str | None = None):
    def fn(p, cache, x, pos):
        return lm.decode_blocks(cfg, p, cache, x, pos, impl=impl)
    return fn


def _head_fn(cfg: ModelConfig):
    def fn(p, x):
        h = x[:, -1:]
        h = rmsnorm(h, p["norm"], cfg.norm_eps)
        return h @ p["w"].astype(h.dtype)
    return fn


# Fused (combined) stage bodies: the sequential composition of the member
# stages as ONE jitted program — the executable form of
# `core.restructure.combine`.  A fused stage that absorbed embed takes raw
# token ids instead of hidden states; one that absorbed head emits logits.
# The member math is identical to the unfused programs (same models/lm
# calls in the same order), and `optimization_barrier` pins each member
# boundary as a materialisation point — numerically exactly what the
# deleted fifo hop did — so XLA cannot fuse across it and re-round the
# bf16 activations: token parity with the unfused pipeline is structural,
# not coincidental.
def _fused_prefill_fn(cfg: ModelConfig, has_embed: bool, has_head: bool,
                      impl: str | None = None):
    dt = dtype_of(cfg.compute_dtype)

    def fn(p, x, cap):
        if has_embed:
            x = jnp.take(p["embed"], x, axis=0).astype(dt)
            x = jax.lax.optimization_barrier(x)
        S = x.shape[1]
        y, cache = lm.prefill_blocks(cfg, p["layers"], x, jnp.arange(S),
                                     cap=cap, impl=impl)
        if has_head:
            h = jax.lax.optimization_barrier(y)[:, -1:]
            h = rmsnorm(h, p["norm"], cfg.norm_eps)
            y = h @ p["w"].astype(h.dtype)
        return y, cache
    return fn


def _fused_decode_fn(cfg: ModelConfig, has_embed: bool, has_head: bool,
                     impl: str | None = None):
    dt = dtype_of(cfg.compute_dtype)

    def fn(p, cache, x, pos):
        if has_embed:
            x = jnp.take(p["embed"], x, axis=0).astype(dt)
            x = jax.lax.optimization_barrier(x)
        y, cache = lm.decode_blocks(cfg, p["layers"], cache, x, pos,
                                    impl=impl)
        if has_head:
            h = jax.lax.optimization_barrier(y)[:, -1:]
            h = rmsnorm(h, p["norm"], cfg.norm_eps)
            y = h @ p["w"].astype(h.dtype)
        return y, cache
    return fn


@dataclass(frozen=True)
class _StageDesc:
    """One executed pipeline stage, possibly the fusion of several base
    stages.  ``members`` are the base stage names in chain order;
    ``span`` is the union of the members' block-period spans (None for a
    lone embed/head)."""
    name: str
    members: tuple[str, ...]
    has_embed: bool
    span: tuple[int, int] | None
    has_head: bool


# ===========================================================================
# run state
# ===========================================================================
@dataclass
class _Group:
    """One serving slot group: a batch of requests decoding in lockstep,
    mirroring `LMServer.serve_round`'s round semantics exactly (same
    bucketing, same EOS/budget bookkeeping) so completions are
    token-identical."""
    gid: int
    tokens: np.ndarray                 # (B, bucket) right-aligned prompts
    bucket: int
    cap: int
    budget: np.ndarray
    done: np.ndarray = None
    out_tokens: list = None
    steps: int = 0                     # completed decode steps
    cur: np.ndarray = None             # last sampled token per slot (B,)
    t_start: float = 0.0
    t_prefill_done: float = 0.0
    t_last: float = 0.0
    decode_done_s: list = field(default_factory=list)
    fed: list = field(default_factory=list)
    # token history: fed[j] is the (B,) token batch fed back for decode
    # step j.  out_tokens is NOT enough to replay a cache — done slots
    # keep feeding their last sampled token in lockstep without emitting
    # it — so failover/rescale cache rebuilds read this instead.

    @property
    def batch(self) -> int:
        return self.tokens.shape[0]


@dataclass
class ServeRunResult(EngineResult):
    """One pipelined serve: per-request tokens + the engine's measurement
    surface (stage completion streams, fifo stats, trace).  As an
    `EngineResult` it exposes ``stage_inverse_us``, so a serve run feeds
    `measure.compare_lm(stg, sel, run,
    stage_map=pipe.graph_stage_map())` exactly like an LM microbatch run
    — serving traffic is a calibration source for re-planning too."""
    tokens: list = field(default_factory=list)   # per request, generated
    group_of: list = field(default_factory=list)  # request index -> group id
    groups: list = field(default_factory=list)   # _Group bookkeeping
    fifo_stats: dict = field(default_factory=dict)
    placement: Placement | None = None
    paused: bool = False               # admission-paused mid-stream
    resume_state: object = None        # `ResumeState` when paused

    @property
    def decode_tokens(self) -> int:
        return sum(len(t) for t in self.tokens)

    @property
    def prefill_tokens(self) -> int:
        return sum(g.batch * g.bucket for g in self.groups)

    def decode_done_s(self) -> list[float]:
        """Merged decode-step completion times across groups (run-relative,
        sorted) — the serving-side analogue of a stage's completion
        stream."""
        return sorted(t for g in self.groups for t in g.decode_done_s)

    def decode_tokens_per_s(self) -> float:
        """Steady-state generated tokens/s from the merged decode
        completion stream (excludes prefill and the fill ramp; falls back
        to wall-clock for very short runs)."""
        ts = self.decode_done_s()
        toks_per_step = (sum(g.batch for g in self.groups)
                         / max(1, len(self.groups)))
        if len(ts) >= 3:
            k = max(1, len(ts) // 4)
            w = ts[k:]
            if len(w) >= 2 and w[-1] > w[0]:
                return toks_per_step * (len(w) - 1) / (w[-1] - w[0])
        return self.decode_tokens / max(self.wall_s, 1e-9)

    def token_latencies_s(self) -> list[float]:
        """Per-token latency samples: gaps between successive decode-step
        completions *within* each group (what a client slot observes)."""
        out = []
        for g in self.groups:
            ts = [g.t_prefill_done] + list(g.decode_done_s)
            out.extend(b - a for a, b in zip(ts, ts[1:]))
        return out

    def slo(self) -> dict:
        """Per-request serving SLO percentiles (flat ms dict): queue wait
        (submit -> first prefill dispatch), TTFT (submit -> first sampled
        token), and inter-token gap — `metrics.serving_slo` over the
        group timings.  Groups are the unit a client slot experiences, so
        samples are per group, gaps per decoded token."""
        from .metrics import serving_slo
        return serving_slo(
            queue_wait_s=[g.t_start for g in self.groups],
            ttft_s=[g.t_prefill_done for g in self.groups],
            token_gap_s=self.token_latencies_s())


# ===========================================================================
# stage programs
# ===========================================================================
class _ServeStageProgram:
    """One serving stage's op queue on the shared engine.

    Ops arrive dynamically: prefill ops for all groups are enqueued up
    front; each decode op is enqueued (to *every* stage, with one global
    sequence number) the moment the head samples the previous token — the
    queue order is therefore identical across stages and every FIFO sees
    a contiguous seq stream, re-sorted by the engine's reorder buffers
    when replicas retire out of order."""

    def __init__(self, s: int, pipe: "DecodePipeline", run: "_ServeRun"):
        self.s = s
        self.S = len(pipe.stage_names)
        self.name = pipe.stage_names[s]
        self.pipe = pipe
        self.run = run
        self.n_replicas = len(pipe.stage_devices[s])
        self.queue: list = []          # (kind, gid, seq, pos)
        self.pos_i = 0
        self.stall_mark = -1
        self.wait_reason = None   # (reason, fifo) of the last deferral
        self.caches: dict[int, object] = {}    # gid -> resident cache slice
        # failover/rebalance state: group routing defaults to the cache-
        # affinity rule gid % n_replicas; rep_map overrides it after a
        # replica dies (or a straggler sheds load), dead marks replicas
        # the engine must never route to again
        self.rep_map: dict[int, int] = {}
        self.dead: set[int] = set()
        self.redo: list = []           # (kind, gid, seq, pos, payload):
        #                                lost ops re-issued under their
        #                                ORIGINAL seq so reorder holes fill
        self.done_count: dict[int, int] = {}   # gid -> retired ops here
        self.inflight: dict[int, int] = {}     # gid -> dispatched-unretired

    def enqueue(self, kind: str, gid: int, seq: int, pos: int) -> None:
        self.queue.append((kind, gid, seq, pos))

    def pending(self) -> int:
        return len(self.queue) - self.pos_i + len(self.redo)

    def rep_of(self, gid: int) -> int:
        return self.rep_map.get(gid, gid % self.n_replicas)

    def peek(self) -> Op | None:
        if self.redo:
            kind, gid, seq, _pos, _payload = self.redo[0]
            return Op(stage=self.s, kind=kind, seq=seq, rep=self.rep_of(gid))
        if self.pos_i >= len(self.queue):
            return None
        kind, gid, seq, _ = self.queue[self.pos_i]
        return Op(stage=self.s, kind=kind, seq=seq, rep=self.rep_of(gid))

    def ready(self, op: Op, count_stall: bool = False) -> float | None:
        s, S, run = self.s, self.S, self.run
        if self.redo:
            # a replayed op re-runs from its saved inputs and retires into
            # the slot its original dispatch already reserved — no fifo
            # state to wait for
            return 0.0
        if s > 0 and not run.acts[s - 1].can_pop(1):
            self.wait_reason = ("starve", run.acts[s - 1])
            return None
        if s == 0 and op.kind == "D" and not run.feedback.can_pop(1):
            self.wait_reason = ("starve", run.feedback)
            return None
        if s < S - 1 and not run.acts[s].can_push(1):
            if self.stall_mark != self.pos_i:
                self.stall_mark = self.pos_i
                run.acts[s].note_stall()
            self.wait_reason = ("credit", run.acts[s])
            return None
        return 0.0

    def idle_reason(self):
        """Why this stage's op queue is *empty*: the head hasn't sampled
        the token that schedules the next op yet, so the stage is starved
        on its input edge (the feedback stream for stage 0, the upstream
        act fifo otherwise).  None once the token stream closed — run
        drained, idleness isn't a wait.  The drivers consult this under
        tracing so source stages (embed) appear in
        ``stage_wait_s``/``per_stage_starve_ms`` instead of being
        silently absent (their queue is refilled and their feedback
        satisfied in the same head retirement, so the nonempty-queue wait
        path never fires for them)."""
        run = self.run
        if run.feedback.closed:
            return None
        src = run.feedback if self.s == 0 else run.acts[self.s - 1]
        return ("starve", src)

    def _task_for(self, kind: str, gid: int, pos: int, payload, rep: int):
        """Build the op body from in-hand inputs (``payload`` is the
        embedded/popped value) — shared by the normal dispatch path and
        failover replay, so a redo runs the exact math the lost op
        would have."""
        s, pipe = self.s, self.pipe
        g = self.run.groups[gid]
        desc = pipe.stage_descs[s]
        dev = pipe.stage_devices[s][rep]
        params = pipe.stage_params[s][rep]
        if desc.span is None:                             # lone embed / head
            prog = pipe._embed if desc.has_embed else pipe._head
            return (_run_stage, (prog, params, (payload,), dev))
        if desc.has_embed or desc.has_head:               # fused stage
            pre, dec = pipe._fused[(desc.has_embed, desc.has_head)]
        else:                                             # plain block stage
            pre, dec = pipe._block_prefill, pipe._block_decode
        if kind == "P":
            return (_run_stage_static_cap, (pre, params, payload, g.cap, dev))
        cache = self.caches[gid]
        return (_run_stage,
                (dec, params,
                 (cache, payload, jnp.asarray(pos, jnp.int32)), dev))

    def dispatch(self, op: Op, driver):
        s, S, run = self.s, self.S, self.run
        if self.redo:
            # replay of a lost op: inputs were saved at its original
            # dispatch; that dispatch's downstream reservation is still
            # outstanding, so no pop and no reserve here — retirement
            # fills the reorder hole under the original seq
            kind, gid, seq, pos, payload = self.redo.pop(0)
            self.inflight[gid] = self.inflight.get(gid, 0) + 1
            return self._task_for(kind, gid, pos, payload, op.rep)
        kind, gid, seq, pos = self.queue[self.pos_i]
        self.pos_i += 1
        g = run.groups[gid]
        if s == 0:                                        # embed
            if kind == "P":
                g.t_start = time.perf_counter()
                payload = jnp.asarray(g.tokens)
            else:
                seq_got, (gid_got, toks) = run.feedback.pop(1)[0]
                assert (seq_got, gid_got) == (seq, gid), \
                    f"feedback order broke: {(seq_got, gid_got)}!={(seq, gid)}"
                payload = toks
        else:
            seq_got, (gid_got, x) = run.acts[s - 1].pop_hold(1)[0]
            assert (seq_got, gid_got) == (seq, gid), \
                f"fifo order broke: {(seq_got, gid_got)}!={(seq, gid)}"
            op.releases.append((run.acts[s - 1], 1))
            payload = x
        if s < S - 1:
            run.acts[s].reserve(1)
        op.recover = (kind, gid, seq, pos, payload)
        self.inflight[gid] = self.inflight.get(gid, 0) + 1
        return self._task_for(kind, gid, pos, payload, op.rep)

    def retire(self, op: Op, result, engine: Engine) -> float:
        s, run = self.s, self.run
        out, t_done = result
        gid = run.gid_of[op.seq]
        self.done_count[gid] = self.done_count.get(gid, 0) + 1
        self.inflight[gid] = self.inflight.get(gid, 1) - 1
        desc = self.pipe.stage_descs[s]
        y = out
        if desc.span is not None:                         # cache stays
            y, cache = out                                # resident here
            self.caches[gid] = cache
        if desc.has_head:                                 # head: sample
            run.on_head(op, y, t_done, engine)
        else:
            engine.ordered_push(run.acts[s], op.seq, (gid, y), t_done)
        return t_done

    # -- failover & rebalance -----------------------------------------------
    def fail_replica(self, rep: int, driver, lost: list) -> None:
        """Replica ``rep`` died: remap its groups onto survivors, rebuild
        the resident cache slices that died with it (deterministic replay
        from prompt + fed-token history — bitwise what the dead replica
        held), and queue the drained in-flight ops for redo under their
        original sequence numbers.  No survivors -> `PipelineFailure`
        (the engine attaches its diagnostic bundle)."""
        from ..failures import PipelineFailure
        self.dead.add(rep)
        alive = [r for r in range(self.n_replicas) if r not in self.dead]
        if not alive:
            raise PipelineFailure(
                f"stage {self.name}: replica r{rep} was the last one — "
                f"nothing left to fail over to",
                stage=self.name, replica=rep)
        moved = [gid for gid in range(len(self.run.groups))
                 if self.rep_of(gid) == rep]
        for i, gid in enumerate(moved):
            self.rep_map[gid] = alive[i % len(alive)]
        for op in lost:
            kind, gid, seq, pos, payload = op.recover
            self.inflight[gid] = self.inflight.get(gid, 1) - 1
            self.redo.append((kind, gid, seq, pos, payload))
        for gid in moved:
            if gid in self.caches and self.done_count.get(gid, 0) > 0:
                self.caches[gid] = self.pipe._replay_cache(
                    self.run, self.run.groups[gid], self.s,
                    self.done_count[gid], self.rep_map[gid])
            else:
                self.caches.pop(gid, None)

    def migrate_gid(self, gid: int, to_rep: int) -> bool:
        """Move one group to another replica between its ops (straggler
        shedding): the resident cache slice is *copied* to the new
        owner's device — the source replica is alive, so no replay is
        needed — and routing flips.  Refused while the group has an op
        in flight anywhere at this stage."""
        if self.inflight.get(gid) or to_rep in self.dead:
            return False
        if self.rep_of(gid) == to_rep:
            return True
        self.rep_map[gid] = to_rep
        if gid in self.caches:
            self.caches[gid] = jax.device_put(
                self.caches[gid], self.pipe.stage_devices[self.s][to_rep])
        return True

    def shed_replica(self, rep: int, max_groups: int = 1) -> int:
        """Shift dispatch share off a slow replica: migrate up to
        ``max_groups`` of its idle groups to the least-loaded healthy
        peer.  Returns how many actually moved."""
        peers = [r for r in range(self.n_replicas)
                 if r not in self.dead and r != rep]
        if not peers:
            return 0
        n_groups = len(self.run.groups)
        moved = 0
        for gid in range(n_groups):
            if moved >= max_groups:
                break
            g = self.run.groups[gid]
            if self.rep_of(gid) != rep or gid not in self.caches \
                    or g.done is not None and g.done.all():
                continue
            load = {r: sum(1 for g2 in range(n_groups)
                           if self.rep_of(g2) == r) for r in peers}
            to = min(peers, key=lambda r: (load[r], r))
            if self.migrate_gid(gid, to):
                moved += 1
        return moved

    def describe(self) -> str:
        return describe_position(
            self.name, self.pos_i, self.queue,
            lambda q: f"{q[0]}(gid={q[1]},seq={q[2]})")


def _run_stage(fn, params, args, dev):
    """Dispatch one stage program and return without a host sync: the
    engine retires the op off the watch set's completion future.  Watch
    the first output leaf only — a block stage's (hidden, cache) pair
    materialises together (one executable), and the resident cache slice
    is rebound at retirement, after that future fires."""
    args = tuple(jax.device_put(a, dev) if hasattr(a, "shape") else a
                 for a in args)
    out = fn(params, *args)
    return AsyncResult((out,), watch=jax.tree.leaves(out)[:1])


def _run_stage_static_cap(fn, params, x, cap, dev):
    x = jax.device_put(x, dev)
    out = fn(params, x, cap)
    return AsyncResult((out,), watch=jax.tree.leaves(out)[:1])


class _ServeRun:
    """Shared state of one pipelined serve: groups, channels, the global
    op sequence, and the head-side sampling/bookkeeping."""

    def __init__(self, pipe: "DecodePipeline", groups: list, *,
                 eos_id: int, capacity_blocks: int, overlap: bool,
                 temperature: float | None = None,
                 pause_at: int | None = None,
                 open_groups: int | None = None,
                 feedback_capacity: int | None = None):
        self.pipe = pipe
        self.groups = groups
        self.eos_id = eos_id
        self.temperature = temperature
        self.pause_at = pause_at       # admission pause: groups reaching
        self.parked: list[int] = []    # this many decode steps park (their
        #                                caches stay resident for export)
        #                                instead of feeding back
        self.gid_of: list[int] = []            # seq -> gid
        self.programs = [_ServeStageProgram(s, pipe, self)
                         for s in range(len(pipe.stage_names))]
        S = len(self.programs)
        self.acts = [pipe._edge_fifo(s, capacity_blocks, overlap)
                     for s in range(S - 1)]
        # the continuous token stream: head -> embed feedback.  At most
        # one token per live group is ever in flight (a group's next op
        # consumes it before its next push), so n_groups slots suffice.
        # The head pushes here *unconditionally* at retirement, which is
        # why `verify_decode_plan` requires capacity >= n_groups — an
        # override below that statically fails preflight.
        fb_cap = feedback_capacity if feedback_capacity is not None \
            else max(2, len(groups))
        self.feedback = StreamChannel(block=1, capacity_blocks=1,
                                      min_capacity=fb_cap)
        self.open_groups = len(groups) if open_groups is None else open_groups

    def enqueue(self, kind: str, gid: int, pos: int) -> int:
        seq = len(self.gid_of)
        self.gid_of.append(gid)
        for p in self.programs:
            p.enqueue(kind, gid, seq, pos)
        return seq

    def on_head(self, op: Op, logits, t_done: float, engine: Engine) -> None:
        """Sample at head retirement and schedule the group's next decode
        step (or retire the group) — `LMServer.serve_round` bookkeeping,
        verbatim, so completions are token-identical."""
        g = self.groups[self.gid_of[op.seq]]
        nxt = np.asarray(self.pipe._sample(logits, g.gid, self.temperature))
        if op.kind == "P":
            g.t_prefill_done = t_done - engine.t0
            g.cur = nxt.astype(np.int32)
            for i in range(g.batch):
                g.out_tokens[i] = [int(nxt[i])]
            g.done = np.array([t[0] == self.eos_id for t in g.out_tokens])
        else:
            g.steps += 1
            g.decode_done_s.append(t_done - engine.t0)
            for i in range(g.batch):
                if not g.done[i] and g.steps < g.budget[i]:
                    tok = int(nxt[i])
                    g.out_tokens[i].append(tok)
                    if tok == self.eos_id:
                        g.done[i] = True
                elif not g.done[i]:
                    g.done[i] = True
            g.cur = nxt.astype(np.int32)
        if (not g.done.all()) and g.steps < g.budget.max() - 1:
            if self.pause_at is not None and g.steps >= self.pause_at:
                # admission pause: park the group instead of feeding its
                # token back — caches stay resident for the rescale
                # export, g.cur is the un-fed token resume() re-feeds
                self.parked.append(g.gid)
                self.open_groups -= 1
                if self.open_groups == 0:
                    self.feedback.close()
            else:
                seq = self.enqueue("D", g.gid, g.bucket + g.steps)
                g.fed.append(g.cur.copy())
                self.feedback.push([(seq, (g.gid, g.cur[:, None]))], t_done)
        else:
            g.t_last = t_done - engine.t0
            for p in self.programs:            # free the group's resident
                p.caches.pop(g.gid, None)      # cache slices immediately
            self.open_groups -= 1
            if self.open_groups == 0:
                self.feedback.close()


@dataclass
class ResumeState:
    """Everything a drained, admission-paused serve hands the next
    pipeline: the group bookkeeping (prompts, budgets, sampled-token
    history, the un-fed ``cur`` token) and each block stage's resident
    cache slices keyed by the stage's period span.  A resuming pipeline
    whose stage spans match *transfers* the slices (device_put — the
    cheap path); mismatched spans are rebuilt by deterministic replay
    from prompt + fed-token history, so a rescale can change the stage
    partitioning without touching in-flight requests."""
    groups: list                       # _Group objects, indexed by gid
    group_of: list                     # request index -> gid
    eos_id: int
    stage_caches: dict = field(default_factory=dict)
    # stage name -> {"span": (lo, hi), "caches": {gid: cache pytree}}

    def live_groups(self) -> list:
        return [g for g in self.groups
                if g.done is not None and not g.done.all()
                and g.steps < g.budget.max() - 1]


# ===========================================================================
# the pipeline
# ===========================================================================
class DecodePipeline:
    """A placed serving pipeline: prefill + decode token streams through a
    planned, placed, replicated LM stage graph.

    ``stg``/``sel`` come from the planner on a decode shape
    (`as_selection` accepts the PlanResult directly);
    ``periods_per_stage`` groups adjacent block-pattern periods into one
    stage (the decode analogue of ``layers_per_stage``).  ``params``
    overrides the default `models/lm.init_params(cfg, PRNGKey(seed))` —
    pass the single-device server's params for A/B parity.  ``warmup``
    (default True) AOT-compiles every stage program for each group shape
    before the engine starts; ``compile_stats.late`` counts compiles
    that landed inside a timed serve (kept at zero by the default).

    ``fusion_plan``: planner-selected stage combining
    (`core.restructure`).  ``None`` runs every base stage as its own
    program (the historical layout); ``"auto"`` scores candidate fusions
    with `planner.plan_fusion`-equivalent rules on the analytic graph;
    an explicit plan is a contiguous partition of the base stage chain,
    e.g. ``[("embed", "blocks00"), ("blocks01",), ("blocks02",),
    ("blocks03", "head")]``.  A fused stage runs ONE AOT program for the
    member sequence — one host dispatch and one fewer FIFO hop per fused
    boundary — with the member math unchanged (bitwise token parity vs
    the unfused pipeline) and cache donation / KV-slice residency
    preserved per member.
    """

    def __init__(self, cfg: ModelConfig, stg: STG, sel, *,
                 devices=None, periods_per_stage: int = 1,
                 capacity_blocks: int = 2, seed: int = 0,
                 overlap: bool = True, replica_queue: int = 2,
                 workers: int | None = None, params=None,
                 temperature: float = 0.0, warmup: bool = True,
                 fusion_plan=None, impl: str | None = None):
        from . import as_selection
        sel = as_selection(sel)
        if cfg.encdec or cfg.frontend:
            raise ValueError(
                f"{cfg.name}: DecodePipeline runs embed->blocks->head "
                f"decoder pipelines only (enc-dec / multimodal frontends "
                f"are a ROADMAP item)")
        self.cfg = cfg
        self.stg = stg                 # kept for static verification
        self.sel = sel                 # (core.verify.verify_decode_plan)
        self.overlap = overlap
        self.replica_queue = max(1, replica_queue)
        self.workers = workers
        self.temperature = temperature
        self.impl = impl               # kernel tier for every stage program
        #                                (kernels.ops.resolve_impl; None =
        #                                auto, "ref" = historical A/B path)
        devices = list(devices if devices is not None else jax.devices())
        self._keys = {}
        self._base_key = jax.random.PRNGKey(seed ^ 0xC0FFEE)

        L = len(cfg.block_pattern)
        pps = max(1, periods_per_stage)
        graph_blocks = [n for n in stg.topo_order()
                        if n not in ("embed", "head")]
        if not all(n.startswith("block") for n in graph_blocks):
            raise ValueError(
                f"graph nodes {graph_blocks} are not decoder blocks: "
                f"DecodePipeline executes embed->blocks->head only")
        if len(graph_blocks) != cfg.n_layers:
            raise ValueError(
                f"graph has {len(graph_blocks)} block nodes but the model "
                f"has {cfg.n_layers} layers — plan and model disagree")

        params = params if params is not None \
            else lm.init_params(cfg, jax.random.PRNGKey(seed))
        self._init_params = params     # full tree (references, not copies):
        self.periods_per_stage = pps   # what elastic.rescale_serving needs
        self.seed = seed               # to rebuild this pipeline elsewhere
        head_w = params["embed"].T if cfg.tie_embeddings else params["head"]

        # stage list: embed, one per pps-period group, head — then the
        # fusion plan partitions that base chain into executed stages.
        # Each block-owning stage owns periods [a, b) == layers
        # [a*L, b*L); its params and its runtime cache are
        # `slice_periods` of the stacked pytrees.
        self.stage_names: list[str] = []
        self.stage_params: list[dict] = []     # stage -> {rep: pytree}
        self.stage_devices: list[list] = []
        self.period_span: list = []            # stage -> (lo, hi) or None
        pl = place(stg, sel, devices)
        self.placement = pl

        def owners_of(lo_p, hi_p):
            return [f"block{li:02d}" for li in range(lo_p * L, hi_p * L)]

        spans = [(a, min(a + pps, cfg.n_periods))
                 for a in range(0, cfg.n_periods, pps)]
        base = [("embed", None)] + [
            (f"blocks{idx:02d}", sp) for idx, sp in enumerate(spans)] \
            + [("head", None)]
        groups = self._resolve_fusion(base, fusion_plan, stg, sel)
        self.fusion_plan = (tuple(groups)
                            if any(len(g) > 1 for g in groups) else None)
        base_span = dict(base)
        self.stage_descs: list[_StageDesc] = []
        for grp in groups:
            m_spans = [base_span[m] for m in grp if base_span[m] is not None]
            span = (m_spans[0][0], m_spans[-1][1]) if m_spans else None
            self.stage_descs.append(_StageDesc(
                name="+".join(grp), members=tuple(grp),
                has_embed="embed" in grp, span=span,
                has_head="head" in grp))
        for desc in self.stage_descs:
            owners = ["embed"] if desc.has_embed else []
            if desc.span is not None:
                block_owners = owners_of(*desc.span)
                owners.extend(block_owners)
                picks = {sel.choices[o] for o in block_owners}
                if len(picks) > 1:
                    raise ValueError(
                        f"stage {desc.name} groups graph nodes "
                        f"{block_owners} whose plan choices differ "
                        f"({sorted(picks)}) — use periods_per_stage=1 "
                        f"or align the plan")
            if desc.has_head:
                owners.append("head")
            head_p = {"norm": params["final_norm"], "w": head_w}
            if desc.span is None:
                stage_p = ({"embed": params["embed"]} if desc.has_embed
                           else head_p)
            elif desc.has_embed or desc.has_head:
                # fused stage: member param trees keyed by role — the ONE
                # fused program reads them all (one dispatch for the
                # whole member sequence)
                stage_p = {"layers": lm.slice_periods(params["layers"],
                                                      *desc.span)}
                if desc.has_embed:
                    stage_p["embed"] = params["embed"]
                if desc.has_head:
                    stage_p.update(head_p)
            else:
                stage_p = lm.slice_periods(params["layers"], *desc.span)
            # replica pool: every member owner's placement slices (same
            # rule as jax_pipe — nr x n_owners copies, each doing the
            # whole fused stage's work, same planned capacity)
            slices = [sl for owner in owners for sl in pl.replicas_of(owner)]
            devs, reps = [], {}
            for k, sl in enumerate(slices):
                # decode stages are single-device jits: a tp>1 slice folds
                # onto its first device (plan replicas, not intra-stage
                # sharding, are what this backend executes)
                dev = sl.resolve(devices)[0]
                devs.append(dev)
                reps[k] = jax.device_put(stage_p, dev)
            if not devs:
                devs = [devices[0]]
                reps = {0: jax.device_put(stage_p, devices[0])}
            self.stage_names.append(desc.name)
            self.stage_devices.append(devs)
            self.stage_params.append(reps)
            self.period_span.append(desc.span)

        # one embed program serves prefill AND decode traffic (one compile
        # cache — the old pair of jax.jit instances of the same function
        # paid two compiles for identical math whenever avals coincided).
        # The block decode program DONATES its incoming cache slice
        # (argnum 1): each token step aliases the update onto the resident
        # buffers instead of allocating a fresh KV/SSM pytree per token
        # per stage — `models/lm.decode_blocks` guarantees the returned
        # cache matches the input structure leaf-for-leaf, so every leaf
        # aliases.  All programs are `aot.AotProgram`s: serve() precompiles
        # them against each group's concrete shapes before the engine's
        # clock starts (``warmup=`` is the escape hatch; late compiles are
        # counted in ``compile_stats.late``).
        self.warmup = warmup
        self.compile_stats = CompileStats()
        self._warmed: set = set()
        self._embed = AotProgram(_embed_prefill_fn(cfg), name="embed",
                                 stats=self.compile_stats)
        self._block_prefill = AotProgram(_block_prefill_fn(cfg, impl),
                                         name="block.prefill",
                                         stats=self.compile_stats,
                                         static_argnums=(2,))
        self._block_decode = AotProgram(_block_decode_fn(cfg, impl),
                                        name="block.decode",
                                        stats=self.compile_stats,
                                        donate_argnums=(1,))
        self._head = AotProgram(_head_fn(cfg), name="head",
                                stats=self.compile_stats)
        # fused-stage programs, one (prefill, decode) pair per signature
        # actually present in the plan.  The decode program donates the
        # member cache exactly like the plain block program — fusion
        # changes dispatch granularity, not the residency discipline.
        self._fused: dict = {}
        for desc in self.stage_descs:
            key = (desc.has_embed, desc.has_head)
            if desc.span is None or not any(key) or key in self._fused:
                continue
            tag = "+".join((["embed"] if key[0] else [])
                           + ["blocks"] + (["head"] if key[1] else []))
            self._fused[key] = (
                AotProgram(_fused_prefill_fn(cfg, *key, impl),
                           name=f"fused.{tag}.prefill",
                           stats=self.compile_stats, static_argnums=(2,)),
                AotProgram(_fused_decode_fn(cfg, *key, impl),
                           name=f"fused.{tag}.decode",
                           stats=self.compile_stats, donate_argnums=(1,)))

    def _resolve_fusion(self, base, fusion_plan, stg, sel):
        """Normalize ``fusion_plan`` to a contiguous partition of the base
        stage chain.  ``"auto"`` scores candidates on the analytic graph
        (`core.restructure.auto_fusion`): span-bearing block stages are
        ``heavy`` (they never fuse together — that axis is
        ``periods_per_stage``), so the scorer absorbs the stateless
        embed/head endpoints into their neighbours, minimizing host
        dispatches per token."""
        names = [n for n, _ in base]
        if fusion_plan is None:
            return [(n,) for n in names]
        if fusion_plan == "auto":
            from ...core import restructure
            L = len(self.cfg.block_pattern)
            dev, reps = {}, {}
            for name, span in base:
                owners = [name] if span is None else [
                    f"block{li:02d}"
                    for li in range(span[0] * L, span[1] * L)]
                dev[name] = sum(sel.impl_of(stg, o).ii for o in owners)
                reps[name] = min(sel.replicas(o) for o in owners)
            heavy = [n for n, sp in base if sp is not None]
            return [tuple(g) for g in restructure.auto_fusion(
                names, dev_us=dev, heavy=heavy, replicas=reps,
                dev_in_score=False).groups]
        groups = [(g,) if isinstance(g, str) else tuple(g)
                  for g in fusion_plan]
        flat = [n for g in groups for n in g]
        if flat != names:
            raise ValueError(
                f"fusion_plan {groups} is not a contiguous partition of "
                f"the stage chain {names}")
        return groups

    # -- sampling -----------------------------------------------------------
    def _sample(self, logits, gid: int, temperature: float | None = None):
        """Greedy by default (token-identical to the single-device
        server); temperature > 0 samples from a per-group key stream —
        statistically equivalent to, but not draw-identical with, the
        single-device server's single key stream."""
        t = self.temperature if temperature is None else temperature
        if t <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        key = self._keys.get(gid, jax.random.fold_in(self._base_key, gid))
        key, sub = jax.random.split(key)
        self._keys[gid] = key
        return jax.random.categorical(
            sub, logits[:, -1, :] / t, axis=-1).astype(jnp.int32)

    def _edge_fifo(self, s: int, capacity_blocks: int, overlap: bool) -> Fifo:
        # same slot accounting as the LM pipeline: reservations from
        # producer dispatch to consumer retirement, plus buffered slack
        prod = len(self.stage_devices[s])
        cons = len(self.stage_devices[s + 1])
        cons_devs = self.stage_devices[s + 1]

        def staging(tok):
            gid, y = tok
            check_not_donated(y, f"act edge {s}->{s + 1} (gid={gid})")
            return (gid, jax.device_put(y, cons_devs[gid % cons]))

        slots = (prod + cons) * self.replica_queue
        return Fifo(block=1, capacity_blocks=capacity_blocks,
                    min_capacity=capacity_blocks + slots,
                    prefetch_fn=staging if overlap else None,
                    prefetch_depth=cons * self.replica_queue)

    def _n_workers(self) -> int:
        if self.workers is not None:
            return max(1, self.workers)
        return min(16, max(2, sum(len(d) for d in self.stage_devices)))

    def _warm_group_shape(self, batch: int, bucket: int, cap: int) -> None:
        """AOT-compile every program one group shape class will execute —
        embed/head at prefill (B, bucket) and decode (B, 1) avals, block
        prefill with its static cap, block decode against the cache
        struct that prefill produces — on every replica's device, plus
        one greedy-sampler eager warm per head device.  Runs before the
        engine's clock starts; no served request ever sees a compile."""
        from jax.sharding import SingleDeviceSharding
        key = (batch, bucket, cap)
        if key in self._warmed:
            return
        cfg = self.cfg
        dt = dtype_of(cfg.compute_dtype)
        d = cfg.d_model
        for s, desc in enumerate(self.stage_descs):
            for rep, dev in enumerate(self.stage_devices[s]):
                sh = SingleDeviceSharding(dev)
                params = self.stage_params[s][rep]

                def sds(*shape, dtype=dt):
                    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

                if desc.span is None:
                    if desc.has_embed:
                        self._embed.precompile(params, sds(batch, bucket,
                                                           dtype=jnp.int32))
                        self._embed.precompile(params, sds(batch, 1,
                                                           dtype=jnp.int32))
                    else:
                        self._head.precompile(params, sds(batch, bucket, d))
                        self._head.precompile(params, sds(batch, 1, d))
                else:
                    if desc.has_embed or desc.has_head:
                        pre, dec = self._fused[(desc.has_embed,
                                                desc.has_head)]
                        xp = sds(batch, bucket, dtype=jnp.int32) \
                            if desc.has_embed else sds(batch, bucket, d)
                        xd = sds(batch, 1, dtype=jnp.int32) \
                            if desc.has_embed else sds(batch, 1, d)
                    else:
                        pre, dec = self._block_prefill, self._block_decode
                        xp, xd = sds(batch, bucket, d), sds(batch, 1, d)
                    pre.precompile(params, xp, cap)
                    _, cache_s = jax.eval_shape(
                        lambda p, x: pre.fn(p, x, cap), params, xp)
                    cache_sh = jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                       sharding=sh), cache_s)
                    dec.precompile(params, cache_sh, xd,
                                   sds(dtype=jnp.int32))
                if desc.has_head and (self.temperature or 0.0) <= 0.0:
                    # greedy sampling is eager jnp ops: execute once
                    # per device so the op cache is warm too
                    z = jax.device_put(
                        jnp.zeros((batch, 1, cfg.padded_vocab), dt), dev)
                    self._sample(z, gid=-1)
        self._warmed.add(key)

    def graph_stage_map(self) -> dict[str, str]:
        """graph node -> executed stage name (block nodes collapse onto
        the period-group stage that owns them) — the ``stage_map``
        `measure.compare_lm` needs to read a serve run's completion
        streams against the decode-shape plan."""
        L = len(self.cfg.block_pattern)
        out = {}
        for desc in self.stage_descs:
            if desc.has_embed:
                out["embed"] = desc.name
            if desc.span is not None:
                for li in range(desc.span[0] * L, desc.span[1] * L):
                    out[f"block{li:02d}"] = desc.name
            if desc.has_head:
                out["head"] = desc.name
        return out

    def _replay_cache(self, run: "_ServeRun", g: _Group, s_target: int,
                      k: int, new_rep: int):
        """Recompute stage ``s_target``'s resident cache slice for group
        ``g`` as it stood after ``k`` retired ops (prefill + k-1 decode
        steps), landing it on replica ``new_rep``'s device.

        The replay re-runs the same AOT executables the live traffic uses
        (embed -> preceding block stages -> target stage) from the
        prompt and the fed-token history, so on a deterministic platform
        the rebuilt slice is bitwise the one the dead replica held.
        Healthy stages are untouched: intermediate stages compute into
        *temporary* caches (their donated buffers are fresh allocations,
        never the resident slices), honoring the donation discipline."""
        gid = g.gid

        def par_dev(s):
            rep = new_rep if s == s_target else run.programs[s].rep_of(gid)
            return self.stage_params[s][rep], self.stage_devices[s][rep]

        def progs(desc):
            if desc.has_embed or desc.has_head:
                return self._fused[(desc.has_embed, desc.has_head)]
            return self._block_prefill, self._block_decode

        caches = {}
        x = jnp.asarray(g.tokens)
        for s in range(s_target + 1):
            desc = self.stage_descs[s]
            par, dev = par_dev(s)
            if desc.span is None:              # lone embed (head is last,
                x = self._embed(               # never precedes a target)
                    par, jax.device_put(x, dev))
                continue
            pre, _dec = progs(desc)
            x, caches[s] = pre(par, jax.device_put(x, dev), g.cap)
        for j in range(k - 1):
            x = jnp.asarray(g.fed[j][:, None])
            pos = jnp.asarray(g.bucket + j, jnp.int32)
            for s in range(s_target + 1):
                desc = self.stage_descs[s]
                par, dev = par_dev(s)
                if desc.span is None:
                    x = self._embed(par, jax.device_put(x, dev))
                    continue
                _pre, dec = progs(desc)
                x, caches[s] = dec(par, caches[s],
                                   jax.device_put(x, dev), pos)
        return caches[s_target]

    # -- serving ------------------------------------------------------------
    def serve(self, prompts: list[list[int]], max_new, *, eos_id: int = 1,
              group_size: int = 8, capacity_blocks: int = 2,
              overlap: bool | None = None,
              temperature: float | None = None,
              tracer=None, injector=None, health=None,
              pause_after_tokens: int | None = None,
              preflight: bool = True,
              feedback_capacity: int | None = None) -> ServeRunResult:
        """Serve ``prompts`` in ``group_size`` slot groups streamed
        concurrently through the pipeline.  Grouping, bucketing, and
        EOS/budget bookkeeping mirror `LMServer.serve_round` on each
        group, so a single-device server with ``max_batch=group_size``
        produces token-identical completions.  ``temperature`` overrides
        the pipeline-level default for this run.  ``tracer``: optional
        `trace.Tracer` — the serve emits op spans, credit/starve waits,
        and fifo occupancy (incl. the head->embed feedback stream);
        warmup stays untraced.  ``injector``: optional
        `failures.ReplicaFaultPlan` chaos schedule (see
        `fail_replica` for the failover semantics).  ``health``: optional
        `health.HealthController` ticked from the engine's retire path.
        ``pause_after_tokens``: admission pause — groups reaching that
        many decode steps park instead of scheduling further work; the
        returned result has ``paused=True`` and a ``resume_state`` that
        `resume()` (on this or a rescaled pipeline) continues without
        dropping any in-flight request.  ``preflight``: run the static
        plan verifier (`core.verify.verify_decode_plan`) before
        launching — channel/cycle credits, fusion legality, placement
        consistency, cache-donation avals — raising
        `PlanVerificationError` on any ERROR (False = escape hatch for
        deliberately unsafe experiments; the deadlock report will note
        preflight was skipped).  ``feedback_capacity``: override the
        head->embed stream's capacity (default ``max(2, n_groups)``) —
        mainly for demonstrating that an undersized feedback path is
        rejected statically."""
        if not prompts:
            raise ValueError("serve() needs at least one prompt")
        overlap = self.overlap if overlap is None else overlap
        if isinstance(max_new, int):
            max_new = [max_new] * len(prompts)
        if len(max_new) != len(prompts):
            raise ValueError("max_new must be a scalar or match prompts")
        groups: list[_Group] = []
        group_of: list[int] = []
        for gid, lo in enumerate(range(0, len(prompts), group_size)):
            chunk = prompts[lo:lo + group_size]
            budgets = np.array(max_new[lo:lo + group_size])
            plen = max(len(p) for p in chunk)
            bucket = _bucket(plen)
            # same capacity clamp as lm.prefill: SWA archs ring-buffer the
            # cache at the attention window — an unclamped cap would let
            # the pipeline attend further back than the single-device
            # server and break token parity on windowed configs
            cap = blocks.attn_cache_capacity(
                self.cfg, bucket + int(budgets.max()))
            toks = np.zeros((len(chunk), bucket), np.int32)
            for i, p in enumerate(chunk):          # right-align prompts so
                toks[i, bucket - len(p):] = p      # last token is real
            groups.append(_Group(
                gid=gid, tokens=toks, bucket=bucket, cap=cap,
                budget=budgets, out_tokens=[None] * len(chunk)))
            group_of.extend([gid] * len(chunk))

        report = None
        if preflight:
            report = self._preflight(
                n_groups=len(groups), capacity_blocks=capacity_blocks,
                feedback_capacity=feedback_capacity,
                group_shapes=[(g.batch, g.bucket, g.cap) for g in groups])

        if self.warmup:
            for g in groups:
                self._warm_group_shape(g.batch, g.bucket, g.cap)

        run = _ServeRun(self, groups, eos_id=eos_id,
                        capacity_blocks=capacity_blocks, overlap=overlap,
                        temperature=temperature,
                        pause_at=pause_after_tokens,
                        feedback_capacity=feedback_capacity)
        for g in groups:
            run.enqueue("P", g.gid, 0)
        res, engine = self._launch(run, group_of, overlap=overlap,
                                   tracer=tracer, injector=injector,
                                   health=health, static_report=report)
        for g in groups:                       # run-relative group timings
            g.t_start = max(0.0, g.t_start - engine.t0)
        return res

    def _preflight(self, *, n_groups: int, capacity_blocks: int,
                   feedback_capacity: int | None, group_shapes):
        """Static verification of this serve's plan tuple; raises
        `core.verify.PlanVerificationError` on any ERROR and caches the
        accepted report (donation avals don't change per serve) on
        ``self.last_preflight``."""
        from ...core import verify as _verify
        key = (n_groups, capacity_blocks, feedback_capacity,
               frozenset(group_shapes))
        cached = getattr(self, "_preflight_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1].raise_if_errors("DecodePipeline.serve")
        report = _verify.verify_decode_plan(
            self, n_groups=n_groups, capacity_blocks=capacity_blocks,
            feedback_capacity=feedback_capacity, group_shapes=group_shapes)
        self._preflight_cache = (key, report)
        self.last_preflight = report
        return report.raise_if_errors("DecodePipeline.serve")

    def _launch(self, run: "_ServeRun", group_of: list, *, overlap: bool,
                tracer, injector, health,
                static_report=None) -> tuple[ServeRunResult, Engine]:
        """Wire channels, drive the engine to quiescence, fold the
        engine result into a `ServeRunResult` (exporting a `ResumeState`
        when the run admission-paused) — shared by `serve` and
        `resume`."""
        names = self.stage_names
        fifo_map = {f"act{s}": run.acts[s] for s in range(len(run.acts))}
        fifo_map["feedback"] = run.feedback
        if tracer is not None:
            for s in range(len(run.acts)):
                tracer.watch_fifo(run.acts[s], f"act{s}",
                                  src=names[s], dst=names[s + 1])
            tracer.watch_fifo(run.feedback, "feedback",
                              src=names[-1], dst=names[0])
        engine = Engine(run.programs, overlap=overlap,
                        workers=self._n_workers(),
                        replica_queue=self.replica_queue,
                        tracer=tracer, fifos=fifo_map, injector=injector,
                        on_tick=None if health is None else health.tick,
                        tick_every=64 if health is None
                        else health.check_every,
                        static_report=static_report)
        with self.compile_stats.window():
            er = engine.run()
        assert run.feedback.exhausted, \
            "token stream not drained: a group retired with tokens in flight"

        res = ServeRunResult(
            tokens=[], group_of=group_of, groups=run.groups,
            stage_done_s=er.stage_done_s, stage_seconds=er.stage_seconds,
            stage_firings=er.stage_firings,
            stage_dispatch_s=er.stage_dispatch_s, op_trace=er.op_trace,
            max_inflight=er.max_inflight, wall_s=er.wall_s,
            stage_wait_s=er.stage_wait_s, failovers=er.failovers,
            placement=self.placement)
        idx_in_group: dict[int, int] = {}
        for gid in group_of:
            i = idx_in_group.get(gid, 0)
            idx_in_group[gid] = i + 1
            res.tokens.append(run.groups[gid].out_tokens[i])
        for s in range(len(run.acts)):
            res.fifo_stats[("act", s)] = run.acts[s].stats
        res.fifo_stats["feedback"] = run.feedback.stats
        if run.parked:
            res.paused = True
            res.resume_state = ResumeState(
                groups=run.groups, group_of=list(group_of),
                eos_id=run.eos_id,
                stage_caches={
                    names[s]: {"span": self.period_span[s],
                               "caches": dict(run.programs[s].caches)}
                    for s in range(len(names))
                    if self.period_span[s] is not None})
        return res, engine

    def resume(self, state: ResumeState, *, capacity_blocks: int = 2,
               overlap: bool | None = None,
               temperature: float | None = None, tracer=None,
               injector=None, health=None,
               pause_after_tokens: int | None = None,
               preflight: bool = True,
               feedback_capacity: int | None = None) -> ServeRunResult:
        """Continue an admission-paused serve on THIS pipeline — possibly
        a different plan, partitioning, or device pool than the one that
        drained (`elastic.rescale_serving` builds that pipeline).  Live
        groups' cache slices are adopted: *transferred* (device_put)
        when this pipeline's stage spans match the exporter's, rebuilt
        by deterministic replay from prompt + fed-token history when
        they don't.  Each group's parked token is fed back and decoding
        continues, so no in-flight request is dropped and the combined
        streams are bitwise what an uninterrupted serve yields."""
        overlap = self.overlap if overlap is None else overlap
        live = state.live_groups()
        if not live:
            raise ValueError("resume() on a state with no live groups")
        report = None
        if preflight:
            # the channel is sized for every exported group (finished
            # ones hold no tokens), but only live groups circulate
            fb_cap = feedback_capacity if feedback_capacity is not None \
                else max(2, len(state.groups))
            report = self._preflight(
                n_groups=len(live), capacity_blocks=capacity_blocks,
                feedback_capacity=fb_cap,
                group_shapes=[(g.batch, g.bucket, g.cap) for g in live])
        if self.warmup:
            for g in live:
                self._warm_group_shape(g.batch, g.bucket, g.cap)
        run = _ServeRun(self, state.groups, eos_id=state.eos_id,
                        capacity_blocks=capacity_blocks, overlap=overlap,
                        temperature=temperature,
                        pause_at=pause_after_tokens,
                        open_groups=len(live),
                        feedback_capacity=feedback_capacity)
        S = len(self.stage_names)
        by_span = {tuple(v["span"]): v["caches"]
                   for v in state.stage_caches.values()}
        for s in range(S):
            prog = run.programs[s]
            span = self.period_span[s]
            donors = by_span.get(tuple(span)) if span is not None else None
            for g in live:
                k = 1 + g.steps        # every stage retired prefill +
                prog.done_count[g.gid] = k     # g.steps decode ops
                if span is None:
                    continue
                if donors is not None and g.gid in donors:
                    prog.caches[g.gid] = jax.device_put(
                        donors[g.gid],
                        self.stage_devices[s][prog.rep_of(g.gid)])
                else:
                    prog.caches[g.gid] = self._replay_cache(
                        run, g, s, k, prog.rep_of(g.gid))
        for g in live:
            seq = run.enqueue("D", g.gid, g.bucket + g.steps)
            g.fed.append(g.cur.copy())
            run.feedback.push([(seq, (g.gid, g.cur[:, None]))], 0.0)
        res, _engine = self._launch(run, state.group_of, overlap=overlap,
                                    tracer=tracer, injector=injector,
                                    health=health, static_report=report)
        return res
