"""Spatial streaming executor: run planned STGs as real pipelines.

Three layers (see README §runtime/pipeline):

  placement   — partition the device set into per-stage slices sized
                tp x replicas, round-robin fork/join routing, per-stage
                sub-meshes for tp-sharded stage params
  channels    — bounded two-level (host queue + on-device staging) FIFOs
                with backpressure; capacity bounds in-flight work
  execution   — `interpreter` (host/numpy, any functional STG) and
                `jax_pipe` (device-to-device LM pipeline, overlapped
                async dispatch, 1F1B schedule)
  measurement — `measure.compare` / `measure.compare_lm` line measured
                steady-state inverse throughput up against
                `core/throughput.analyze`; `measure.measured_replan`
                feeds it back into the solver
"""
from .channels import ChannelSet, Fifo, FifoStats
from .interpreter import PipelineRun, execute, execute_materialized
from .jax_pipe import (LMPipeline, LMPipelineResult, build_lm_stages,
                       selection_from_plan)
from .measure import (PipelineReport, StageMeasurement, calibrate, compare,
                      compare_lm, measured_replan)
from .placement import Placement, StageSlice, place, tp_of
from .schedule import (fill_drain, fill_drain_bubble, max_live_activations,
                       one_f_one_b)

__all__ = [
    "ChannelSet", "Fifo", "FifoStats",
    "PipelineRun", "execute", "execute_materialized",
    "LMPipeline", "LMPipelineResult", "build_lm_stages", "selection_from_plan",
    "PipelineReport", "StageMeasurement", "calibrate", "compare",
    "compare_lm", "measured_replan",
    "Placement", "StageSlice", "place", "tp_of",
    "fill_drain", "fill_drain_bubble", "max_live_activations", "one_f_one_b",
]
