"""Spatial streaming executor: run planned STGs as real pipelines.

Layers (see README §runtime/pipeline):

  placement   — partition the device set into per-stage slices sized
                tp x replicas, round-robin fork/join routing, per-stage
                sub-meshes for tp-sharded stage params
  channels    — bounded two-level (host queue + on-device staging) FIFOs
                with backpressure; capacity bounds in-flight work;
                `StreamChannel` adds open-ended token streams (decode
                feedback traffic)
  engine      — the graph-generic executor core: ONE `Program` protocol
                (op streams with ready/dispatch/retire semantics) and two
                drivers of it — the wall-clock asynchronous scheduler
                (`Engine`) and the virtual-clock discrete-event loop
                (`run_event_loop`) — owning FIFO credits, reorder
                buffers, replica busy budgets, completion timing, and
                deadlock diagnostics for every backend
  schedule    — schedules as first-class plan objects (`Schedule` /
                `SchedOp`): `fill_drain`, `one_f_one_b`,
                `interleaved_1f1b(p, m, v)` with analytic bubble models,
                plus `simulate_schedule` — the schedule executed as data
                under the virtual-clock driver
  backends    — `interpreter` (host/numpy, any functional STG),
                `jax_pipe` (device-to-device LM microbatch pipeline,
                overlapped async dispatch, 1F1B), and `decode`
                (prefill/decode serving with per-stage KV-cache residency
                and a token feedback stream)
  measurement — `measure.compare` / `measure.compare_lm` line measured
                steady-state inverse throughput up against
                `core/throughput.analyze` through one shared report
                builder; `measure.measured_replan` feeds one step back
                into the solver and `measure.replan_to_fixed_point`
                iterates the loop to convergence
  self-healing— `failures.ReplicaFaultPlan` injects deterministic
                (stage, replica) crashes/stalls into either driver;
                the engine fails over onto surviving replicas (lost ops
                replayed under their original sequence numbers, caches
                rebuilt from token history) or escalates a structured
                `PipelineFailure`; `health.HealthController` turns
                straggler detection into live rebalancing and replan
                advice; `elastic.rescale_serving` + `DecodePipeline`'s
                pause/resume rescale a serving pool under load without
                dropping in-flight requests
"""


def as_selection(plan):
    """The one plan -> executable-Selection materialisation rule.

    Accepts a `core.stg.Selection` (passed through), a solver
    ``TradeoffResult`` (its ``.selection``), or a planner ``PlanResult``
    (per-stage (impl, replicas) choices) — every executor entry point
    (`jax_pipe.LMPipeline` via `selection_from_plan`,
    `interpreter.execute`, `decode.DecodePipeline`) funnels through here
    instead of re-implementing the mapping.
    """
    from ...core.stg import Selection
    if isinstance(plan, Selection):
        return plan
    if hasattr(plan, "selection"):          # TradeoffResult
        return plan.selection
    sel = Selection()
    for sp in plan.stages:                  # PlanResult
        sel.set(sp.name, sp.impl, sp.replicas)
    return sel


from .aot import AotProgram, CompileStats, tree_add_program
from .channels import ChannelSet, Fifo, FifoStats, StreamChannel
from .engine import (AsyncResult, Driver, Engine, EngineResult, EventLoop,
                     EventLoopStats, Op, Program, StageProgram,
                     run_event_loop, steady_inverse)
from .schedule import (SchedOp, Schedule, ScheduleProgram, ScheduleRun,
                       fill_drain, fill_drain_bubble, interleaved_1f1b,
                       interleaved_bubble, max_live_activations,
                       max_live_by_chunk, one_f_one_b, schedule_programs,
                       simulate_schedule)
from .interpreter import PipelineRun, execute, execute_materialized
from .jax_pipe import (LMPipeline, LMPipelineResult, build_lm_stages,
                       selection_from_plan)
from .decode import DecodePipeline, ResumeState, ServeRunResult
from .health import HealthController
from .measure import (FixedPointResult, PipelineReport, StageMeasurement,
                      calibrate, compare, compare_lm, measured_bubble,
                      measured_replan, replan_to_fixed_point)
from .placement import Placement, StageSlice, place, tp_of
from .trace import FifoWatch, TraceEvent, Tracer
from .metrics import (BlameEntry, Counter, Gauge, Histogram, MetricsRegistry,
                      attribute_bottleneck, registry_from_trace, serving_slo,
                      stall_bottleneck)
from ..straggler import StragglerReport, detect_replica_stragglers
from ..failures import (FailureInjector, PipelineFailure, ReplicaFault,
                        ReplicaFaultPlan, ReplicaFaultSpec)

__all__ = [
    "as_selection",
    "AotProgram", "CompileStats", "tree_add_program",
    "ChannelSet", "Fifo", "FifoStats", "StreamChannel",
    "AsyncResult", "Driver", "Engine", "EngineResult", "EventLoop",
    "EventLoopStats", "Op",
    "Program", "StageProgram", "run_event_loop", "steady_inverse",
    "SchedOp", "Schedule", "ScheduleProgram", "ScheduleRun",
    "fill_drain", "fill_drain_bubble", "interleaved_1f1b",
    "interleaved_bubble", "max_live_activations", "max_live_by_chunk",
    "one_f_one_b", "schedule_programs", "simulate_schedule",
    "PipelineRun", "execute", "execute_materialized",
    "LMPipeline", "LMPipelineResult", "build_lm_stages", "selection_from_plan",
    "DecodePipeline", "ResumeState", "ServeRunResult",
    "HealthController",
    "FixedPointResult", "PipelineReport", "StageMeasurement", "calibrate",
    "compare", "compare_lm", "measured_bubble", "measured_replan",
    "replan_to_fixed_point",
    "Placement", "StageSlice", "place", "tp_of",
    "FifoWatch", "TraceEvent", "Tracer",
    "BlameEntry", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "attribute_bottleneck", "registry_from_trace", "serving_slo",
    "stall_bottleneck",
    "StragglerReport", "detect_replica_stragglers",
    "FailureInjector", "PipelineFailure", "ReplicaFault",
    "ReplicaFaultPlan", "ReplicaFaultSpec",
]
