"""Jax device-to-device pipeline for LM streaming task graphs.

Executes the planner's LM stage graph (`graphs/lm_graph.build_stg`: embed
-> block00.. -> head) as a real microbatch pipeline over jax devices:
every stage's parameters live on its placement slice — sharded over a
per-stage (1, tp) sub-mesh when the slice owns tp > 1 distinct devices
(`launch/mesh.stage_submeshes` + `launch/sharding.stage_param_specs`),
pinned to the slice's device otherwise — activations move between slices
with ``jax.device_put`` (device-to-device when the pool has distinct
devices; a no-op on a single-device pool, which then time-shares — the
placement layer reports the oversubscription), microbatches are dispatched
to stage replicas round-robin (the fork/join routing of
`core/transform.py` collapsed to its end-to-end effect), and execution
follows whatever `schedule.Schedule` object the caller passes.  Stage
bodies are built from `models/blocks.py`.

This module generates **no schedules**: ``run(schedule=...)`` consumes a
first-class `schedule.Schedule` (defaults: `schedule.one_f_one_b` for
train shapes, `schedule.fill_drain` for serving), and an interleaved
schedule (`schedule.interleaved_1f1b(p, m, v)`) runs ``v`` virtual-stage
chunks per physical program — op ``(kind, mb, chunk)`` executes built
model stage ``chunk * p + s`` — over the same linear activation/gradient
FIFO chain, shrinking the pipeline bubble for deep LM graphs while
keeping grads bitwise-equal to plain 1F1B and sequential autodiff.

The event loop itself lives in the graph-generic executor core
(`engine.Engine`, a driver of the one `engine.Program` protocol): this
module only defines *stage programs* — per-physical-stage
ready/dispatch/retire hooks for the scheduled forward and backward ops
(`_LMStageProgram`).  The engine owns FIFO credits, per-edge reorder
buffers, replica busy budgets, completion timing, and deadlock detection,
shared with the host interpreter and the decode serving pipeline.

Execution is *overlapped* by default (``overlap=True``): the engine never
blocks on an op — each firing is handed to a small worker pool that
dispatches the jax computation *and returns without a host sync*
(`engine.AsyncResult`); the engine retires ops off completion futures
(`jax.Array.is_ready`), so a worker launches the next op while the
previous one's transfer/compute is still in flight, a replicated stage's
microbatches run concurrently across its replica slices (measured
inverse throughput reads ii/nr, like the interpreter path), and the host
scheduling loop itself hides inside device compute.

The steady state is zero-copy and compile-free: stage programs are
`aot.AotProgram`s — ahead-of-time ``.lower(...).compile()`` executables
per (aval, sharding), precompiled against the run's concrete shapes
before the engine's clock starts (``warmup=``; `compile_stats.late`
counts what a disabled warmup lets land inside the window) — and
gradient accumulation is a donated in-place ``acc <- acc + p_bar``
program resident on each stage's ``grad_target()``, bitwise-equal to
the per-leaf host-driven adds it replaced.  The training vjp chain
keeps its eager `jax.vjp` call structure (the bitwise contract with
sequential-autodiff references) and warms by execution instead.
Inter-stage buffers are two-level host+device FIFOs (`channels.Fifo`): a
slot is occupied from producer *dispatch* to consumer *retirement*, so
channel capacity bounds total in-flight work per edge (bounded device
memory under backpressure), and queued activations are prefetched onto
the consumer's device slice up to ``prefetch_blocks`` ahead of
consumption — the transfer overlaps the consumer's current microbatch
(on-device double buffering) instead of serialising with its next one.
``overlap=False`` reproduces the legacy serial executor (dispatch, block,
advance) for A/B measurement; `benchmarks/bench_pipeline.py` reports the
recovered bubble.

Per-stage timing is sampled from completion events: each op timestamps
the moment its output became ready, and ``stage_inverse_us`` reads the
steady-state gap of the stage's merged completion stream — replicas
interleave, so a replicated stage measures its *effective* inverse
throughput, directly comparable to the plan's ii/nr.  The jax path is
therefore a valid calibration source: feed
``measure.compare_lm(...).ratios()`` into
``planner.replan(measured_ratio=...)`` exactly like interpreter-path
reports (remember measured ratios mix host-vs-roofline scale; the solver
consumes *relative* per-stage ratios).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import (NamedSharding, PartitionSpec as P,
                          SingleDeviceSharding)

from ...configs.base import ModelConfig
from ...core.stg import STG, Selection
from ...launch.mesh import submesh_of
from ...launch.sharding import ShardingPolicy, stage_param_shardings
from ...models import blocks
from ...models.common import KeyGen, dense_init, rmsnorm
from .aot import AotProgram, CompileStats, tree_add_program
from .channels import Fifo, check_not_donated
from .engine import AsyncResult, Engine, Op, describe_position, steady_inverse
from .placement import Placement, place
from .schedule import (SchedOp, Schedule, fill_drain, max_live_by_chunk,
                       one_f_one_b)


def selection_from_plan(plan) -> Selection:
    """PlanResult -> Selection over the lm_graph node names (delegates to
    the package-level `as_selection`, the single materialisation rule
    shared with the interpreter path)."""
    from . import as_selection
    return as_selection(plan)


# ===========================================================================
# stage construction (models/blocks)
# ===========================================================================
@dataclass
class LMStage:
    name: str
    fwd: object                  # (params, x) -> y: an `aot.AotProgram`
                                 # (drop-in for the jit it replaces —
                                 # traceable under vjp, AOT-compiled for
                                 # concrete serve-path calls)
    params: dict                 # replica index -> pytree on that slice
    devices: list                # replica index -> first jax.Device
    x_shardings: list = None     # replica index -> NamedSharding (tp-sharded
                                 # slices) or None (single-device placement)
    meshes: list = None          # replica index -> sub-mesh or None
    acc: object = None           # donated grad accumulator (aot.tree_add):
                                 # acc <- acc + p_bar in place on grad_target

    def x_target(self, rep: int):
        """Where replica ``rep``'s inputs must live: the sub-mesh's
        replicated sharding for tp-sharded slices, its device otherwise."""
        if self.x_shardings and self.x_shardings[rep] is not None:
            return self.x_shardings[rep]
        return self.devices[rep]

    def x_sharding(self, rep: int):
        """``x_target`` as a `Sharding` (for ShapeDtypeStruct lowering)."""
        tgt = self.x_target(rep)
        return tgt if isinstance(tgt, NamedSharding) \
            else SingleDeviceSharding(tgt)

    def grad_target(self):
        """Where accumulated grads live: replica 0's param shardings for a
        tp-sharded stage (grads shard like their params), its device
        otherwise."""
        if self.meshes and self.meshes[0] is not None:
            return jax.tree.map(lambda leaf: leaf.sharding, self.params[0])
        return self.devices[0]


@jax.custom_vjp
def _act_barrier(x):
    """A differentiable `optimization_barrier`: pins a fused-stage member
    boundary as a materialisation point so XLA cannot fuse across it and
    re-round bf16 activations — numerically exactly what the deleted
    fifo hop did.  The cotangent is barriered too (the staged backward
    pass materialises it at the same boundary), so fused grads stay
    bitwise-equal to the staged composition."""
    return jax.lax.optimization_barrier(x)


def _act_barrier_fwd(x):
    return _act_barrier(x), None


def _act_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_act_barrier.defvjp(_act_barrier_fwd, _act_barrier_bwd)


def _embed_fwd(cfg: ModelConfig):
    def fwd(p, tokens):
        return p["emb"][tokens].astype(jnp.bfloat16)
    return fwd


def _block_fwd(cfg: ModelConfig, mixers: tuple[tuple[str, str], ...]):
    def fwd(p, x):
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, axis=0)
        for li, (mixer, mlp) in enumerate(mixers):
            lp = p[f"l{li}"]
            if mixer == "attn":
                x = blocks.attn_forward(lp["mix"], cfg, x, positions)
            else:
                x = blocks.mamba_forward(lp["mix"], cfg, x)
            if mlp == "moe":
                x = blocks.moe_forward(lp["mlp"], cfg, x)
            else:
                x = blocks.mlp_forward(lp["mlp"], cfg, x)
        return x
    return fwd


def _head_fwd(cfg: ModelConfig):
    def fwd(p, x):
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        return (h @ p["w_out"].astype(h.dtype)).astype(jnp.float32)
    return fwd


def build_lm_stages(cfg: ModelConfig, *, layers_per_stage: int | None = None,
                    seed: int = 0) -> tuple[list[str], dict, dict]:
    """(stage names, fwd fns, init params) for embed / block groups / head.

    ``layers_per_stage`` groups adjacent layers into one pipeline stage
    (1 == the lm_graph granularity: one node per block).
    """
    kg = KeyGen(jax.random.PRNGKey(seed))
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    d = cfg.d_model
    pattern = cfg.block_pattern * (cfg.n_layers // len(cfg.block_pattern))
    lps = layers_per_stage or 1

    names, fwds, params = [], {}, {}
    names.append("embed")
    fwds["embed"] = _embed_fwd(cfg)
    params["embed"] = {"emb": dense_init(kg("emb"), (cfg.padded_vocab, d), dt)}

    for s0 in range(0, len(pattern), lps):
        mixers = tuple(pattern[s0:s0 + lps])
        name = f"block{s0 // lps:02d}"
        p = {}
        for li, (mixer, mlp) in enumerate(mixers):
            mix_p = (blocks.init_attn(kg, cfg, f"{name}.l{li}.mix")
                     if mixer == "attn"
                     else blocks.init_mamba(kg, cfg, f"{name}.l{li}.mix"))
            mlp_p = (blocks.init_moe(kg, cfg, f"{name}.l{li}.mlp")
                     if mlp == "moe"
                     else blocks.init_mlp(kg, cfg, f"{name}.l{li}.mlp"))
            p[f"l{li}"] = {"mix": mix_p, "mlp": mlp_p}
        names.append(name)
        fwds[name] = _block_fwd(cfg, mixers)
        params[name] = p

    names.append("head")
    fwds["head"] = _head_fwd(cfg)
    params["head"] = {"norm": jnp.ones((d,), jnp.float32),
                      "w_out": dense_init(kg("w_out"), (d, cfg.padded_vocab), dt)}
    return names, fwds, params


# ===========================================================================
# result type
# ===========================================================================
@dataclass
class LMPipelineResult:
    outputs: list                           # microbatch logits (serve runs;
                                            # train runs release them at B
                                            # and fill ``losses`` instead)
    losses: dict = field(default_factory=dict)    # mb -> loss value (train)
    stage_seconds: dict[str, float] = field(default_factory=dict)
    stage_firings: dict[str, int] = field(default_factory=dict)
    stage_done_s: dict[str, list[float]] = field(default_factory=dict)
    stage_dispatch_s: dict[str, float] = field(default_factory=dict)
    mb_done_s: list[float] = field(default_factory=list)
    wall_s: float = 0.0
    placement: Placement | None = None
    grads: dict | None = None               # stage -> pytree (train runs)
    fifo_stats: dict = field(default_factory=dict)   # edge label -> FifoStats
    stage_wait_s: dict = field(default_factory=dict)
    # stage -> {reason: seconds blocked} (traced runs only): "credit" =
    # output fifo full (downstream slow), "starve" = input empty
    # (upstream slow), "reorder"/"dep" = ordering, not capacity
    max_inflight: int = 0                   # peak concurrently in-flight ops
    op_trace: list = field(default_factory=list)
    # (stage, kind, mb, replica, t_dispatch, t_done) per op, run-relative —
    # the raw material for overlap debugging and gantt-style bench plots

    def stage_inverse_us(self, name: str) -> float:
        """Effective microseconds per forward firing of one stage: the
        steady-state gap of the stage's merged completion-event stream
        (`engine.steady_inverse`).  Replicas interleave under overlapped
        dispatch, so a replicated stage reads ii/nr — directly comparable
        to the analytic plan (and to the interpreter path's
        ``stage_inverse_throughput``).

        Runs too short to show a steady state (< 4 forward completions)
        fall back to mean in-flight latency per op — an
        order-of-magnitude degraded mode that mixes forward and backward
        ops *and* dispatch-queue wait (overlapping ops can sum past wall
        time).  ``compare_lm`` skips such stages rather than calibrating
        on the fallback."""
        try:
            return steady_inverse(self.stage_done_s.get(name, ())) * 1e6
        except ValueError:
            n = self.stage_firings.get(name, 0)
            return self.stage_seconds[name] / n * 1e6 if n else float("nan")

    def stage_host_us(self, name: str) -> float:
        """Host-side dispatch microseconds per firing (wall time the
        stage's op bodies spent issuing transfers and dispatching
        programs) — the overhead component `measure.compare_lm` surfaces
        as its own column instead of folding into stage II."""
        n = self.stage_firings.get(name, 0)
        return (self.stage_dispatch_s.get(name, 0.0) / n * 1e6
                if n else float("nan"))

    def tokens_per_s(self, toks_per_mb: int) -> float:
        """Steady-state tokens/s from inter-microbatch completion gaps.
        Short runs (< 3 completed microbatches) still exclude the pipeline
        fill ramp by anchoring at the first completion instead of dividing
        by the full wall clock."""
        if len(self.mb_done_s) >= 3:
            k = max(1, len(self.mb_done_s) // 4)
            window = self.mb_done_s[k:]
            if len(window) >= 2 and window[-1] > window[0]:
                return toks_per_mb * (len(window) - 1) / (window[-1] - window[0])
        if len(self.mb_done_s) >= 2 and self.mb_done_s[-1] > self.mb_done_s[0]:
            span = self.mb_done_s[-1] - self.mb_done_s[0]
            return toks_per_mb * (len(self.mb_done_s) - 1) / span
        return toks_per_mb * len(self.mb_done_s) / max(self.wall_s, 1e-9)


# ===========================================================================
# op bodies (run on the engine's dispatch pool under overlap).  Bodies
# DISPATCH device work and return immediately (`engine.AsyncResult`):
# the engine retires the op when the watch set reports ready, so a
# worker is free to launch the next op while this one's transfer/compute
# is still in flight.  Watch one representative output per executable —
# an executable's outputs materialise together.
# ===========================================================================
def _fwd_op(st: LMStage, rep: int, x, train: bool):
    x = jax.device_put(x, st.x_target(rep))
    if train:
        # traced path: AotProgram falls through to its jit, keeping the
        # vjp call structure (and grads) bitwise-identical to sequential
        # autodiff references built from the same stage fns
        y, vjp = jax.vjp(st.fwd, st.params[rep], x)
    else:
        y, vjp = st.fwd(st.params[rep], x), None
    return AsyncResult((y, vjp), watch=[y])


def _bwd_op(st: LMStage, rep: int, vjp, y_bar, logits, loss_fn):
    lval = None
    if logits is not None:            # last stage: seed from loss
        if loss_fn:
            lval, y_bar = jax.value_and_grad(loss_fn)(logits)
        else:
            y_bar = jnp.ones_like(logits)
    else:
        y_bar = jax.device_put(y_bar, st.x_target(rep))
    p_bar, x_bar = vjp(y_bar)
    watch = [x_bar, jax.tree.leaves(p_bar)[-1]]
    if lval is not None:
        watch.append(lval)
    return AsyncResult((p_bar, x_bar, lval), watch=watch)


# ===========================================================================
# stage program: one pipeline stage's schedule on the shared engine
# ===========================================================================
class _LMStageProgram:
    """Ready/dispatch/retire hooks for one *physical* stage's scheduled
    F/B ops — an `engine.Program`.

    A physical stage executes one or more virtual-stage *chunks*: op
    ``(kind, mb, chunk)`` runs built model stage ``chunks[chunk]``
    (plain schedules have exactly one chunk, the identity case).  Both F
    and B ops reach each model-stage edge in microbatch order, so each
    inter-stage fifo's head is always the next scheduled microbatch —
    consumers pop the head directly; out-of-order replica completions
    are re-sorted by the engine's per-edge reorder buffer.
    """

    def __init__(self, s: int, pipe: "LMPipeline", ops: list, *,
                 chunks: list[int], acts: list, grds: list | None,
                 res: LMPipelineResult, microbatches: list, train: bool,
                 loss_fn, grads: dict | None, raw_losses: dict):
        self.s = s
        self.M = pipe.n_stages              # built model stages
        self.pipe = pipe
        self.chunks = chunks                # chunk c -> built stage index
        self.stages = [pipe.stages[i] for i in chunks]
        self.name = (self.stages[0].name if len(chunks) == 1 else
                     "+".join(st.name for st in self.stages))
        self.n_replicas = max(len(st.devices) for st in self.stages)
        self.ops = ops                      # list[SchedOp]
        self.pos = 0
        self.stall_mark = -1
        self.wait_reason = None   # (reason, fifo) of the last deferral
        self.acts = acts
        self.grds = grds
        self.res = res
        self.microbatches = microbatches
        self.train = train
        self.loss_fn = loss_fn
        self.grads = grads
        self.raw_losses = raw_losses
        self.vjps: dict[tuple[int, int], object] = {}   # (built, mb)
        # in-flight-activation ceilings per chunk, from the schedule
        # itself (chunk-aware max_live) — the runtime assert that catches
        # a driver mis-ordering ops against the schedule's memory promise
        self.live_bound = max_live_by_chunk(ops)
        self._live = {c: 0 for c in self.live_bound}
        # deterministic grad accumulation: p_bars fold in microbatch order
        # per built stage regardless of which replica retires first
        self.acc_next = {i: 0 for i in chunks}
        self.acc_buf = {i: {} for i in chunks}

    def pending(self) -> int:
        return len(self.ops) - self.pos

    def peek(self) -> Op | None:
        if self.pos >= len(self.ops):
            return None
        k = self.ops[self.pos]
        st = self.stages[k.chunk]
        return Op(stage=self.s, kind=k.kind, seq=k.mb, chunk=k.chunk,
                  rep=k.mb % len(st.devices), is_firing=(k.kind == "F"))

    def ready(self, op: Op, count_stall: bool = False) -> float | None:
        """None while blocked on tokens/credits; counts a producer stall
        the first time a given op is deferred purely by output-buffer
        backpressure.  Each None leaves a ``wait_reason`` breadcrumb —
        (reason, blocking fifo) — the tracing driver turns into
        stall/starve attribution."""
        i, M, mb = self.chunks[op.chunk], self.M, op.seq
        if op.kind == "F":
            if i > 0 and not self.acts[i - 1].can_pop(1):
                self.wait_reason = ("starve", self.acts[i - 1])
                return None
            if i < M - 1 and not self.acts[i].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.acts[i].note_stall()
                self.wait_reason = ("credit", self.acts[i])
                return None               # backpressure: skip this turn
        else:
            if (i, mb) not in self.vjps:
                self.wait_reason = ("dep", None)
                return None               # forward still in flight
            if i < M - 1 and not self.grds[i].can_pop(1):
                self.wait_reason = ("starve", self.grds[i])
                return None
            if i > 0 and not self.grds[i - 1].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.grds[i - 1].note_stall()
                self.wait_reason = ("credit", self.grds[i - 1])
                return None
        return 0.0

    def dispatch(self, op: Op, driver):
        i, M, mb = self.chunks[op.chunk], self.M, op.seq
        st = self.stages[op.chunk]
        rep = mb % len(st.devices)
        if op.kind == "F":
            if i == 0:
                x = self.microbatches[mb]
            else:
                mb_got, x = self.acts[i - 1].pop_hold(1)[0]
                assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                op.releases.append((self.acts[i - 1], 1))
            if i < M - 1:
                self.acts[i].reserve(1)
            task = (_fwd_op, (st, rep, x, self.train))
        else:
            if i == M - 1:
                logits, y_bar = self.res.outputs[mb], None
                # release the vocab-sized tensor: 1F1B exists to bound
                # live activations, so don't hoard logits
                self.res.outputs[mb] = None
            else:
                mb_got, y_bar = self.grds[i].pop_hold(1)[0]
                assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                op.releases.append((self.grds[i], 1))
                logits = None
            if i > 0:
                self.grds[i - 1].reserve(1)
            self._live[op.chunk] -= 1
            task = (_bwd_op, (st, rep, self.vjps.pop((i, mb)), y_bar,
                              logits, self.loss_fn))
        self.pos += 1
        return task

    def retire(self, op: Op, result, engine: Engine) -> float:
        i, M = self.chunks[op.chunk], self.M
        st = self.stages[op.chunk]
        if op.kind == "F":
            y, vjp, t_done = result
            if self.train:
                self.vjps[(i, op.seq)] = vjp
                self._live[op.chunk] += 1
                assert self._live[op.chunk] <= self.live_bound[op.chunk], \
                    (f"{self.name}: chunk {op.chunk} holds "
                     f"{self._live[op.chunk]} live activations, schedule "
                     f"promised {self.live_bound[op.chunk]}")
            if i < M - 1:
                engine.ordered_push(self.acts[i], op.seq, y, t_done)
            else:
                self.res.outputs[op.seq] = y
                self.res.mb_done_s.append(t_done - engine.t0)
        else:
            p_bar, x_bar, lval, t_done = result
            if i > 0:
                engine.ordered_push(self.grds[i - 1], op.seq, x_bar, t_done)
            if lval is not None:
                self.raw_losses[op.seq] = lval
            buf, nxt = self.acc_buf[i], self.acc_next
            buf[op.seq] = p_bar
            while nxt[i] in buf:
                pb = buf.pop(nxt[i])
                nxt[i] += 1
                pb = jax.device_put(pb, st.grad_target())
                # donated in-place accumulate: ONE compiled program whose
                # output aliases the resident acc buffer (st.acc), not a
                # host-driven per-leaf dispatch allocating a fresh pytree
                # per microbatch — bitwise-identical fold order
                self.grads[st.name] = (
                    pb if self.grads[st.name] is None else
                    st.acc(self.grads[st.name], pb))
        return t_done

    def describe(self) -> str:
        return describe_position(self.name, self.pos, self.ops,
                                 SchedOp.describe)


# ===========================================================================
# pipeline assembly + execution
# ===========================================================================
class LMPipeline:
    """A placed, compiled LM pipeline ready to stream microbatches.

    ``overlap`` selects the asynchronous executor (concurrent replica
    dispatch + on-device prefetch; the default); ``prefetch_blocks`` is
    how many queued activations each channel stages onto the consumer's
    device slice ahead of consumption; ``workers`` caps the dispatch pool
    (default: one per replica slice, at most 16).  ``schedule`` is the
    default `schedule.Schedule` object ``run`` executes (per-run
    ``schedule=`` overrides it; None picks `schedule.one_f_one_b` for
    training and `schedule.fill_drain` for serving) — schedules are
    plan data, never generated here.  ``warmup`` (default True)
    precompiles every program a run shape needs before the engine's
    clock starts; ``compile_stats`` reports compiles and the ``late``
    count (compiles that landed inside a timed window).
    """

    def __init__(self, cfg: ModelConfig, stg: STG, sel: Selection, *,
                 devices=None, layers_per_stage: int | None = None,
                 capacity_blocks: int = 2, seed: int = 0,
                 overlap: bool = True, prefetch_blocks: int = 1,
                 replica_queue: int = 2, workers: int | None = None,
                 policy: ShardingPolicy | None = None,
                 schedule: Schedule | None = None, warmup: bool = True,
                 fusion_plan=None):
        self.cfg = cfg
        self.schedule = schedule
        self.stg = stg                 # kept for static verification
        self.sel = sel                 # (core.verify.verify_lm_plan)
        devices = list(devices if devices is not None else jax.devices())
        names, fwds, init_params = build_lm_stages(
            cfg, layers_per_stage=layers_per_stage, seed=seed)
        self.placement = place(stg, sel, devices)
        self.overlap = overlap
        self.prefetch_blocks = prefetch_blocks
        self.replica_queue = max(1, replica_queue)
        self.warmup = warmup
        self.compile_stats = CompileStats()
        self._warmed: set = set()
        policy = policy or ShardingPolicy(fsdp=False, tp=True)
        # map lm_graph node names onto built stages: embed/head by name,
        # blockNN graph nodes collapse onto the built group that owns them
        # (topological, not lexicographic: block100 sorts before block11)
        graph_blocks = [n for n in stg.topo_order()
                        if n not in ("embed", "head")]
        built_blocks = [n for n in names if n not in ("embed", "head")]
        lps = layers_per_stage or 1
        # every graph node must land in exactly one built stage, or the
        # pipeline would silently run less model than the plan placed
        # (e.g. enc-dec graphs emit encNN nodes no decoder stage claims)
        if len(graph_blocks) != sum(
                len(graph_blocks[i * lps:(i + 1) * lps])
                for i in range(len(built_blocks))) or not all(
                n.startswith("block") for n in graph_blocks):
            raise ValueError(
                f"graph nodes {graph_blocks} do not map 1:1 onto the "
                f"{len(built_blocks)} built decoder stages x "
                f"{lps} layer(s): LMPipeline executes embed->blocks->head "
                f"only (encoder/decoder pipelines are a ROADMAP item)")
        self.stages: list[LMStage] = []
        for name in names:
            if name in ("embed", "head"):
                owners = [name]
            else:
                # built stage i holds layers [i*lps, (i+1)*lps) — slice the
                # per-layer graph nodes with the same arithmetic (floor
                # division over-counts when lps does not divide n_layers)
                i = built_blocks.index(name)
                owners = graph_blocks[i * lps:(i + 1) * lps]
                if not owners:
                    raise ValueError(
                        f"stage {name}: no graph nodes map to it — the "
                        f"graph/built-stage invariant above broke")
                picks = {sel.choices[o] for o in owners}
                if len(picks) > 1:
                    raise ValueError(
                        f"stage {name} groups graph nodes {owners} whose "
                        f"plan choices differ ({sorted(picks)}) — the "
                        f"executor would drop replicas the plan promised; "
                        f"use layers_per_stage=1 or align the plan")
            # a fused stage does the work of all its owners' graph nodes;
            # use every owner's replica slices (nr x n_owners copies, each
            # doing n_owners layers of work -> same planned capacity) so
            # the plan's device budget is not silently idled
            slices = [sl for owner in owners
                      for sl in self.placement.replicas_of(owner)]
            devs, meshes, x_shs, reps = [], [], [], {}
            for k, sl in enumerate(slices):
                handles = sl.resolve(devices)
                mesh = submesh_of(handles)
                devs.append(handles[0])
                meshes.append(mesh)
                if mesh is not None:
                    # tp > 1 on distinct devices: shard the stage's params
                    # over its slice instead of parking them on handles[0]
                    sh = stage_param_shardings(name, init_params[name],
                                               mesh, cfg, policy)
                    reps[k] = jax.device_put(init_params[name], sh)
                    x_shs.append(NamedSharding(mesh, P()))
                else:
                    reps[k] = jax.device_put(init_params[name], handles[0])
                    x_shs.append(None)
            if not devs:
                devs, meshes, x_shs = [devices[0]], [None], [None]
                reps = {0: jax.device_put(init_params[name], devices[0])}
            self.stages.append(LMStage(
                name=name,
                fwd=AotProgram(fwds[name], name=f"{name}.fwd",
                               stats=self.compile_stats),
                params=reps, devices=devs, x_shardings=x_shs, meshes=meshes,
                acc=tree_add_program(f"{name}.acc", self.compile_stats)))
        self.fusion_plan = None
        if fusion_plan is not None:
            groups = self._resolve_fusion(fusion_plan)
            if any(len(g) > 1 for g in groups):
                self.stages = self._fuse_lm_stages(groups, fwds)
                self.fusion_plan = tuple(groups)
        self.capacity_blocks = capacity_blocks
        self.workers = workers

    def _resolve_fusion(self, fusion_plan) -> list[tuple[str, ...]]:
        """Normalise ``fusion_plan`` into a contiguous partition of the
        built stage names.  ``"auto"`` asks `core.restructure.auto_fusion`
        (block stages form the ``heavy`` set — merging them is
        ``layers_per_stage``'s job; fusion absorbs the stateless
        endpoints); an explicit plan is a list of adjacent-name tuples."""
        names = [st.name for st in self.stages]
        if fusion_plan == "auto":
            from repro.core import restructure
            heavy = [n for n in names if n.startswith("block")]
            reps = {st.name: len(st.devices) for st in self.stages}
            return list(restructure.auto_fusion(
                names, heavy=heavy, replicas=reps,
                dev_in_score=False).groups)
        groups = [tuple(g) if isinstance(g, (tuple, list)) else (g,)
                  for g in fusion_plan]
        flat = [n for g in groups for n in g]
        if flat != names:
            raise ValueError(
                f"fusion_plan {groups} is not a contiguous partition of "
                f"the built stages {names}")
        return groups

    def _fuse_lm_stages(self, groups: list[tuple[str, ...]],
                        fwds: dict) -> list[LMStage]:
        """Rewrite ``self.stages`` under a fusion plan: each multi-member
        group becomes ONE stage whose forward is the sequential
        composition of the members' raw fns over params keyed by member
        name — one AOT program, one dispatch, one fifo hop deleted per
        fused boundary.  Replicas POOL the members' placement slices
        (each pooled replica holds every member's params and does the
        whole group's work), so the plan's device budget is kept and a
        fused stage natively has >= 2 replicas for failover whenever its
        members had distinct slices.  The composition keeps the eager
        ``jax.vjp`` call structure, so train-path grads stay
        bitwise-identical to the sequential reference (the fused grad
        tree is the members' trees under their name keys).

        tp-sharded members are rejected: composing across differently
        meshed param shardings would need a resharding pass the runtime
        does not have (a named ROADMAP follow-on)."""
        by_name = {st.name: st for st in self.stages}
        out: list[LMStage] = []
        for grp in groups:
            if len(grp) == 1:
                out.append(by_name[grp[0]])
                continue
            members = [by_name[n] for n in grp]
            for m in members:
                if m.meshes and any(mesh is not None for mesh in m.meshes):
                    raise ValueError(
                        f"cannot fuse tp-sharded stage {m.name}: stage "
                        f"combining requires single-device members")
            name = "+".join(grp)
            member_fns = [fwds[n] for n in grp]

            def fused_fn(p, x, _fns=tuple(member_fns), _names=tuple(grp)):
                for i, (nm, fn) in enumerate(zip(_names, _fns)):
                    if i:
                        x = _act_barrier(x)
                    x = fn(p[nm], x)
                return x

            devs = [d for m in members for d in m.devices]
            reps = {k: {m.name: jax.device_put(m.params[0], dev)
                        for m in members}
                    for k, dev in enumerate(devs)}
            out.append(LMStage(
                name=name,
                fwd=AotProgram(fused_fn, name=f"{name}.fwd",
                               stats=self.compile_stats),
                params=reps, devices=devs,
                x_shardings=[None] * len(devs),
                meshes=[None] * len(devs),
                acc=tree_add_program(f"{name}.acc", self.compile_stats)))
        return out

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def _n_workers(self) -> int:
        if self.workers is not None:
            return max(1, self.workers)
        return min(16, max(2, sum(len(st.devices) for st in self.stages)))

    def reference(self, microbatches: list) -> list:
        """Unpipelined forward — the same stage fns applied in sequence on
        replica 0; the pipelined run must match this bitwise on CPU."""
        outs = []
        for mb in microbatches:
            x = mb
            for st in self.stages:
                x = st.fwd(st.params[0], jax.device_put(x, st.x_target(0)))
            outs.append(x)
        return outs

    def _edge_fifo(self, producer: LMStage, consumer: LMStage,
                   overlap: bool) -> Fifo:
        # a slot is occupied from producer *dispatch* (reservation) to
        # consumer *retirement* (hold release), so both endpoints' full
        # in-flight complements must fit alongside the buffered tokens:
        # nr x replica_queue reservations on the producer side (else a
        # replicated producer serialises its own replicas on output
        # slots), nr x replica_queue holds on the consumer side, plus
        # ``capacity_blocks`` actually-queued tokens of slack between
        # them — the knob keeps its double-buffering meaning
        nrep = len(consumer.devices)

        def staging(tok):
            mb, y = tok
            check_not_donated(y, f"act edge ->{consumer.name} (mb={mb})")
            return (mb, jax.device_put(y, consumer.x_target(mb % nrep)))

        slots = (len(producer.devices) + len(consumer.devices)) \
            * self.replica_queue
        return Fifo(block=1, capacity_blocks=self.capacity_blocks,
                    min_capacity=self.capacity_blocks + slots,
                    prefetch_fn=staging if overlap else None,
                    prefetch_depth=self.prefetch_blocks
                    * len(consumer.devices) * self.replica_queue)

    def _resolve_schedule(self, schedule: Schedule | None, n_micro: int,
                          train: bool) -> Schedule:
        """Check a caller's schedule object against this pipeline and this
        run, or pick the default (`one_f_one_b` / `fill_drain`)."""
        M = self.n_stages
        if schedule is None:
            schedule = self.schedule
        if schedule is None:
            return (one_f_one_b(M, n_micro) if train
                    else fill_drain(M, n_micro))
        if schedule.n_model_stages != M:
            raise ValueError(
                f"schedule {schedule.name} covers "
                f"{schedule.n_stages} x {schedule.n_chunks} = "
                f"{schedule.n_model_stages} model stages; this pipeline "
                f"built {M}")
        if schedule.n_micro != n_micro:
            raise ValueError(
                f"schedule {schedule.name} is for {schedule.n_micro} "
                f"microbatches; run got {n_micro}")
        if train != schedule.trains:
            raise ValueError(
                f"schedule {schedule.name} "
                f"{'has no backward ops' if train else 'schedules backward'}"
                f" — mismatched with train={train}")
        return schedule.validate()

    def _preflight(self, sched: Schedule, n_micro: int, train: bool,
                   act_caps: list, grd_caps: list):
        """Static verification of this run's plan tuple; raises
        `core.verify.PlanVerificationError` on any ERROR.  Cached per
        (schedule, shape, capacities) — steady-state reruns of the same
        plan pay a dict lookup, not a re-simulation."""
        from ...core import verify as _verify
        key = (id(sched), sched.name, n_micro, train,
               tuple(act_caps), tuple(grd_caps))
        cached = getattr(self, "_preflight_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1].raise_if_errors("LMPipeline.run")
        report = _verify.verify_lm_plan(
            self, schedule=sched, n_micro=n_micro, train=train,
            act_capacities=act_caps, grd_capacities=grd_caps)
        self._preflight_cache = (key, report)
        self.last_preflight = report
        return report.raise_if_errors("LMPipeline.run")

    def _warm_run(self, mb, train: bool, loss_fn) -> None:
        """Ensure every program this run's shape will execute is compiled
        BEFORE the engine's clock starts (the ``warmup=`` escape hatch
        skips this; `compile_stats.late` then counts what landed inside
        the window).

        Serve shapes are true AOT: each stage forward is
        ``.lower(...).compile()``-ed against its concrete param placement
        and a sharded activation struct — nothing executes.  The train
        chain keeps its eager ``jax.vjp`` call structure (the bitwise
        contract with sequential-autodiff references forbids re-jitting
        it), so its jit caches warm by executing one zeros microbatch
        through F/B on every replica off the clock, and the donated
        accumulator is AOT-compiled from the real grad avals that run
        produces."""
        # key on the loss function's CODE object (shared by every instance
        # of the same lambda, so per-step closures don't re-trigger the
        # full eager warm or pin each closure; not id(), which a collected
        # lambda can recycle into a false warm hit)
        key = (tuple(mb.shape), str(mb.dtype), train,
               getattr(loss_fn, "__code__", loss_fn))
        if key in self._warmed:
            return
        if not train:
            struct = jax.ShapeDtypeStruct(mb.shape, mb.dtype)
            for st in self.stages:
                out = None
                for rep in range(len(st.devices)):
                    s_rep = jax.ShapeDtypeStruct(
                        struct.shape, struct.dtype,
                        sharding=st.x_sharding(rep))
                    if isinstance(st.fwd, AotProgram):
                        st.fwd.precompile(st.params[rep], s_rep)
                    if out is None:
                        out = jax.eval_shape(st.fwd, st.params[rep], s_rep)
                struct = out
            self._warmed.add(key)
            return
        t0 = time.perf_counter()
        x = jnp.zeros(mb.shape, mb.dtype)
        per_stage = []
        for st in self.stages:
            outs = {}
            for rep in range(len(st.devices)):
                xr = jax.device_put(x, st.x_target(rep))
                outs[rep] = jax.vjp(st.fwd, st.params[rep], xr)
            per_stage.append(outs)
            x = outs[0][0]
        y_bar = None
        for si in reversed(range(len(self.stages))):
            st = self.stages[si]
            nxt_bar = None
            for rep, (y, vjp) in per_stage[si].items():
                if si == len(self.stages) - 1:
                    yb = (jax.value_and_grad(loss_fn)(y)[1] if loss_fn
                          else jnp.ones_like(y))
                else:
                    yb = jax.device_put(y_bar, st.x_target(rep))
                pb, xb = vjp(yb)
                if rep == 0:
                    pb_t = jax.device_put(pb, st.grad_target())
                    st.acc.precompile(pb_t, pb_t)
                    nxt_bar = xb
            y_bar = nxt_bar
        jax.block_until_ready(y_bar)
        self.compile_stats.warm_exec_s += time.perf_counter() - t0
        self._warmed.add(key)

    def run(self, microbatches: list, *, train: bool = False,
            loss_fn=None, overlap: bool | None = None,
            schedule: Schedule | None = None,
            tracer=None, injector=None,
            preflight: bool = True) -> LMPipelineResult:
        """Stream microbatches through the pipeline under ``schedule``.

        Serving (train=False) defaults to `schedule.fill_drain` streaming
        with bounded inter-stage buffers — a stage whose output fifo is
        full skips its turn until the consumer drains it.  Training
        (train=True) defaults to `schedule.one_f_one_b` with per-stage
        vjp backward and grad accumulation; ``loss_fn(logits) -> scalar``
        seeds the backward (defaults to sum-of-logits).  An interleaved
        schedule (``schedule.interleaved_1f1b(p, m, v)`` with
        ``p * v == n_stages``) runs v virtual-stage chunks per physical
        program over the same FIFO chain — grads stay bitwise-equal to
        the plain schedules.  ``overlap`` overrides the pipeline-level
        knob for this run (the benchmark's A/B switch).  ``tracer``: an
        optional `trace.Tracer` — the run emits dispatch/retire spans,
        credit/starve waits, and fifo occupancy counters, and fills
        ``res.stage_wait_s``; warmup stays untraced so the aggregates
        cover only the timed window.  ``preflight``: run the static plan
        verifier (`core.verify.verify_lm_plan`) over the resolved
        schedule and the actual act/grd FIFO capacities before building
        the engine — schedule-consistency plus an exact credit
        simulation of the op order — raising `PlanVerificationError` on
        any ERROR (False = escape hatch; the deadlock report then notes
        preflight was skipped).
        """
        overlap = self.overlap if overlap is None else overlap
        n_micro = len(microbatches)
        M = self.n_stages
        sched = self._resolve_schedule(schedule, n_micro, train)
        p = sched.n_stages
        if self.warmup and microbatches:
            self._warm_run(microbatches[0], train, loss_fn)

        acts = [self._edge_fifo(self.stages[i], self.stages[i + 1], overlap)
                for i in range(M - 1)]             # i -> i+1 activations
        grds = [self._edge_fifo(self.stages[i + 1], self.stages[i], overlap)
                for i in range(M - 1)] if train else None
        report = None
        if preflight:
            report = self._preflight(sched, n_micro, train,
                                     [f.capacity for f in acts],
                                     [f.capacity for f in grds or []])
        fifo_map = {}
        for i in range(M - 1):
            fifo_map[f"act{i}"] = acts[i]
            if grds is not None:
                fifo_map[f"grd{i}"] = grds[i]
        if tracer is not None:
            for i in range(M - 1):
                tracer.watch_fifo(acts[i], f"act{i}",
                                  src=self.stages[i].name,
                                  dst=self.stages[i + 1].name)
                if grds is not None:
                    tracer.watch_fifo(grds[i], f"grd{i}",
                                      src=self.stages[i + 1].name,
                                      dst=self.stages[i].name)
        res = LMPipelineResult(outputs=[None] * n_micro,
                               placement=self.placement)
        grads = {st.name: None for st in self.stages} if train else None
        raw_losses: dict[int, object] = {}

        programs = [
            _LMStageProgram(s, self, sched.stage_ops[s],
                            chunks=[sched.model_stage(s, c)
                                    for c in range(sched.n_chunks)],
                            acts=acts, grds=grds, res=res,
                            microbatches=microbatches, train=train,
                            loss_fn=loss_fn, grads=grads,
                            raw_losses=raw_losses)
            for s in range(p)]
        engine = Engine(programs, overlap=overlap,
                        workers=self._n_workers(),
                        replica_queue=self.replica_queue,
                        tracer=tracer, fifos=fifo_map, injector=injector,
                        static_report=report)
        with self.compile_stats.window():
            er = engine.run()
        res.stage_wait_s = er.stage_wait_s
        res.stage_seconds = er.stage_seconds
        res.stage_firings = er.stage_firings
        res.stage_done_s = er.stage_done_s
        res.stage_dispatch_s = er.stage_dispatch_s
        res.op_trace = er.op_trace
        res.max_inflight = er.max_inflight

        # drain the async tail before reading the wall clock
        jax.block_until_ready([o for o in res.outputs if o is not None])
        if grads is not None:
            jax.block_until_ready([g for g in grads.values()
                                   if g is not None])
        res.losses = {mb: float(v) for mb, v in sorted(raw_losses.items())}
        res.mb_done_s.sort()
        res.wall_s = time.perf_counter() - engine.t0
        res.grads = grads
        for i in range(M - 1):
            res.fifo_stats[("act", i)] = acts[i].stats
            if grds is not None:
                res.fifo_stats[("grd", i)] = grds[i].stats
        return res
