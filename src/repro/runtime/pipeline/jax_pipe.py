"""Jax device-to-device pipeline for LM streaming task graphs.

Executes the planner's LM stage graph (`graphs/lm_graph.build_stg`: embed
-> block00.. -> head) as a real microbatch pipeline over jax devices:
every stage's parameters live on its placement slice, activations move
between slices with ``jax.device_put`` (device-to-device when the pool has
distinct devices; a no-op on a single-device pool, which then time-shares
— the placement layer reports the oversubscription), microbatches are
dispatched to stage replicas round-robin (the fork/join routing of
`core/transform.py` collapsed to its end-to-end effect), and execution
follows a 1F1B schedule for train shapes or fill-drain streaming for
serving.  Stage bodies are built from `models/blocks.py`.

Inter-stage buffers are the same bounded double-buffered FIFOs as the
interpreter path (`channels.Fifo`): a stage whose output buffer is full
skips its turn (backpressure), and activations cross devices at
*consumption* time, so the FIFO models the wire buffer.  Per-stage wall
time is recorded around ``block_until_ready`` so the measurement layer can
report measured inverse throughput per stage and tokens/s against the
plan's promise.

Measurement caveat: the host loop runs every op to completion on one
thread, so a stage's replicas execute *serially* — ``stage_inverse_us``
is per-replica time, while the analytic plan's v is ii/nr assuming
concurrent replicas.  Don't feed jax-path ratios of replicated stages
into ``planner.replan(measured_ratio=...)`` unscaled; the interpreter
path models replica interleaving correctly and is the calibration
source of truth (threaded/async replica execution is a ROADMAP item).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ...configs.base import ModelConfig
from ...core.stg import STG, Selection
from ...models import blocks
from ...models.common import KeyGen, dense_init, rmsnorm
from .channels import Fifo
from .placement import Placement, place
from .schedule import fill_drain, one_f_one_b


def selection_from_plan(plan) -> Selection:
    """PlanResult -> Selection over the lm_graph node names."""
    sel = Selection()
    for sp in plan.stages:
        sel.set(sp.name, sp.impl, sp.replicas)
    return sel


# ===========================================================================
# stage construction (models/blocks)
# ===========================================================================
@dataclass
class LMStage:
    name: str
    fwd: object                  # jitted (params, x) -> y
    params: dict                 # replica index -> pytree on that device
    devices: list                # replica index -> jax.Device


def _embed_fwd(cfg: ModelConfig):
    def fwd(p, tokens):
        return p["emb"][tokens].astype(jnp.bfloat16)
    return fwd


def _block_fwd(cfg: ModelConfig, mixers: tuple[tuple[str, str], ...]):
    def fwd(p, x):
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, axis=0)
        for li, (mixer, mlp) in enumerate(mixers):
            lp = p[f"l{li}"]
            if mixer == "attn":
                x = blocks.attn_forward(lp["mix"], cfg, x, positions)
            else:
                x = blocks.mamba_forward(lp["mix"], cfg, x)
            if mlp == "moe":
                x = blocks.moe_forward(lp["mlp"], cfg, x)
            else:
                x = blocks.mlp_forward(lp["mlp"], cfg, x)
        return x
    return fwd


def _head_fwd(cfg: ModelConfig):
    def fwd(p, x):
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        return (h @ p["w_out"].astype(h.dtype)).astype(jnp.float32)
    return fwd


def build_lm_stages(cfg: ModelConfig, *, layers_per_stage: int | None = None,
                    seed: int = 0) -> tuple[list[str], dict, dict]:
    """(stage names, fwd fns, init params) for embed / block groups / head.

    ``layers_per_stage`` groups adjacent layers into one pipeline stage
    (1 == the lm_graph granularity: one node per block).
    """
    kg = KeyGen(jax.random.PRNGKey(seed))
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    d = cfg.d_model
    pattern = cfg.block_pattern * (cfg.n_layers // len(cfg.block_pattern))
    lps = layers_per_stage or 1

    names, fwds, params = [], {}, {}
    names.append("embed")
    fwds["embed"] = _embed_fwd(cfg)
    params["embed"] = {"emb": dense_init(kg("emb"), (cfg.padded_vocab, d), dt)}

    for s0 in range(0, len(pattern), lps):
        mixers = tuple(pattern[s0:s0 + lps])
        name = f"block{s0 // lps:02d}"
        p = {}
        for li, (mixer, mlp) in enumerate(mixers):
            mix_p = (blocks.init_attn(kg, cfg, f"{name}.l{li}.mix")
                     if mixer == "attn"
                     else blocks.init_mamba(kg, cfg, f"{name}.l{li}.mix"))
            mlp_p = (blocks.init_moe(kg, cfg, f"{name}.l{li}.mlp")
                     if mlp == "moe"
                     else blocks.init_mlp(kg, cfg, f"{name}.l{li}.mlp"))
            p[f"l{li}"] = {"mix": mix_p, "mlp": mlp_p}
        names.append(name)
        fwds[name] = _block_fwd(cfg, mixers)
        params[name] = p

    names.append("head")
    fwds["head"] = _head_fwd(cfg)
    params["head"] = {"norm": jnp.ones((d,), jnp.float32),
                      "w_out": dense_init(kg("w_out"), (d, cfg.padded_vocab), dt)}
    return names, fwds, params


# ===========================================================================
# pipeline assembly + execution
# ===========================================================================
@dataclass
class LMPipelineResult:
    outputs: list                           # microbatch logits (serve runs;
                                            # train runs release them at B
                                            # and fill ``losses`` instead)
    losses: dict = field(default_factory=dict)    # mb -> loss value (train)
    stage_seconds: dict[str, float] = field(default_factory=dict)
    stage_firings: dict[str, int] = field(default_factory=dict)
    mb_done_s: list[float] = field(default_factory=list)
    wall_s: float = 0.0
    placement: Placement | None = None
    grads: dict | None = None               # stage -> pytree (train runs)

    def stage_inverse_us(self, name: str) -> float:
        """Mean host microseconds per firing of one stage.  NOTE: replicas
        run serially on the host thread, so for a replicated stage this is
        per-replica time — not directly comparable to the plan's ii/nr."""
        n = self.stage_firings.get(name, 0)
        return self.stage_seconds[name] / n * 1e6 if n else float("nan")

    def tokens_per_s(self, toks_per_mb: int) -> float:
        """Steady-state tokens/s from inter-microbatch completion gaps."""
        if len(self.mb_done_s) >= 3:
            k = max(1, len(self.mb_done_s) // 4)
            window = self.mb_done_s[k:]
            if len(window) >= 2 and window[-1] > window[0]:
                return toks_per_mb * (len(window) - 1) / (window[-1] - window[0])
        return toks_per_mb * len(self.mb_done_s) / max(self.wall_s, 1e-9)


class LMPipeline:
    """A placed, compiled LM pipeline ready to stream microbatches."""

    def __init__(self, cfg: ModelConfig, stg: STG, sel: Selection, *,
                 devices=None, layers_per_stage: int | None = None,
                 capacity_blocks: int = 2, seed: int = 0):
        self.cfg = cfg
        devices = list(devices if devices is not None else jax.devices())
        names, fwds, init_params = build_lm_stages(
            cfg, layers_per_stage=layers_per_stage, seed=seed)
        self.placement = place(stg, sel, devices)
        # map lm_graph node names onto built stages: embed/head by name,
        # blockNN graph nodes collapse onto the built group that owns them
        # (topological, not lexicographic: block100 sorts before block11)
        graph_blocks = [n for n in stg.topo_order()
                        if n not in ("embed", "head")]
        built_blocks = [n for n in names if n not in ("embed", "head")]
        lps = layers_per_stage or 1
        self.stages: list[LMStage] = []
        for name in names:
            if name in ("embed", "head"):
                owners = [name]
            else:
                # built stage i holds layers [i*lps, (i+1)*lps) — slice the
                # per-layer graph nodes with the same arithmetic (floor
                # division over-counts when lps does not divide n_layers)
                i = built_blocks.index(name)
                owners = (graph_blocks[i * lps:(i + 1) * lps]
                          or [graph_blocks[-1]])
                picks = {sel.choices[o] for o in owners}
                if len(picks) > 1:
                    raise ValueError(
                        f"stage {name} groups graph nodes {owners} whose "
                        f"plan choices differ ({sorted(picks)}) — the "
                        f"executor would drop replicas the plan promised; "
                        f"use layers_per_stage=1 or align the plan")
            # a fused stage does the work of all its owners' graph nodes;
            # use every owner's replica slices (nr x n_owners copies, each
            # doing n_owners layers of work -> same planned capacity) so
            # the plan's device budget is not silently idled
            devs = []
            for owner in owners:
                for sl in self.placement.replicas_of(owner):
                    d = sl.devices[0]
                    devs.append(d if not isinstance(d, int)
                                else devices[d % len(devices)])
            devs = devs or [devices[0]]
            reps = {k: jax.device_put(init_params[name], devs[k])
                    for k in range(len(devs))}
            self.stages.append(LMStage(name=name, fwd=jax.jit(fwds[name]),
                                       params=reps, devices=devs))
        self.capacity_blocks = capacity_blocks

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def reference(self, microbatches: list) -> list:
        """Unpipelined forward — the same stage fns applied in sequence on
        replica 0; the pipelined run must match this bitwise on CPU."""
        outs = []
        for mb in microbatches:
            x = mb
            for st in self.stages:
                x = st.fwd(st.params[0], jax.device_put(x, st.devices[0]))
            outs.append(x)
        return outs

    def run(self, microbatches: list, *, train: bool = False,
            loss_fn=None) -> LMPipelineResult:
        """Stream microbatches through the pipeline.

        Serving (train=False): fill-drain streaming with bounded
        inter-stage buffers — a stage whose output fifo is full skips its
        turn until the consumer drains it.  Training (train=True): 1F1B
        with per-stage vjp backward and grad accumulation;
        ``loss_fn(logits) -> scalar`` seeds the backward (defaults to
        sum-of-logits).

        Both F and B ops reach each stage in microbatch order, so each
        inter-stage fifo's head is always the next scheduled microbatch —
        consumers pop the head directly, no reordering map needed.
        """
        n_micro = len(microbatches)
        S = self.n_stages
        sched = one_f_one_b(S, n_micro) if train else fill_drain(S, n_micro)
        pos = [0] * S                              # next op index per stage
        acts = [Fifo(block=1, capacity_blocks=self.capacity_blocks)
                for _ in range(S - 1)]             # s -> s+1 activations
        grds = [Fifo(block=1, capacity_blocks=self.capacity_blocks)
                for _ in range(S - 1)] if train else None
        vjps: list[dict[int, object]] = [dict() for _ in range(S)]
        res = LMPipelineResult(outputs=[None] * n_micro,
                               placement=self.placement)
        for st in self.stages:
            res.stage_seconds[st.name] = 0.0
            res.stage_firings[st.name] = 0
        grads = {st.name: None for st in self.stages} if train else None

        def ready(s: int) -> bool:
            if pos[s] >= len(sched[s]):
                return False
            kind, mb = sched[s][pos[s]]
            if kind == "F":
                if s > 0 and not acts[s - 1].can_pop(1):
                    return False
                if s < S - 1 and not acts[s].can_push(1):
                    return False              # backpressure: skip this turn
            else:
                if s < S - 1 and not grds[s].can_pop(1):
                    return False
                if s > 0 and not grds[s - 1].can_push(1):
                    return False
            return True

        t0 = time.perf_counter()
        pending = sum(len(ops) for ops in sched)
        while pending:
            progressed = False
            # downstream-first: consumers drain fifos before producers push
            for s in reversed(range(S)):
                if not ready(s):
                    continue
                kind, mb = sched[s][pos[s]]
                st = self.stages[s]
                rep = mb % len(st.devices)
                tic = time.perf_counter()
                if kind == "F":
                    if s == 0:
                        x = microbatches[mb]
                    else:
                        mb_got, x = acts[s - 1].pop(1)[0]
                        assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                    x = jax.device_put(x, st.devices[rep])
                    if train:
                        y, vjp = jax.vjp(st.fwd, st.params[rep], x)
                        vjps[s][mb] = vjp
                    else:
                        y = st.fwd(st.params[rep], x)
                    y = jax.block_until_ready(y)
                    if s < S - 1:
                        acts[s].push([(mb, y)], 0.0)
                    else:
                        res.outputs[mb] = y
                        res.mb_done_s.append(time.perf_counter() - t0)
                else:
                    if s == S - 1:
                        logits = res.outputs[mb]
                        if loss_fn:
                            lval, y_bar = jax.value_and_grad(loss_fn)(logits)
                            res.losses[mb] = float(lval)
                        else:
                            y_bar = jnp.ones_like(logits)
                        # release the vocab-sized tensor: 1F1B exists to
                        # bound live activations, so don't hoard logits
                        res.outputs[mb] = None
                    else:
                        mb_got, y_bar = grds[s].pop(1)[0]
                        assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                    vjp = vjps[s].pop(mb)
                    p_bar, x_bar = vjp(jax.device_put(y_bar, st.devices[rep]))
                    jax.block_until_ready(x_bar)
                    # accumulate on replica 0's device — p_bar is committed
                    # to whichever replica ran the microbatch
                    p_bar = jax.device_put(p_bar, st.devices[0])
                    grads[st.name] = (p_bar if grads[st.name] is None else
                                      jax.tree.map(jnp.add, grads[st.name], p_bar))
                    if s > 0:
                        grds[s - 1].push([(mb, x_bar)], 0.0)
                res.stage_seconds[st.name] += time.perf_counter() - tic
                res.stage_firings[st.name] += 1
                pos[s] += 1
                pending -= 1
                progressed = True
            if not progressed:
                raise RuntimeError(
                    f"pipeline deadlock: pos={pos} of "
                    f"{[len(o) for o in sched]} — schedule/backpressure bug")
        res.wall_s = time.perf_counter() - t0
        res.grads = grads
        return res
