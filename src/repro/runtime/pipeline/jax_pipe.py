"""Jax device-to-device pipeline for LM streaming task graphs.

Executes the planner's LM stage graph (`graphs/lm_graph.build_stg`: embed
-> block00.. -> head) as a real microbatch pipeline over jax devices:
every stage's parameters live on its placement slice — sharded over a
per-stage (1, tp) sub-mesh when the slice owns tp > 1 distinct devices
(`launch/mesh.stage_submeshes` + `launch/sharding.stage_param_specs`),
pinned to the slice's device otherwise — activations move between slices
with ``jax.device_put`` (device-to-device when the pool has distinct
devices; a no-op on a single-device pool, which then time-shares — the
placement layer reports the oversubscription), microbatches are dispatched
to stage replicas round-robin (the fork/join routing of
`core/transform.py` collapsed to its end-to-end effect), and execution
follows a 1F1B schedule for train shapes or fill-drain streaming for
serving.  Stage bodies are built from `models/blocks.py`.

The event loop itself lives in the graph-generic executor core
(`engine.Engine`): this module only defines *stage programs* — per-stage
dispatch/retire hooks for the embed/block/head forward and backward ops
(`_LMStageProgram`).  The engine owns FIFO credits, per-edge reorder
buffers, replica busy budgets, completion timing, and deadlock detection,
shared with the host interpreter and the decode serving pipeline.

Execution is *overlapped* by default (``overlap=True``): the engine never
blocks on an op — each firing is handed to a small worker pool that
dispatches the jax computation and retires it on completion, so a
replicated stage's microbatches run concurrently across its replica
slices (measured inverse throughput reads ii/nr, like the interpreter
path) and the host scheduling loop itself hides inside device compute.
Inter-stage buffers are two-level host+device FIFOs (`channels.Fifo`): a
slot is occupied from producer *dispatch* to consumer *retirement*, so
channel capacity bounds total in-flight work per edge (bounded device
memory under backpressure), and queued activations are prefetched onto
the consumer's device slice up to ``prefetch_blocks`` ahead of
consumption — the transfer overlaps the consumer's current microbatch
(on-device double buffering) instead of serialising with its next one.
``overlap=False`` reproduces the legacy serial executor (dispatch, block,
advance) for A/B measurement; `benchmarks/bench_pipeline.py` reports the
recovered bubble.

Per-stage timing is sampled from completion events: each op timestamps
the moment its output became ready, and ``stage_inverse_us`` reads the
steady-state gap of the stage's merged completion stream — replicas
interleave, so a replicated stage measures its *effective* inverse
throughput, directly comparable to the plan's ii/nr.  The jax path is
therefore a valid calibration source: feed
``measure.compare_lm(...).ratios()`` into
``planner.replan(measured_ratio=...)`` exactly like interpreter-path
reports (remember measured ratios mix host-vs-roofline scale; the solver
consumes *relative* per-stage ratios).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ...configs.base import ModelConfig
from ...core.stg import STG, Selection
from ...launch.mesh import submesh_of
from ...launch.sharding import ShardingPolicy, stage_param_shardings
from ...models import blocks
from ...models.common import KeyGen, dense_init, rmsnorm
from .channels import Fifo
from .engine import Engine, Op, steady_inverse
from .placement import Placement, place
from .schedule import fill_drain, one_f_one_b


def selection_from_plan(plan) -> Selection:
    """PlanResult -> Selection over the lm_graph node names (delegates to
    the package-level `as_selection`, the single materialisation rule
    shared with the interpreter path)."""
    from . import as_selection
    return as_selection(plan)


# ===========================================================================
# stage construction (models/blocks)
# ===========================================================================
@dataclass
class LMStage:
    name: str
    fwd: object                  # jitted (params, x) -> y
    params: dict                 # replica index -> pytree on that slice
    devices: list                # replica index -> first jax.Device
    x_shardings: list = None     # replica index -> NamedSharding (tp-sharded
                                 # slices) or None (single-device placement)
    meshes: list = None          # replica index -> sub-mesh or None

    def x_target(self, rep: int):
        """Where replica ``rep``'s inputs must live: the sub-mesh's
        replicated sharding for tp-sharded slices, its device otherwise."""
        if self.x_shardings and self.x_shardings[rep] is not None:
            return self.x_shardings[rep]
        return self.devices[rep]

    def grad_target(self):
        """Where accumulated grads live: replica 0's param shardings for a
        tp-sharded stage (grads shard like their params), its device
        otherwise."""
        if self.meshes and self.meshes[0] is not None:
            return jax.tree.map(lambda leaf: leaf.sharding, self.params[0])
        return self.devices[0]


def _embed_fwd(cfg: ModelConfig):
    def fwd(p, tokens):
        return p["emb"][tokens].astype(jnp.bfloat16)
    return fwd


def _block_fwd(cfg: ModelConfig, mixers: tuple[tuple[str, str], ...]):
    def fwd(p, x):
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, axis=0)
        for li, (mixer, mlp) in enumerate(mixers):
            lp = p[f"l{li}"]
            if mixer == "attn":
                x = blocks.attn_forward(lp["mix"], cfg, x, positions)
            else:
                x = blocks.mamba_forward(lp["mix"], cfg, x)
            if mlp == "moe":
                x = blocks.moe_forward(lp["mlp"], cfg, x)
            else:
                x = blocks.mlp_forward(lp["mlp"], cfg, x)
        return x
    return fwd


def _head_fwd(cfg: ModelConfig):
    def fwd(p, x):
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        return (h @ p["w_out"].astype(h.dtype)).astype(jnp.float32)
    return fwd


def build_lm_stages(cfg: ModelConfig, *, layers_per_stage: int | None = None,
                    seed: int = 0) -> tuple[list[str], dict, dict]:
    """(stage names, fwd fns, init params) for embed / block groups / head.

    ``layers_per_stage`` groups adjacent layers into one pipeline stage
    (1 == the lm_graph granularity: one node per block).
    """
    kg = KeyGen(jax.random.PRNGKey(seed))
    dt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    d = cfg.d_model
    pattern = cfg.block_pattern * (cfg.n_layers // len(cfg.block_pattern))
    lps = layers_per_stage or 1

    names, fwds, params = [], {}, {}
    names.append("embed")
    fwds["embed"] = _embed_fwd(cfg)
    params["embed"] = {"emb": dense_init(kg("emb"), (cfg.padded_vocab, d), dt)}

    for s0 in range(0, len(pattern), lps):
        mixers = tuple(pattern[s0:s0 + lps])
        name = f"block{s0 // lps:02d}"
        p = {}
        for li, (mixer, mlp) in enumerate(mixers):
            mix_p = (blocks.init_attn(kg, cfg, f"{name}.l{li}.mix")
                     if mixer == "attn"
                     else blocks.init_mamba(kg, cfg, f"{name}.l{li}.mix"))
            mlp_p = (blocks.init_moe(kg, cfg, f"{name}.l{li}.mlp")
                     if mlp == "moe"
                     else blocks.init_mlp(kg, cfg, f"{name}.l{li}.mlp"))
            p[f"l{li}"] = {"mix": mix_p, "mlp": mlp_p}
        names.append(name)
        fwds[name] = _block_fwd(cfg, mixers)
        params[name] = p

    names.append("head")
    fwds["head"] = _head_fwd(cfg)
    params["head"] = {"norm": jnp.ones((d,), jnp.float32),
                      "w_out": dense_init(kg("w_out"), (d, cfg.padded_vocab), dt)}
    return names, fwds, params


# ===========================================================================
# result type
# ===========================================================================
@dataclass
class LMPipelineResult:
    outputs: list                           # microbatch logits (serve runs;
                                            # train runs release them at B
                                            # and fill ``losses`` instead)
    losses: dict = field(default_factory=dict)    # mb -> loss value (train)
    stage_seconds: dict[str, float] = field(default_factory=dict)
    stage_firings: dict[str, int] = field(default_factory=dict)
    stage_done_s: dict[str, list[float]] = field(default_factory=dict)
    mb_done_s: list[float] = field(default_factory=list)
    wall_s: float = 0.0
    placement: Placement | None = None
    grads: dict | None = None               # stage -> pytree (train runs)
    fifo_stats: dict = field(default_factory=dict)   # edge label -> FifoStats
    max_inflight: int = 0                   # peak concurrently in-flight ops
    op_trace: list = field(default_factory=list)
    # (stage, kind, mb, replica, t_dispatch, t_done) per op, run-relative —
    # the raw material for overlap debugging and gantt-style bench plots

    def stage_inverse_us(self, name: str) -> float:
        """Effective microseconds per forward firing of one stage: the
        steady-state gap of the stage's merged completion-event stream
        (`engine.steady_inverse`).  Replicas interleave under overlapped
        dispatch, so a replicated stage reads ii/nr — directly comparable
        to the analytic plan (and to the interpreter path's
        ``stage_inverse_throughput``).

        Runs too short to show a steady state (< 4 forward completions)
        fall back to mean in-flight latency per op — an
        order-of-magnitude degraded mode that mixes forward and backward
        ops *and* dispatch-queue wait (overlapping ops can sum past wall
        time).  ``compare_lm`` skips such stages rather than calibrating
        on the fallback."""
        try:
            return steady_inverse(self.stage_done_s.get(name, ())) * 1e6
        except ValueError:
            n = self.stage_firings.get(name, 0)
            return self.stage_seconds[name] / n * 1e6 if n else float("nan")

    def tokens_per_s(self, toks_per_mb: int) -> float:
        """Steady-state tokens/s from inter-microbatch completion gaps.
        Short runs (< 3 completed microbatches) still exclude the pipeline
        fill ramp by anchoring at the first completion instead of dividing
        by the full wall clock."""
        if len(self.mb_done_s) >= 3:
            k = max(1, len(self.mb_done_s) // 4)
            window = self.mb_done_s[k:]
            if len(window) >= 2 and window[-1] > window[0]:
                return toks_per_mb * (len(window) - 1) / (window[-1] - window[0])
        if len(self.mb_done_s) >= 2 and self.mb_done_s[-1] > self.mb_done_s[0]:
            span = self.mb_done_s[-1] - self.mb_done_s[0]
            return toks_per_mb * (len(self.mb_done_s) - 1) / span
        return toks_per_mb * len(self.mb_done_s) / max(self.wall_s, 1e-9)


# ===========================================================================
# op bodies (run on the engine's dispatch pool under overlap)
# ===========================================================================
def _fwd_op(st: LMStage, rep: int, x, train: bool):
    x = jax.device_put(x, st.x_target(rep))
    if train:
        y, vjp = jax.vjp(st.fwd, st.params[rep], x)
    else:
        y, vjp = st.fwd(st.params[rep], x), None
    jax.block_until_ready(y)
    return y, vjp, time.perf_counter()


def _bwd_op(st: LMStage, rep: int, vjp, y_bar, logits, loss_fn):
    lval = None
    if logits is not None:            # last stage: seed from loss
        if loss_fn:
            lval, y_bar = jax.value_and_grad(loss_fn)(logits)
        else:
            y_bar = jnp.ones_like(logits)
    else:
        y_bar = jax.device_put(y_bar, st.x_target(rep))
    p_bar, x_bar = vjp(y_bar)
    jax.block_until_ready(x_bar)
    return p_bar, x_bar, lval, time.perf_counter()


# ===========================================================================
# stage program: one pipeline stage's schedule on the shared engine
# ===========================================================================
class _LMStageProgram:
    """Dispatch/retire hooks for one LM stage's scheduled F/B ops.

    Both F and B ops reach each stage in microbatch order, so each
    inter-stage fifo's head is always the next scheduled microbatch —
    consumers pop the head directly; out-of-order replica completions are
    re-sorted by the engine's per-edge reorder buffer.
    """

    def __init__(self, s: int, pipe: "LMPipeline", ops: list, *,
                 acts: list, grds: list | None, res: LMPipelineResult,
                 microbatches: list, train: bool, loss_fn,
                 grads: dict | None, raw_losses: dict):
        self.s = s
        self.S = pipe.n_stages
        self.st = pipe.stages[s]
        self.name = self.st.name
        self.n_replicas = len(self.st.devices)
        self.ops = ops
        self.pos = 0
        self.stall_mark = -1
        self.acts = acts
        self.grds = grds
        self.res = res
        self.microbatches = microbatches
        self.train = train
        self.loss_fn = loss_fn
        self.grads = grads
        self.raw_losses = raw_losses
        self.vjps: dict[int, object] = {}
        # deterministic grad accumulation: p_bars fold in microbatch order
        # regardless of which replica retires first
        self.acc_next = 0
        self.acc_buf: dict[int, object] = {}

    def pending(self) -> int:
        return len(self.ops) - self.pos

    def peek(self) -> Op | None:
        if self.pos >= len(self.ops):
            return None
        kind, mb = self.ops[self.pos]
        return Op(stage=self.s, kind=kind, seq=mb,
                  rep=mb % self.n_replicas, is_firing=(kind == "F"))

    def ready(self, op: Op) -> bool:
        """Can this op be dispatched now?  Counts a producer stall the
        first time a given op is deferred purely by output-buffer
        backpressure."""
        s, S, mb = self.s, self.S, op.seq
        if op.kind == "F":
            if s > 0 and not self.acts[s - 1].can_pop(1):
                return False
            if s < S - 1 and not self.acts[s].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.acts[s].note_stall()
                return False              # backpressure: skip this turn
        else:
            if mb not in self.vjps:
                return False              # forward still in flight
            if s < S - 1 and not self.grds[s].can_pop(1):
                return False
            if s > 0 and not self.grds[s - 1].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.grds[s - 1].note_stall()
                return False
        return True

    def dispatch(self, op: Op):
        s, S, mb, st = self.s, self.S, op.seq, self.st
        if op.kind == "F":
            if s == 0:
                x = self.microbatches[mb]
            else:
                mb_got, x = self.acts[s - 1].pop_hold(1)[0]
                assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                op.releases.append((self.acts[s - 1], 1))
            if s < S - 1:
                self.acts[s].reserve(1)
            task = (_fwd_op, (st, op.rep, x, self.train))
        else:
            if s == S - 1:
                logits, y_bar = self.res.outputs[mb], None
                # release the vocab-sized tensor: 1F1B exists to bound
                # live activations, so don't hoard logits
                self.res.outputs[mb] = None
            else:
                mb_got, y_bar = self.grds[s].pop_hold(1)[0]
                assert mb_got == mb, f"fifo order broke: {mb_got}!={mb}"
                op.releases.append((self.grds[s], 1))
                logits = None
            if s > 0:
                self.grds[s - 1].reserve(1)
            task = (_bwd_op, (st, op.rep, self.vjps.pop(mb), y_bar, logits,
                              self.loss_fn))
        self.pos += 1
        return task

    def retire(self, op: Op, result, engine: Engine) -> float:
        s, S, st = self.s, self.S, self.st
        if op.kind == "F":
            y, vjp, t_done = result
            if self.train:
                self.vjps[op.seq] = vjp
            if s < S - 1:
                engine.ordered_push(self.acts[s], op.seq, y, t_done)
            else:
                self.res.outputs[op.seq] = y
                self.res.mb_done_s.append(t_done - engine.t0)
        else:
            p_bar, x_bar, lval, t_done = result
            if s > 0:
                engine.ordered_push(self.grds[s - 1], op.seq, x_bar, t_done)
            if lval is not None:
                self.raw_losses[op.seq] = lval
            self.acc_buf[op.seq] = p_bar
            while self.acc_next in self.acc_buf:
                pb = self.acc_buf.pop(self.acc_next)
                self.acc_next += 1
                pb = jax.device_put(pb, st.grad_target())
                self.grads[st.name] = (
                    pb if self.grads[st.name] is None else
                    jax.tree.map(jnp.add, self.grads[st.name], pb))
        return t_done

    def describe(self) -> str:
        return f"{self.name}: {self.pos}/{len(self.ops)}"


# ===========================================================================
# pipeline assembly + execution
# ===========================================================================
class LMPipeline:
    """A placed, compiled LM pipeline ready to stream microbatches.

    ``overlap`` selects the asynchronous executor (concurrent replica
    dispatch + on-device prefetch; the default); ``prefetch_blocks`` is
    how many queued activations each channel stages onto the consumer's
    device slice ahead of consumption; ``workers`` caps the dispatch pool
    (default: one per replica slice, at most 16).
    """

    def __init__(self, cfg: ModelConfig, stg: STG, sel: Selection, *,
                 devices=None, layers_per_stage: int | None = None,
                 capacity_blocks: int = 2, seed: int = 0,
                 overlap: bool = True, prefetch_blocks: int = 1,
                 replica_queue: int = 2, workers: int | None = None,
                 policy: ShardingPolicy | None = None):
        self.cfg = cfg
        devices = list(devices if devices is not None else jax.devices())
        names, fwds, init_params = build_lm_stages(
            cfg, layers_per_stage=layers_per_stage, seed=seed)
        self.placement = place(stg, sel, devices)
        self.overlap = overlap
        self.prefetch_blocks = prefetch_blocks
        self.replica_queue = max(1, replica_queue)
        policy = policy or ShardingPolicy(fsdp=False, tp=True)
        # map lm_graph node names onto built stages: embed/head by name,
        # blockNN graph nodes collapse onto the built group that owns them
        # (topological, not lexicographic: block100 sorts before block11)
        graph_blocks = [n for n in stg.topo_order()
                        if n not in ("embed", "head")]
        built_blocks = [n for n in names if n not in ("embed", "head")]
        lps = layers_per_stage or 1
        # every graph node must land in exactly one built stage, or the
        # pipeline would silently run less model than the plan placed
        # (e.g. enc-dec graphs emit encNN nodes no decoder stage claims)
        if len(graph_blocks) != sum(
                len(graph_blocks[i * lps:(i + 1) * lps])
                for i in range(len(built_blocks))) or not all(
                n.startswith("block") for n in graph_blocks):
            raise ValueError(
                f"graph nodes {graph_blocks} do not map 1:1 onto the "
                f"{len(built_blocks)} built decoder stages x "
                f"{lps} layer(s): LMPipeline executes embed->blocks->head "
                f"only (encoder/decoder pipelines are a ROADMAP item)")
        self.stages: list[LMStage] = []
        for name in names:
            if name in ("embed", "head"):
                owners = [name]
            else:
                # built stage i holds layers [i*lps, (i+1)*lps) — slice the
                # per-layer graph nodes with the same arithmetic (floor
                # division over-counts when lps does not divide n_layers)
                i = built_blocks.index(name)
                owners = graph_blocks[i * lps:(i + 1) * lps]
                if not owners:
                    raise ValueError(
                        f"stage {name}: no graph nodes map to it — the "
                        f"graph/built-stage invariant above broke")
                picks = {sel.choices[o] for o in owners}
                if len(picks) > 1:
                    raise ValueError(
                        f"stage {name} groups graph nodes {owners} whose "
                        f"plan choices differ ({sorted(picks)}) — the "
                        f"executor would drop replicas the plan promised; "
                        f"use layers_per_stage=1 or align the plan")
            # a fused stage does the work of all its owners' graph nodes;
            # use every owner's replica slices (nr x n_owners copies, each
            # doing n_owners layers of work -> same planned capacity) so
            # the plan's device budget is not silently idled
            slices = [sl for owner in owners
                      for sl in self.placement.replicas_of(owner)]
            devs, meshes, x_shs, reps = [], [], [], {}
            for k, sl in enumerate(slices):
                handles = sl.resolve(devices)
                mesh = submesh_of(handles)
                devs.append(handles[0])
                meshes.append(mesh)
                if mesh is not None:
                    # tp > 1 on distinct devices: shard the stage's params
                    # over its slice instead of parking them on handles[0]
                    sh = stage_param_shardings(name, init_params[name],
                                               mesh, cfg, policy)
                    reps[k] = jax.device_put(init_params[name], sh)
                    x_shs.append(NamedSharding(mesh, P()))
                else:
                    reps[k] = jax.device_put(init_params[name], handles[0])
                    x_shs.append(None)
            if not devs:
                devs, meshes, x_shs = [devices[0]], [None], [None]
                reps = {0: jax.device_put(init_params[name], devices[0])}
            self.stages.append(LMStage(name=name, fwd=jax.jit(fwds[name]),
                                       params=reps, devices=devs,
                                       x_shardings=x_shs, meshes=meshes))
        self.capacity_blocks = capacity_blocks
        self.workers = workers

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def _n_workers(self) -> int:
        if self.workers is not None:
            return max(1, self.workers)
        return min(16, max(2, sum(len(st.devices) for st in self.stages)))

    def reference(self, microbatches: list) -> list:
        """Unpipelined forward — the same stage fns applied in sequence on
        replica 0; the pipelined run must match this bitwise on CPU."""
        outs = []
        for mb in microbatches:
            x = mb
            for st in self.stages:
                x = st.fwd(st.params[0], jax.device_put(x, st.x_target(0)))
            outs.append(x)
        return outs

    def _edge_fifo(self, producer: LMStage, consumer: LMStage,
                   overlap: bool) -> Fifo:
        # a slot is occupied from producer *dispatch* (reservation) to
        # consumer *retirement* (hold release), so both endpoints' full
        # in-flight complements must fit alongside the buffered tokens:
        # nr x replica_queue reservations on the producer side (else a
        # replicated producer serialises its own replicas on output
        # slots), nr x replica_queue holds on the consumer side, plus
        # ``capacity_blocks`` actually-queued tokens of slack between
        # them — the knob keeps its double-buffering meaning
        nrep = len(consumer.devices)

        def staging(tok):
            mb, y = tok
            return (mb, jax.device_put(y, consumer.x_target(mb % nrep)))

        slots = (len(producer.devices) + len(consumer.devices)) \
            * self.replica_queue
        return Fifo(block=1, capacity_blocks=self.capacity_blocks,
                    min_capacity=self.capacity_blocks + slots,
                    prefetch_fn=staging if overlap else None,
                    prefetch_depth=self.prefetch_blocks
                    * len(consumer.devices) * self.replica_queue)

    def run(self, microbatches: list, *, train: bool = False,
            loss_fn=None, overlap: bool | None = None) -> LMPipelineResult:
        """Stream microbatches through the pipeline.

        Serving (train=False): fill-drain streaming with bounded
        inter-stage buffers — a stage whose output fifo is full skips its
        turn until the consumer drains it.  Training (train=True): 1F1B
        with per-stage vjp backward and grad accumulation;
        ``loss_fn(logits) -> scalar`` seeds the backward (defaults to
        sum-of-logits).  ``overlap`` overrides the pipeline-level knob for
        this run (the benchmark's A/B switch).
        """
        overlap = self.overlap if overlap is None else overlap
        n_micro = len(microbatches)
        S = self.n_stages
        sched = one_f_one_b(S, n_micro) if train else fill_drain(S, n_micro)

        acts = [self._edge_fifo(self.stages[s], self.stages[s + 1], overlap)
                for s in range(S - 1)]             # s -> s+1 activations
        grds = [self._edge_fifo(self.stages[s + 1], self.stages[s], overlap)
                for s in range(S - 1)] if train else None
        res = LMPipelineResult(outputs=[None] * n_micro,
                               placement=self.placement)
        grads = {st.name: None for st in self.stages} if train else None
        raw_losses: dict[int, object] = {}

        programs = [
            _LMStageProgram(s, self, sched[s], acts=acts, grds=grds,
                            res=res, microbatches=microbatches, train=train,
                            loss_fn=loss_fn, grads=grads,
                            raw_losses=raw_losses)
            for s in range(S)]
        engine = Engine(programs, overlap=overlap,
                        workers=self._n_workers(),
                        replica_queue=self.replica_queue)
        er = engine.run()
        res.stage_seconds = er.stage_seconds
        res.stage_firings = er.stage_firings
        res.stage_done_s = er.stage_done_s
        res.op_trace = er.op_trace
        res.max_inflight = er.max_inflight

        # drain the async tail before reading the wall clock
        jax.block_until_ready([o for o in res.outputs if o is not None])
        if grads is not None:
            jax.block_until_ready([g for g in grads.values()
                                   if g is not None])
        res.losses = {mb: float(v) for mb, v in sorted(raw_losses.items())}
        res.mb_done_s.sort()
        res.wall_s = time.perf_counter() - engine.t0
        res.grads = grads
        for s in range(S - 1):
            res.fifo_stats[("act", s)] = acts[s].stats
            if grds is not None:
                res.fifo_stats[("grd", s)] = grds[s].stats
        return res
