"""Metrics registry over the tracer: counters, gauges, histograms — and
the stall-based bottleneck attribution they enable.

`trace.Tracer` records *events*; this module turns them into *numbers*:

  * `MetricsRegistry` — a small labelled counters/gauges/histograms
    store (`registry_from_trace` populates one from a tracer's
    aggregates: per-stage busy/utilization, wait time by reason,
    retire-latency histograms per (stage, replica) — the histograms
    `runtime.straggler.detect_replica_stragglers` consumes).
  * `attribute_bottleneck` — the paper's bottleneck-vs-excess-capacity
    signal read from measurements instead of the analytic model: a
    credit wait on an edge blames the edge's *consumer* (it is too slow
    to drain), a starve blames the *producer* (too slow to fill), so the
    stage with the most blamed time is the measured bottleneck and
    stages with large own-wait time have excess capacity.  Feed the
    resulting ranking to ``planner.replan(measured_ratio=...)`` as a
    second calibration source next to completion-stream ratios.
  * `serving_slo` — per-request serving percentiles (queue wait, TTFT,
    inter-token gap p50/p95/p99) as one flat milliseconds dict, the
    shape `ServeRunResult.slo()` / `LMServer` / ``bench_serve`` report
    and ``tools/bench_compare.py`` diffs warn-only.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .trace import WAIT_CREDIT, WAIT_REORDER, WAIT_STARVE, Tracer

_SAMPLE_CAP = 4096


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Bounded-memory latency histogram: exact percentiles while under
    ``_SAMPLE_CAP`` samples, a deterministic ring reservoir beyond it
    (count/sum/max stay exact either way)."""

    __slots__ = ("samples", "count", "total", "vmax")

    def __init__(self):
        self.samples: list[float] = []
        self.count = 0
        self.total = 0.0
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v
        if len(self.samples) < _SAMPLE_CAP:
            self.samples.append(v)
        else:
            self.samples[self.count % _SAMPLE_CAP] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "max": self.vmax if self.count else float("nan")}


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation noise)."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
    return s[k]


class MetricsRegistry:
    """Labelled metric store: ``registry.counter("x", stage="embed")``
    creates-or-returns the Counter for that (name, labels) pair."""

    def __init__(self):
        self._m: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict):
        key = (name, tuple(sorted(labels.items())))
        m = self._m.get(key)
        if m is None:
            m = self._m[key] = cls()
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name}{labels} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def find(self, name: str) -> list[tuple[dict, object]]:
        """All (labels, metric) pairs registered under ``name``."""
        return [(dict(key[1]), m) for key, m in self._m.items()
                if key[0] == name]

    def to_dict(self) -> dict:
        out: dict = {}
        for (name, labels), m in sorted(self._m.items(),
                                        key=lambda kv: kv[0]):
            val = m.summary() if isinstance(m, Histogram) else m.value
            out.setdefault(name, []).append(
                {"labels": dict(labels), "value": val})
        return out


# ===========================================================================
# tracer -> registry
# ===========================================================================
def registry_from_trace(tracer: Tracer,
                        wall_s: float | None = None) -> MetricsRegistry:
    """Fold a tracer's aggregates into a registry: per-stage busy time
    and utilization (needs ``wall_s`` — the run's makespan in the
    tracer's time unit), wait counters by (stage, reason), and
    retire-latency histograms per (stage, replica)."""
    reg = MetricsRegistry()
    stage_busy: dict[str, float] = {}
    for track, busy in tracer.busy.items():
        stage, _, rep = track.rpartition("/r")
        reg.counter("pipeline.busy_s", stage=stage, replica=rep).inc(busy)
        stage_busy[stage] = stage_busy.get(stage, 0.0) + busy
    for (stage, reason, edge), s in tracer.wait_s.items():
        reg.counter("pipeline.wait_s", stage=stage, reason=reason).inc(s)
        if edge:
            reg.counter("pipeline.edge_wait_s", edge=edge,
                        reason=reason).inc(s)
    for (stage, rep), samples in tracer.retire_samples.items():
        h = reg.histogram("pipeline.retire_latency_us",
                          stage=stage, replica=str(rep))
        for dt in samples:
            h.observe(dt * 1e6)
    for (stage, rep, t_fault, t_rec, n_replayed) in tracer.failovers:
        reg.counter("pipeline.failovers", stage=stage,
                    replica=str(rep)).inc()
        reg.counter("pipeline.replayed_ops", stage=stage,
                    replica=str(rep)).inc(n_replayed)
        reg.histogram("pipeline.recovery_s", stage=stage).observe(
            t_rec - t_fault)
    if wall_s and wall_s > 0:
        n_reps: dict[str, int] = {}
        for track in tracer.busy:
            stage, _, _rep = track.rpartition("/r")
            n_reps[stage] = n_reps.get(stage, 0) + 1
        for stage, busy in stage_busy.items():
            reg.gauge("pipeline.utilization", stage=stage).set(
                min(1.0, busy / (wall_s * n_reps[stage])))
    return reg


# ===========================================================================
# stall-based bottleneck attribution
# ===========================================================================
@dataclass
class BlameEntry:
    stage: str
    blamed: float = 0.0       # wait time this stage *caused* elsewhere
    own_wait: float = 0.0     # wait time this stage *suffered* itself
    busy: float = 0.0         # op time dispatch->retire across replicas

    @property
    def excess(self) -> float:
        """Positive when the stage waits more than it makes others wait —
        the paper's excess-capacity side of the signal."""
        return self.own_wait - self.blamed


def attribute_bottleneck(tracer: Tracer) -> list[BlameEntry]:
    """Rank stages by the wait time they *caused*, descending.

    A credit wait on edge e (producer blocked pushing) means e's consumer
    drains too slowly — blame ``dst``.  A starve on e (consumer blocked
    popping) means e's producer fills too slowly — blame ``src``.
    Reorder waits blame nobody: the tokens exist, a replica retired out
    of order.  Edges the tracer never saw registered (no ``watch_fifo``
    src/dst) contribute to ``own_wait`` only."""
    blame: dict[str, BlameEntry] = {}

    def entry(stage: str) -> BlameEntry:
        e = blame.get(stage)
        if e is None:
            e = blame[stage] = BlameEntry(stage=stage)
        return e

    for (stage, reason, edge), s in tracer.wait_s.items():
        entry(stage).own_wait += s
        w = tracer.fifo_watch.get(edge)
        if w is None or reason == WAIT_REORDER:
            continue
        if reason == WAIT_CREDIT and w.dst:
            entry(w.dst).blamed += s
        elif reason == WAIT_STARVE and w.src:
            entry(w.src).blamed += s
    for track, b in tracer.busy.items():
        stage, sep, rep = track.rpartition("/r")
        if sep and rep.isdigit() and stage in blame:
            blame[stage].busy += b
    return sorted(blame.values(), key=lambda e: -e.blamed)


def stall_bottleneck(tracer: Tracer) -> str | None:
    """The stage the measurements blame most, or None without any waits.

    Blame alone misattributes around an under-sized edge: a producer
    credit-blocked on a burst-rate FIFO blames the consumer even when
    the consumer is nearly idle (the producer itself is the slow stage
    and the edge just can't absorb its burst).  A stage can only be a
    bottleneck while it is *computing*, so the verdict is the stage
    maximising min(blamed, busy) — blame capped by the time the stage
    actually spent busy.  Falls back to raw blame when the trace has no
    op spans (waits-only traces)."""
    ranked = attribute_bottleneck(tracer)
    if not ranked:
        return None
    if any(e.busy > 0 for e in ranked):
        best = max(ranked, key=lambda e: min(e.blamed, e.busy))
        return best.stage if min(best.blamed, best.busy) > 0 else None
    return ranked[0].stage if ranked[0].blamed > 0 else None


# ===========================================================================
# serving SLOs
# ===========================================================================
def serving_slo(queue_wait_s, ttft_s, token_gap_s) -> dict:
    """Per-request serving percentiles as one flat milliseconds dict —
    the SLO block `ServeRunResult.slo()` reports and bench_serve emits."""
    out: dict[str, float] = {}
    for prefix, xs in (("queue_wait", queue_wait_s), ("ttft", ttft_s),
                       ("token_gap", token_gap_s)):
        for p in (50, 95, 99):
            out[f"{prefix}_p{p}_ms"] = percentile(xs, p) * 1e3
    return out
