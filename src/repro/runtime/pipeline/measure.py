"""Measurement layer: measured vs analytic throughput, and replan feedback.

Closes the paper's loop: the solver promises an application inverse
throughput (Eq. 1/5/6 via `core/throughput.analyze`); the executor
(`interpreter.py` / `jax_pipe.py`) measures what the pipeline actually
sustains.  ``compare()`` (interpreter runs) and ``compare_lm()`` (jax
runs) line the two up per stage; ``calibrate()`` scales each node's
implementation library by its measured/analytic ratio; and
``measured_replan()`` re-runs the solver on the calibrated graph — the
measurement-guided re-planning step that turns a one-shot analytic plan
into a feedback loop (plan -> run -> measure -> replan).  Both executor
paths are calibration sources: the overlapped jax executor dispatches a
stage's replicas concurrently and measures completion-event streams, so
its per-stage ratios carry the same ii/nr semantics as the interpreter's
(`planner.replan(measured_ratio=report.ratios())` consumes either).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from ...core import heuristic, ilp
from ...core.fork_join import LITERAL, ForkJoinModel
from ...core.stg import SINK, SOURCE, STG, Node, Selection, scale_impls
from ...core.throughput import analyze
from .interpreter import PipelineRun


@dataclass
class StageMeasurement:
    stage: str
    analytic_v: float          # cycles/firing the model predicts (II / nr)
    measured_v: float          # cycles/firing the pipeline sustained
    replicas: int
    utilization: float

    @property
    def ratio(self) -> float:
        return self.measured_v / self.analytic_v if self.analytic_v > 0 else 1.0


@dataclass
class PipelineReport:
    stages: dict[str, StageMeasurement] = field(default_factory=dict)
    v_app_analytic: float = 0.0    # cycles per graph iteration, model
    v_app_measured: float = 0.0    # cycles per graph iteration, executed
    bottleneck_analytic: str | None = None
    bottleneck_measured: str | None = None
    fifo_stalls: int = 0
    oversubscription: float = 1.0

    @property
    def accuracy(self) -> float:
        """measured / analytic application inverse throughput (1.0 = the
        pipeline delivers exactly what the model promised)."""
        return (self.v_app_measured / self.v_app_analytic
                if self.v_app_analytic > 0 else float("nan"))

    def ratios(self) -> dict[str, float]:
        return {s.stage: s.ratio for s in self.stages.values()}

    def to_json(self) -> str:
        return json.dumps({
            "v_app_analytic": self.v_app_analytic,
            "v_app_measured": self.v_app_measured,
            "accuracy": self.accuracy,
            "bottleneck_analytic": self.bottleneck_analytic,
            "bottleneck_measured": self.bottleneck_measured,
            "fifo_stalls": self.fifo_stalls,
            "oversubscription": self.oversubscription,
            "stages": {n: {"analytic_v": m.analytic_v,
                           "measured_v": m.measured_v,
                           "ratio": m.ratio,
                           "replicas": m.replicas,
                           "utilization": m.utilization}
                       for n, m in self.stages.items()},
        }, indent=2)

    def summary(self) -> str:
        rows = [f"  {m.stage}: model {m.analytic_v:.3g} vs measured "
                f"{m.measured_v:.3g} cyc/firing (x{m.ratio:.2f}), "
                f"util {m.utilization:.0%}"
                for m in sorted(self.stages.values(), key=lambda m: -m.ratio)]
        return (f"pipeline: v_app measured {self.v_app_measured:.3g} vs model "
                f"{self.v_app_analytic:.3g} ({self.accuracy:.2f}x), "
                f"bottleneck {self.bottleneck_measured} "
                f"(model said {self.bottleneck_analytic}), "
                f"{self.fifo_stalls} fifo stalls\n" + "\n".join(rows))


def compare(stg: STG, sel: Selection, run: PipelineRun,
            warmup_frac: float = 0.25) -> PipelineReport:
    """Per-stage measured-vs-analytic report for one executed pipeline.

    ``stg``/``sel`` are the *logical* graph and selection the plan was made
    for; ``run`` is the executor's result on the materialised graph.
    """
    a = analyze(stg, sel)
    q = stg.repetition_vector()
    rep = PipelineReport(
        v_app_analytic=a.v_app,
        bottleneck_analytic=a.bottleneck,
        fifo_stalls=run.channels.total_stalls() if run.channels else 0,
        oversubscription=(run.placement.oversubscription
                          if run.placement else 1.0))
    worst_v, worst_stage = 0.0, None
    firings: dict[str, int] = {}
    for name in stg.nodes:
        workers = run.replica_map.get(name, [name])
        nr = sel.replicas(name)
        impl = sel.impl_of(stg, name)
        firings[name] = sum(len(run.fire_times.get(w, ())) for w in workers)
        try:
            measured = run.stage_inverse_throughput(name, warmup_frac)
        except (ValueError, KeyError):
            continue            # too few firings to call steady state
        util = (sum(run.utilization(w) for w in workers) / len(workers)
                if workers else 0.0)
        m = StageMeasurement(stage=name, analytic_v=impl.ii / nr,
                             measured_v=measured, replicas=nr,
                             utilization=util)
        rep.stages[name] = m
        # normalise to graph iterations for the app-level number
        v_iter = measured * q[name]
        if v_iter > worst_v:
            worst_v, worst_stage = v_iter, name
    if worst_stage is None:
        counts = ", ".join(f"{n}: {c}" for n, c in sorted(firings.items()))
        shortfall = max(4 - c for c in firings.values()) if firings else 4
        raise ValueError(
            f"no stage reached steady state (need >= 4 firings per stage; "
            f"got {counts}) — stream at least {shortfall} more "
            f"iteration(s) of tokens before measuring")
    rep.v_app_measured = worst_v
    rep.bottleneck_measured = worst_stage
    return rep


def compare_lm(stg: STG, sel: Selection, res,
               stage_map: dict[str, str] | None = None) -> PipelineReport:
    """Per-stage measured-vs-analytic report for one jax-path LM run.

    ``res`` is an `jax_pipe.LMPipelineResult`; measured inverse throughput
    comes from each stage's completion-event stream (replicas dispatch
    concurrently under the overlapped executor, so a replicated stage
    reads its effective ii/nr, same semantics as the interpreter path).
    Analytic v is the plan's roofline ii/nr in µs — absolute magnitudes
    differ from host wall-clock by the hardware gap, but the *relative*
    per-stage ratios are exactly what
    ``planner.replan(measured_ratio=report.ratios())`` consumes.
    ``stage_map`` maps graph node -> executed stage name when stages were
    fused (``layers_per_stage > 1``); identity by default.
    """
    a = analyze(stg, sel)
    q = stg.repetition_vector()
    rep = PipelineReport(
        v_app_analytic=a.v_app,
        bottleneck_analytic=a.bottleneck,
        fifo_stalls=sum(s.producer_stalls for s in res.fifo_stats.values()),
        oversubscription=(res.placement.oversubscription
                          if res.placement else 1.0))
    worst_v, worst_stage = 0.0, None
    firings: dict[str, int] = {}
    for name in stg.nodes:
        node = stg.nodes[name]
        if node.kind in (SOURCE, SINK):
            continue
        exec_name = (stage_map or {}).get(name, name)
        firings[name] = len(res.stage_done_s.get(exec_name, ()))
        measured = res.stage_inverse_us(exec_name)
        if firings[name] < 4 or measured != measured:   # nan: never fired
            continue
        nr = sel.replicas(name)
        impl = sel.impl_of(stg, name)
        busy = res.stage_seconds.get(exec_name, 0.0)
        util = min(1.0, busy / (res.wall_s * nr)) if res.wall_s > 0 else 0.0
        rep.stages[name] = StageMeasurement(
            stage=name, analytic_v=impl.ii / nr, measured_v=measured,
            replicas=nr, utilization=util)
        v_iter = measured * q[name]
        if v_iter > worst_v:
            worst_v, worst_stage = v_iter, name
    if worst_stage is None:
        counts = ", ".join(f"{n}: {c}" for n, c in sorted(firings.items()))
        raise ValueError(
            f"no stage reached steady state (need >= 4 completions per "
            f"stage; got {counts}) — stream more microbatches before "
            f"measuring")
    rep.v_app_measured = worst_v
    rep.bottleneck_measured = worst_stage
    return rep


def calibrate(stg: STG, ratios: dict[str, float],
              floor: float = 0.05) -> STG:
    """A copy of ``stg`` whose implementation IIs are scaled per node by the
    measured/analytic ratio — the graph the re-planner should solve."""
    g = STG()
    for name, node in stg.nodes.items():
        impls = scale_impls(node.impls, ratios.get(name, 1.0), floor)
        g.add_node(Node(name=name, impls=impls, in_rates=node.in_rates,
                        out_rates=node.out_rates, kind=node.kind,
                        fn=node.fn, init_state=node.init_state))
    for ch in stg.channels:
        g.add_channel(ch)
    return g


def measured_replan(stg: STG, report: PipelineReport, *,
                    v_tgt: float | None = None,
                    area_budget: float | None = None,
                    fj: ForkJoinModel = LITERAL, engine: str = "heuristic"):
    """Re-solve the trade-off on the measurement-calibrated graph.

    Exactly one of ``v_tgt`` (min-area mode) / ``area_budget``
    (max-throughput mode).  Returns the engine's TradeoffResult whose
    selection reflects *measured* stage behaviour — e.g. a stage that ran
    2x slower than modelled gets proportionally more replicas.
    """
    if (v_tgt is None) == (area_budget is None):
        raise ValueError("pass exactly one of v_tgt= / area_budget=")
    eng = {"ilp": ilp, "heuristic": heuristic}[engine]
    # sources/sinks fire at the app rate, not their (pseudo, ~0-II) impl
    # rate — their measured/analytic ratio is meaningless noise, drop it
    ratios = {n: r for n, r in report.ratios().items()
              if stg.nodes[n].kind not in (SOURCE, SINK)}
    g = calibrate(stg, ratios)
    if v_tgt is not None:
        return eng.min_area(g, v_tgt, fj)
    return eng.max_throughput(g, area_budget, fj)
