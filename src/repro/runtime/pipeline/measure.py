"""Measurement layer: measured vs analytic throughput, and replan feedback.

Closes the paper's loop: the solver promises an application inverse
throughput (Eq. 1/5/6 via `core/throughput.analyze`); the executors
measure what the pipeline actually sustains.  Every executor backend
(interpreter, jax LM pipeline, decode serving pipeline) runs on the
graph-generic engine core and therefore emits the same measurement
surface — per-stage streams of completion/firing times whose steady-state
gap is the stage's effective inverse throughput (ii/nr for replicated
stages).  One report builder (`_build_report`) lines measured values up
against the analytic model for all of them; ``compare()`` (virtual-clock
interpreter runs) and ``compare_lm()`` (wall-clock jax runs) are thin
unit adapters over it, not separate comparison logics.

``calibrate()`` scales each node's implementation library by its
measured/analytic ratio; ``measured_replan()`` re-runs the solver once on
the calibrated graph; and ``replan_to_fixed_point()`` iterates the whole
loop — plan -> run -> measure -> replan — to a fixed point with geometric
damping and an oscillation guard (a measured-slow stage gains replicas,
which changes what is measured, which changes the plan ...; undamped, the
solver can flip between two selections forever).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable

from ...core import heuristic, ilp
from ...core.fork_join import LITERAL, ForkJoinModel
from ...core.stg import SINK, SOURCE, STG, Node, Selection, scale_impls
from ...core.throughput import analyze
from .interpreter import PipelineRun


@dataclass
class StageMeasurement:
    stage: str
    analytic_v: float          # cycles/firing the model predicts (II / nr)
    measured_v: float          # cycles/firing the pipeline sustained
    replicas: int
    utilization: float
    host_v: float | None = None    # host dispatch overhead per firing (us,
    #                                wall-clock backends; None under the
    #                                virtual clock) — dispatch cost as its
    #                                own column, not folded into measured_v
    stall_v: float | None = None   # total time blocked on a full output
    #                                fifo (credit wait: downstream is the
    #                                bottleneck) — native unit (s wall /
    #                                cycles virtual); None when untraced
    starve_v: float | None = None  # total time blocked on an empty input
    #                                fifo (starve + reorder wait: upstream
    #                                is the bottleneck); None when untraced

    @property
    def ratio(self) -> float:
        return self.measured_v / self.analytic_v if self.analytic_v > 0 else 1.0


@dataclass
class PipelineReport:
    stages: dict[str, StageMeasurement] = field(default_factory=dict)
    v_app_analytic: float = 0.0    # cycles per graph iteration, model
    v_app_measured: float = 0.0    # cycles per graph iteration, executed
    bottleneck_analytic: str | None = None
    bottleneck_measured: str | None = None
    fifo_stalls: int = 0
    oversubscription: float = 1.0
    slo: dict | None = None        # serving-SLO percentiles (flat ms dict,
    #                                `metrics.serving_slo`) when the run
    #                                was a serve; None for batch runs

    @property
    def accuracy(self) -> float:
        """measured / analytic application inverse throughput (1.0 = the
        pipeline delivers exactly what the model promised)."""
        return (self.v_app_measured / self.v_app_analytic
                if self.v_app_analytic > 0 else float("nan"))

    def ratios(self) -> dict[str, float]:
        return {s.stage: s.ratio for s in self.stages.values()}

    def to_json(self) -> str:
        # per-stage metrics that never fired (host on the virtual clock,
        # stall/starve on untraced runs) are omitted, not emitted as null
        def stage_dict(m: StageMeasurement) -> dict:
            d = {"analytic_v": m.analytic_v, "measured_v": m.measured_v,
                 "ratio": m.ratio, "replicas": m.replicas,
                 "utilization": m.utilization, "host_us": m.host_v,
                 "stall": m.stall_v, "starve": m.starve_v}
            return {k: v for k, v in d.items() if v is not None}

        top = {
            "v_app_analytic": self.v_app_analytic,
            "v_app_measured": self.v_app_measured,
            "accuracy": self.accuracy,
            "bottleneck_analytic": self.bottleneck_analytic,
            "bottleneck_measured": self.bottleneck_measured,
            "fifo_stalls": self.fifo_stalls,
            "oversubscription": self.oversubscription,
            "stages": {n: stage_dict(m) for n, m in self.stages.items()},
        }
        if self.slo is not None:
            top["slo"] = self.slo
        return json.dumps(top, indent=2)

    def summary(self) -> str:
        def cols(m: StageMeasurement) -> str:
            # host always gets a column; `-` marks not-applicable (virtual
            # clock) so rows stay alignable.  stall/starve appear only on
            # traced runs — total blocked time in the run's native unit.
            out = (f", host {m.host_v:.0f}us/firing"
                   if m.host_v is not None else ", host -")
            if m.stall_v is not None:
                out += f", stall {m.stall_v:.3g}"
            if m.starve_v is not None:
                out += f", starve {m.starve_v:.3g}"
            return out

        rows = [f"  {m.stage}: model {m.analytic_v:.3g} vs measured "
                f"{m.measured_v:.3g} cyc/firing (x{m.ratio:.2f}), "
                f"util {m.utilization:.0%}" + cols(m)
                for m in sorted(self.stages.values(), key=lambda m: -m.ratio)]
        head = (f"pipeline: v_app measured {self.v_app_measured:.3g} vs model "
                f"{self.v_app_analytic:.3g} ({self.accuracy:.2f}x), "
                f"bottleneck {self.bottleneck_measured} "
                f"(model said {self.bottleneck_analytic}), "
                f"{self.fifo_stalls} fifo stalls")
        if self.slo is not None:
            head += ("\n  slo: " + ", ".join(
                f"{k}={v:.2f}" for k, v in self.slo.items()))
        return head + "\n" + "\n".join(rows)


# ===========================================================================
# one comparison core for every engine backend
# ===========================================================================
def _build_report(stg: STG, sel: Selection, *,
                  measured_of: Callable[[str], float | None],
                  firings_of: Callable[[str], int],
                  util_of: Callable[[str], float],
                  fifo_stalls: int, oversubscription: float,
                  skip_kinds: tuple = (),
                  host_of: Callable[[str], float | None] = lambda name: None,
                  stall_of: Callable[[str], float | None] = lambda name: None,
                  starve_of: Callable[[str], float | None] = lambda name: None,
                  err_noun: str = "firings",
                  err_hint: Callable[[dict], str] = lambda counts: "") \
        -> PipelineReport:
    """Line one executed run's measured per-stage inverse throughput up
    against the analytic model — the single comparison rule for every
    engine backend.  ``measured_of`` returns a stage's steady-state
    measured value or None (no steady state yet; the stage is skipped
    rather than calibrated on a degraded sample)."""
    a = analyze(stg, sel)
    q = stg.repetition_vector()
    rep = PipelineReport(
        v_app_analytic=a.v_app,
        bottleneck_analytic=a.bottleneck,
        fifo_stalls=fifo_stalls,
        oversubscription=oversubscription)
    worst_v, worst_stage = 0.0, None
    firings: dict[str, int] = {}
    for name in stg.nodes:
        if stg.nodes[name].kind in skip_kinds:
            continue
        firings[name] = firings_of(name)
        measured = measured_of(name)
        if measured is None:
            continue            # too few firings to call steady state
        nr = sel.replicas(name)
        impl = sel.impl_of(stg, name)
        rep.stages[name] = StageMeasurement(
            stage=name, analytic_v=impl.ii / nr, measured_v=measured,
            replicas=nr, utilization=util_of(name), host_v=host_of(name),
            stall_v=stall_of(name), starve_v=starve_of(name))
        # normalise to graph iterations for the app-level number
        v_iter = measured * q[name]
        if v_iter > worst_v:
            worst_v, worst_stage = v_iter, name
    if worst_stage is None:
        counts = ", ".join(f"{n}: {c}" for n, c in sorted(firings.items()))
        raise ValueError(
            f"no stage reached steady state (need >= 4 {err_noun} per "
            f"stage; got {counts}){err_hint(firings)}")
    rep.v_app_measured = worst_v
    rep.bottleneck_measured = worst_stage
    return rep


def compare(stg: STG, sel: Selection, run: PipelineRun,
            warmup_frac: float = 0.25) -> PipelineReport:
    """Per-stage measured-vs-analytic report for one interpreter run.

    ``stg``/``sel`` are the *logical* graph and selection the plan was made
    for; ``run`` is the executor's result on the materialised graph.
    """
    def measured_of(name: str) -> float | None:
        try:
            return run.stage_inverse_throughput(name, warmup_frac)
        except (ValueError, KeyError):
            return None

    def firings_of(name: str) -> int:
        workers = run.replica_map.get(name, [name])
        return sum(len(run.fire_times.get(w, ())) for w in workers)

    def util_of(name: str) -> float:
        workers = run.replica_map.get(name, [name])
        return (sum(run.utilization(w) for w in workers) / len(workers)
                if workers else 0.0)

    def hint(firings: dict) -> str:
        shortfall = max(4 - c for c in firings.values()) if firings else 4
        return (f" — stream at least {shortfall} more iteration(s) of "
                f"tokens before measuring")

    def wait_of(name: str, reasons: tuple) -> float | None:
        # traced runs only: sum the stage's replicas' blocked cycles
        if not run.wait_cycles:
            return None
        workers = run.replica_map.get(name, [name])
        return sum(run.wait_cycles.get(w, {}).get(r, 0.0)
                   for w in workers for r in reasons)

    return _build_report(
        stg, sel, measured_of=measured_of, firings_of=firings_of,
        util_of=util_of,
        stall_of=lambda n: wait_of(n, ("credit",)),
        starve_of=lambda n: wait_of(n, ("starve", "reorder")),
        fifo_stalls=run.channels.total_stalls() if run.channels else 0,
        oversubscription=(run.placement.oversubscription
                          if run.placement else 1.0),
        err_noun="firings", err_hint=hint)


def compare_lm(stg: STG, sel: Selection, res,
               stage_map: dict[str, str] | None = None) -> PipelineReport:
    """Per-stage measured-vs-analytic report for one jax-path LM run.

    ``res`` is an `jax_pipe.LMPipelineResult`; measured inverse throughput
    comes from each stage's completion-event stream (replicas dispatch
    concurrently under the overlapped executor, so a replicated stage
    reads its effective ii/nr, same semantics as the interpreter path).
    Analytic v is the plan's roofline ii/nr in µs — absolute magnitudes
    differ from host wall-clock by the hardware gap, but the *relative*
    per-stage ratios are exactly what
    ``planner.replan(measured_ratio=report.ratios())`` consumes.
    ``stage_map`` maps graph node -> executed stage name when stages were
    fused (``layers_per_stage > 1``); identity by default.
    """
    def exec_name(name: str) -> str:
        return (stage_map or {}).get(name, name)

    def measured_of(name: str) -> float | None:
        if firings_of(name) < 4:
            return None
        v = res.stage_inverse_us(exec_name(name))
        return None if v != v else v            # nan: never fired

    def firings_of(name: str) -> int:
        return len(res.stage_done_s.get(exec_name(name), ()))

    def util_of_nr(name: str) -> float:
        busy = res.stage_seconds.get(exec_name(name), 0.0)
        nr = sel.replicas(name)
        return min(1.0, busy / (res.wall_s * nr)) if res.wall_s > 0 else 0.0

    def host_of(name: str) -> float | None:
        # host dispatch us/firing off the engine's per-op accounting
        # (`EngineResult.stage_host_us`); nan -> None (stage never fired)
        v = res.stage_host_us(exec_name(name))
        return None if v != v else v

    def wait_of(name: str, reasons: tuple) -> float | None:
        # traced runs only (`res.stage_wait_s` fills under a Tracer):
        # seconds the stage's sweep slot sat blocked, by reason
        waits = getattr(res, "stage_wait_s", None)
        if not waits:
            return None
        d = waits.get(exec_name(name), {})
        return sum(d.get(r, 0.0) for r in reasons)

    rep = _build_report(
        stg, sel, measured_of=measured_of, firings_of=firings_of,
        util_of=util_of_nr, host_of=host_of,
        stall_of=lambda n: wait_of(n, ("credit",)),
        starve_of=lambda n: wait_of(n, ("starve", "reorder")),
        fifo_stalls=sum(s.producer_stalls for s in res.fifo_stats.values()),
        oversubscription=(res.placement.oversubscription
                          if res.placement else 1.0),
        skip_kinds=(SOURCE, SINK),
        err_noun="completions",
        err_hint=lambda _: " — stream more microbatches before measuring")
    slo_fn = getattr(res, "slo", None)      # serve runs carry client SLOs
    if callable(slo_fn):
        rep.slo = slo_fn()
    return rep


def measured_bubble(run) -> float:
    """Measured pipeline-bubble fraction of one executed run: the idle
    share of the run's total stage-time budget,

        1 - sum(per-stage busy) / (n_stages * makespan)

    Works on either clock domain's result — an `engine.EngineResult` (or
    a backend result aliasing its fields: busy = ``stage_seconds``,
    makespan = ``wall_s``) or an `engine.EventLoopStats` (busy =
    ``busy_cycles``, makespan = ``cycles``) — and lines up against the
    analytic `schedule.fill_drain_bubble` / `schedule.interleaved_bubble`
    ceilings.  Wall-clock values on oversubscribed pools mix bubble with
    time-sharing; the virtual-clock domain (`schedule.simulate_schedule`)
    measures the schedule's own dynamics cleanly."""
    if hasattr(run, "busy_cycles"):               # EventLoopStats
        busy, span, n = (sum(run.busy_cycles.values()), run.cycles,
                         len(run.busy_cycles))
    else:                                         # EngineResult-shaped
        busy, span, n = (sum(run.stage_seconds.values()), run.wall_s,
                         len(run.stage_seconds))
    if span <= 0 or n == 0:
        return float("nan")
    return 1.0 - busy / (n * span)


def calibrate(stg: STG, ratios: dict[str, float],
              floor: float = 0.05) -> STG:
    """A copy of ``stg`` whose implementation IIs are scaled per node by the
    measured/analytic ratio — the graph the re-planner should solve."""
    g = STG()
    for name, node in stg.nodes.items():
        impls = scale_impls(node.impls, ratios.get(name, 1.0), floor)
        g.add_node(Node(name=name, impls=impls, in_rates=node.in_rates,
                        out_rates=node.out_rates, kind=node.kind,
                        fn=node.fn, init_state=node.init_state))
    for ch in stg.channels:
        g.add_channel(ch)
    return g


def measured_replan(stg: STG, report: PipelineReport, *,
                    v_tgt: float | None = None,
                    area_budget: float | None = None,
                    fj: ForkJoinModel = LITERAL, engine: str = "heuristic"):
    """Re-solve the trade-off on the measurement-calibrated graph.

    Exactly one of ``v_tgt`` (min-area mode) / ``area_budget``
    (max-throughput mode).  Returns the engine's TradeoffResult whose
    selection reflects *measured* stage behaviour — e.g. a stage that ran
    2x slower than modelled gets proportionally more replicas.
    """
    if (v_tgt is None) == (area_budget is None):
        raise ValueError("pass exactly one of v_tgt= / area_budget=")
    eng = {"ilp": ilp, "heuristic": heuristic}[engine]
    # sources/sinks fire at the app rate, not their (pseudo, ~0-II) impl
    # rate — their measured/analytic ratio is meaningless noise, drop it
    ratios = {n: r for n, r in report.ratios().items()
              if stg.nodes[n].kind not in (SOURCE, SINK)}
    g = calibrate(stg, ratios)
    if v_tgt is not None:
        return eng.min_area(g, v_tgt, fj)
    return eng.max_throughput(g, area_budget, fj)


# ===========================================================================
# measured-replan convergence loop
# ===========================================================================
@dataclass
class FixedPointStep:
    iteration: int
    selection: dict                 # node -> (impl, nr) at this step
    scale: dict[str, float]         # cumulative calibration applied
    measured: dict[str, float]      # ratios the run reported (vs original)
    residual: float                 # max |log(measured / scale)| this step
    total_area: float
    v_app: float


@dataclass
class FixedPointResult:
    result: object                  # the final engine TradeoffResult
    iterations: int
    converged: bool
    oscillated: bool                # a selection cycle was detected
    scale: dict[str, float]         # final per-node calibration
    history: list[FixedPointStep] = field(default_factory=list)

    @property
    def selection(self) -> Selection:
        return self.result.selection


def replan_to_fixed_point(stg: STG, run_fn, *,
                          v_tgt: float | None = None,
                          area_budget: float | None = None,
                          fj: ForkJoinModel = LITERAL,
                          engine: str = "heuristic",
                          max_iters: int = 10, damping: float = 0.5,
                          damping_floor: float = 0.1) -> FixedPointResult:
    """Iterate plan -> run -> measure -> replan to a fixed point.

    ``measured_replan`` is one feedback step; this is the loop.  Each
    iteration solves the trade-off on the ``scale``-calibrated graph,
    executes the chosen selection via ``run_fn(selection) ->
    dict[node, measured/analytic ratio]`` (or a `PipelineReport`, whose
    ``ratios()`` is used; ratios are vs the ORIGINAL graph's analytic
    model), and folds the measurement into the calibration with
    *geometric damping*:

        scale <- scale^(1-a) * measured^a        (a = ``damping``)

    ``damping=1`` is the undamped jump straight to the measured ratio —
    which oscillates whenever the measured ratio is itself a function of
    the selection (a stage measured slow at nr=1 gains a replica, then
    measures fast, loses it again, forever); damping keeps the memory of
    earlier measurements, so the calibration settles inside the band
    where the solver's choice is stable.  The **oscillation guard**
    detects a repeated non-consecutive selection, halves the damping, and
    continues; if the cycle persists at ``damping_floor`` the loop stops
    and returns the best (lowest measured bottleneck-v) selection seen,
    flagged ``oscillated=True`` — never an infinite loop.

    Converged when the solver returns the same selection twice in a row —
    the fixed point of the plan -> run -> replan map is a *plan* the
    re-solve reproduces (per-node log-residuals are recorded in
    ``history`` for anyone polishing the calibration further).
    """
    if (v_tgt is None) == (area_budget is None):
        raise ValueError("pass exactly one of v_tgt= / area_budget=")
    eng = {"ilp": ilp, "heuristic": heuristic}[engine]

    def solve(g):
        return (eng.min_area(g, v_tgt, fj) if v_tgt is not None
                else eng.max_throughput(g, area_budget, fj))

    scale = {n: 1.0 for n in stg.nodes}
    alpha = min(1.0, max(damping, 0.0))
    history: list[FixedPointStep] = []
    seen: dict[tuple, int] = {}            # selection key -> iteration
    prev_key = None
    best = None                            # (v_app, result, scale snapshot)
    res = None
    converged = oscillated = False

    for it in range(max_iters):
        res = solve(calibrate(stg, scale))
        key = tuple(sorted(res.selection.choices.items()))
        measured = run_fn(res.selection)
        if hasattr(measured, "ratios"):
            measured = measured.ratios()
        measured = {n: r for n, r in measured.items()
                    if stg.nodes[n].kind not in (SOURCE, SINK)}
        residual = max((abs(math.log(max(r, 1e-9) / scale[n]))
                        for n, r in measured.items()), default=0.0)
        history.append(FixedPointStep(
            iteration=it, selection=dict(res.selection.choices),
            scale=dict(scale), measured=dict(measured), residual=residual,
            total_area=res.total_area, v_app=res.v_app))
        if best is None or res.v_app < best[0]:
            best = (res.v_app, res, dict(scale))
        if key == prev_key:
            converged = True
            break
        if key in seen:
            # revisited an earlier selection (an adjacent repeat already
            # returned converged above): we are cycling.  Damp harder;
            # below the floor, stop with the best seen.
            oscillated = True
            alpha = alpha / 2
            if alpha < damping_floor:
                _, res, scale = best
                break
        seen[key] = it
        prev_key = key
        for n, r in measured.items():
            scale[n] = scale[n] ** (1 - alpha) * max(r, 1e-9) ** alpha
    return FixedPointResult(result=res, iterations=len(history),
                            converged=converged, oscillated=oscillated,
                            scale=scale, history=history)
