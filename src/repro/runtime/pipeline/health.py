"""Straggler-driven self-healing: the control loop between observability
and mitigation.

The observability layer (PR 6) can already *see* a sick replica —
`runtime.straggler.detect_replica_stragglers` flags any replica whose
median retire latency drifts past ``threshold`` x its peers.  This module
closes the loop: `HealthController.tick` runs inside the engine's retire
path (every ``check_every`` retirements, via ``Engine(on_tick=...)``),
folds the live trace into a metrics registry, and acts on what it finds:

  1. **Rebalance** — ask the flagged stage's program to shed work off the
     slow replica (``prog.shed_replica(rep, n)``: migrate up to ``n``
     resident groups onto the least-loaded healthy peer).  This is cheap
     and reversible — the replica stays in rotation for its remaining
     groups, it just carries fewer of them.
  2. **Escalate** — a replica flagged on ``replan_after`` consecutive
     ticks is not noise, it is a systematically slow part; per the
     paper's measurement-guided flow the right response is a *re-plan*
     with measured ratios, not more migration.  The controller distills
     the straggler reports into a per-stage measured/analytic ratio dict
     (`replan_advice`) shaped for ``planner.replan(measured_ratio=...)``
     and invokes ``replan_fn(advice)`` when one is attached.  It never
     calls the planner itself: swapping a plan means draining and
     resharding (see `runtime.elastic.rescale_serving`), a decision the
     serving layer owns.

The controller is deliberately engine-agnostic: it only needs
``engine.programs`` (for ``shed_replica``) and a tracer, so the same
instance can watch a `DecodePipeline.serve` run or an `LMPipeline.run`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..straggler import StragglerReport, detect_replica_stragglers
from .metrics import registry_from_trace
from .trace import Tracer


@dataclass
class HealthController:
    """Periodic straggler check + mitigation, driven by the engine.

    Wire it with ``Engine(..., on_tick=hc.tick, tick_every=hc.check_every)``
    — `DecodePipeline.serve(health=hc)` does exactly that.  After the run,
    ``migrations`` counts groups moved off slow replicas, ``strikes``
    holds per-(stage, replica) consecutive-flag counts, and
    ``replan_advice`` (when escalation triggered) is the measured-ratio
    dict to feed ``planner.replan(measured_ratio=...)``.
    """
    tracer: Tracer
    threshold: float = 1.5
    min_samples: int = 8
    check_every: int = 32
    migrate_per_tick: int = 1
    replan_after: int = 2
    replan_fn: object | None = None     # callable(advice: dict) | None
    migrations: int = 0
    ticks: int = 0
    strikes: dict[tuple, int] = field(default_factory=dict)
    reports: list[StragglerReport] = field(default_factory=list)
    replan_advice: dict | None = None
    log: list[str] = field(default_factory=list)

    def tick(self, engine) -> list[StragglerReport]:
        """One health check: detect, rebalance, maybe escalate."""
        self.ticks += 1
        reg = registry_from_trace(self.tracer)
        found = detect_replica_stragglers(
            reg, threshold=self.threshold, min_samples=self.min_samples)
        self.reports.extend(found)
        flagged = {(r.stage, r.replica) for r in found}
        # a clean tick clears a replica's strike count: "consecutive" is
        # the difference between a GC pause and a sick part
        for key in [k for k in self.strikes if k not in flagged]:
            self.strikes.pop(key)
        by_name = {p.name: p for p in getattr(engine, "programs", [])
                   if hasattr(p, "name")}
        for r in found:
            self.strikes[(r.stage, r.replica)] = \
                self.strikes.get((r.stage, r.replica), 0) + 1
            prog = by_name.get(r.stage)
            shed = getattr(prog, "shed_replica", None)
            if shed is not None and self.migrate_per_tick > 0:
                moved = shed(r.replica, self.migrate_per_tick)
                self.migrations += moved
                if moved:
                    self.log.append(
                        f"tick {self.ticks}: moved {moved} group(s) off "
                        f"{r.stage}/r{r.replica} ({r.describe()})")
        if any(n >= self.replan_after for n in self.strikes.values()):
            self.replan_advice = self._advice()
            if self.replan_fn is not None:
                self.replan_fn(self.replan_advice)
        return found

    def _advice(self) -> dict[str, float]:
        """Per-stage measured slowdown ratios for the planner.

        A stage with a straggling replica effectively runs at the
        straggler's pace for the groups it owns; the advice reports the
        worst observed replica-vs-peer ratio per stage so the re-solve
        sizes that stage as if every op cost that much more."""
        advice: dict[str, float] = {}
        for r in self.reports:
            advice[r.stage] = max(advice.get(r.stage, 1.0), r.ratio)
        return advice
