"""Host streaming executor: run a planned STG as a real pipeline.

Where `core/simulate.py` *simulates* (unbounded FIFOs, one global event
loop, no notion of hardware), this module *executes*: the Selection is
materialised into replicas + fork/join routing (`core/transform.py`), every
worker is pinned to a device slice (`placement.py`), inter-stage buffers
are bounded double-buffered FIFOs with backpressure (`channels.py`), and
devices that host more than one worker are time-shared through per-device
busy clocks.  Node functions run for real (numpy), so sink streams are the
actual program output — bitwise comparable against the KPN simulator — and
firing timestamps give *measured* steady-state inverse throughput per
stage, comparable against `core/throughput.analyze`.

Firing rule (deterministic, KPN + backpressure):
  a worker may fire at time t when
    * every required input port holds a full rate-block visible by t
      (JOIN: only the round-robin-scheduled port),
    * every output FIFO that will receive tokens has space
      (FORK: only the scheduled port),
    * the worker is free (t >= worker II clock) and its devices are free.
  Among fireable workers the earliest (t, name) fires; outputs become
  visible at t + latency; worker and devices are busy for II cycles.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from ...core.fork_join import LITERAL, ForkJoinModel
from ...core.stg import FORK, JOIN, STG, Selection
from ...core.transform import ReplicatedGraph, materialize
from .channels import ChannelSet
from .placement import Placement, StageSlice, place


@dataclass
class PipelineRun:
    """Result of one streaming execution."""
    outputs: dict[str, list] = field(default_factory=dict)     # sink worker -> tokens
    fire_times: dict[str, list[float]] = field(default_factory=dict)
    fired: dict[str, int] = field(default_factory=dict)
    cycles: float = 0.0
    placement: Placement | None = None
    channels: ChannelSet | None = None
    replica_map: dict[str, list[str]] = field(default_factory=dict)
    busy_cycles: dict[str, float] = field(default_factory=dict)

    def inverse_throughput(self, worker: str, warmup_frac: float = 0.25) -> float:
        """Steady-state cycles per firing at one worker (drop pipeline fill)."""
        times = self.fire_times[worker]
        if len(times) < 4:
            raise ValueError(f"too few firings at {worker} ({len(times)})")
        k = max(1, int(len(times) * warmup_frac))
        window = times[k:]
        return (window[-1] - window[0]) / (len(window) - 1)

    def stage_inverse_throughput(self, stage: str,
                                 warmup_frac: float = 0.25) -> float:
        """Effective cycles per firing of a (possibly replicated) stage:
        merge all replicas' firings — round-robin replicas interleave, so
        the merged stream fires nr-times faster than one replica."""
        workers = self.replica_map.get(stage, [stage])
        merged = sorted(t for w in workers for t in self.fire_times[w])
        if len(merged) < 4:
            raise ValueError(f"too few firings at stage {stage}")
        k = max(1, int(len(merged) * warmup_frac))
        window = merged[k:]
        return (window[-1] - window[0]) / (len(window) - 1)

    def utilization(self, worker: str) -> float:
        times = self.fire_times[worker]
        if len(times) < 2:
            return 0.0
        span = times[-1] - times[0]
        return min(1.0, self.busy_cycles[worker] / span) if span > 0 else 1.0


def execute(stg: STG, sel: Selection, inputs: dict[str, list], *,
            devices=None, capacity_blocks: int = 2,
            fj: ForkJoinModel = LITERAL, max_firings: int = 1_000_000,
            max_cycles: float = 1e12) -> PipelineRun:
    """Materialise, place, and stream ``inputs`` through the pipeline."""
    rg: ReplicatedGraph = materialize(stg, sel, fj)
    pl = place(stg, sel, devices, replica_map=rg.replica_map)
    # Fork/join workers are routing fabric, not pool PEs: each gets its own
    # router slot so tree hops don't contend with compute time-sharing.
    for name in rg.fork_join_nodes:
        pl.slices[name] = StageSlice(stage=name, worker=name, replica=0,
                                     tp=1, devices=(("router", name),))
    return execute_materialized(rg, pl, inputs,
                                capacity_blocks=capacity_blocks,
                                max_firings=max_firings,
                                max_cycles=max_cycles)


def execute_materialized(rg: ReplicatedGraph, pl: Placement,
                         inputs: dict[str, list], *,
                         capacity_blocks: int = 2,
                         max_firings: int = 1_000_000,
                         max_cycles: float = 1e12) -> PipelineRun:
    g = rg.stg
    sel = rg.selection
    for n in inputs:
        if n not in g.nodes:
            raise ValueError(f"inputs key {n!r} is not a node of the "
                             f"materialised graph (sources: {g.sources()})")
        if g.in_channels(n):
            raise ValueError(f"inputs key {n!r} is not a source node")
    run = PipelineRun(placement=pl, replica_map=dict(rg.replica_map))
    cs = ChannelSet.for_graph(g, capacity_blocks=capacity_blocks)
    run.channels = cs

    in_chs = {n: g.in_channels(n) for n in g.nodes}
    out_chs = {n: g.out_channels(n) for n in g.nodes}
    state = {n: g.nodes[n].init_state for n in g.nodes}
    node_free = {n: 0.0 for n in g.nodes}
    dev_free: dict = {}
    dev_workers: dict = {}
    for w, sl in pl.slices.items():
        for d in sl.devices:
            dev_free.setdefault(d, 0.0)
            dev_workers.setdefault(d, set()).add(w)
    src_streams = {n: list(toks) for n, toks in inputs.items()}
    src_pos = {n: 0 for n in src_streams}
    for n in g.nodes:
        run.fired[n] = 0
        run.fire_times[n] = []
        run.busy_cycles[n] = 0.0
        if not out_chs[n]:
            run.outputs[n] = []

    def required_out_ports(name: str) -> list[int]:
        node = g.nodes[name]
        if node.kind == FORK:
            return [state[name] or 0]
        return [ch.src_port for ch in out_chs[name]]

    def ready_time(name: str, count_stall: bool = False) -> float | None:
        """Earliest fire time, or None if blocked on tokens/space.

        ``count_stall``: record a producer stall on the blocking fifo —
        set only on the heap-pop re-check, so FifoStats counts scheduled
        firings actually deferred, not readiness probes."""
        node = g.nodes[name]
        chans = in_chs[name]
        sl = pl.slices.get(name)
        t = node_free[name]
        if sl is not None:
            for d in sl.devices:
                t = max(t, dev_free[d])
        # inputs
        if not chans:   # source: finite stream
            n_need = node.out_rates[0]
            if name not in src_streams or \
                    src_pos[name] + n_need > len(src_streams[name]):
                return None
        elif node.kind == JOIN:
            k = state[name] or 0
            q = cs[chans[k].key()]
            rt = q.ready_time(node.in_rates[k])
            if rt is None:
                return None
            t = max(t, rt)
        else:
            for ch in chans:
                q = cs[ch.key()]
                rt = q.ready_time(node.in_rates[ch.dst_port])
                if rt is None:
                    return None
                t = max(t, rt)
        # backpressure: every port fired into must have block space now
        need_ports = set(required_out_ports(name))
        for ch in out_chs[name]:
            if ch.src_port in need_ports:
                q = cs[ch.key()]
                if not q.can_push(g.nodes[name].out_rates[ch.src_port]):
                    if count_stall:
                        q.note_stall()
                    return None
        return t

    seq = 0
    heap: list[tuple[float, int, str]] = []

    def push_candidate(name: str) -> None:
        nonlocal seq
        t = ready_time(name)
        if t is not None:
            heapq.heappush(heap, (t, seq, name))
            seq += 1

    for n in g.nodes:
        push_candidate(n)

    total_fired = 0
    hit_cycle_cap = False
    while heap and total_fired < max_firings:
        now, _, name = heapq.heappop(heap)
        if now > max_cycles:
            hit_cycle_cap = True
            break
        t = ready_time(name, count_stall=True)
        if t is None:
            continue            # became blocked; a pop/firing will requeue it
        if t > now:
            heapq.heappush(heap, (t, seq, name))
            seq += 1
            continue
        node = g.nodes[name]
        impl = sel.impl_of(g, name)
        # -- consume ---------------------------------------------------------
        ins: list[list] = [[] for _ in range(max(1, node.n_in))]
        popped_from: list[str] = []
        if in_chs[name]:
            if node.kind == JOIN:
                k = state[name] or 0
                ch = in_chs[name][k]
                ins[k] = cs[ch.key()].pop(node.in_rates[k])
                popped_from.append(ch.src)
            else:
                for ch in in_chs[name]:
                    ins[ch.dst_port] = cs[ch.key()].pop(node.in_rates[ch.dst_port])
                    popped_from.append(ch.src)
        else:
            n_need = node.out_rates[0]
            p = src_pos[name]
            ins[0] = src_streams[name][p:p + n_need]
            src_pos[name] = p + n_need
        # -- compute ---------------------------------------------------------
        if node.fn is not None:
            outs, state[name] = node.fn(ins, state[name])
        elif not in_chs[name]:
            outs = [ins[0]]
        else:
            outs = ([list(ins[0]) for _ in range(node.n_out)]
                    if out_chs[name] else [list(ins[0])])
        # -- produce ---------------------------------------------------------
        done = now + (impl.latency or impl.ii)
        if out_chs[name]:
            for ch in out_chs[name]:
                toks = outs[ch.src_port]
                if toks:
                    cs[ch.key()].push(toks, done)
        else:
            for port_out in outs:
                run.outputs[name].extend(port_out)
        run.fired[name] += 1
        run.fire_times[name].append(now)
        run.busy_cycles[name] += impl.ii
        total_fired += 1
        node_free[name] = now + impl.ii
        sl = pl.slices.get(name)
        if sl is not None:
            for d in sl.devices:
                dev_free[d] = now + impl.ii
        run.cycles = max(run.cycles, done)
        # -- wake ups: self, data consumers, space producers, device sharers -
        cand = {name}
        cand.update(ch.dst for ch in out_chs[name])
        cand.update(popped_from)
        if sl is not None:
            for d in sl.devices:
                cand.update(dev_workers[d])
        for c in cand:
            push_candidate(c)
    # wedge guard: the loop ending with a full source block unconsumed means
    # no node could ever fire again (undersized buffer / malformed graph) —
    # fail loudly rather than hand back a silently-truncated stream.  Not a
    # wedge: the caller's own max_firings / max_cycles caps stopped us.
    if total_fired < max_firings and not hit_cycle_cap:
        for n, stream in src_streams.items():
            left = len(stream) - src_pos[n]
            if left >= g.nodes[n].out_rates[0]:
                raise RuntimeError(
                    f"pipeline wedged: source {n} has {left} unconsumed "
                    f"tokens but no node can fire (fired={run.fired})")
    return run
