"""Host streaming executor: run a planned STG as a real pipeline.

Where `core/simulate.py` *simulates* (unbounded FIFOs, one global event
loop, no notion of hardware), this module *executes*: the Selection is
materialised into replicas + fork/join routing (`core/transform.py`), every
worker is pinned to a device slice (`placement.py`), inter-stage buffers
are bounded double-buffered FIFOs with backpressure (`channels.py`), and
devices that host more than one worker are time-shared through per-device
busy clocks.  Node functions run for real (numpy), so sink streams are the
actual program output — bitwise comparable against the KPN simulator — and
firing timestamps give *measured* steady-state inverse throughput per
stage, comparable against `core/throughput.analyze`.

The event loop itself is the graph-generic executor core's virtual-clock
driver (`engine.run_event_loop`): this module only defines the per-node
*program* (`_HostNode`, an `engine.Program` — the same protocol the
wall-clock `Engine` drives) — KPN firing rules, FORK/JOIN routing state,
multirate token blocks, source streams, and per-device busy clocks.  The
loop owns the heap, candidate re-queueing, wake-set propagation, and the
firing/cycle caps, shared with the wall-clock engine the jax paths run on.

Firing rule (deterministic, KPN + backpressure):
  a worker may fire at time t when
    * every required input port holds a full rate-block visible by t
      (JOIN: only the round-robin-scheduled port),
    * every output FIFO that will receive tokens has space
      (FORK: only the scheduled port),
    * the worker is free (t >= worker II clock) and its devices are free.
  Among fireable workers the earliest (t, name) fires; outputs become
  visible at t + latency; worker and devices are busy for II cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ...core.fork_join import LITERAL, ForkJoinModel
from ...core.stg import FORK, JOIN, STG, Selection
from ...core.transform import ReplicatedGraph, materialize
from .channels import ChannelSet
from .engine import Op, run_event_loop, steady_inverse
from .placement import Placement, StageSlice, place


@dataclass
class PipelineRun:
    """Result of one streaming execution."""
    outputs: dict[str, list] = field(default_factory=dict)     # sink worker -> tokens
    fire_times: dict[str, list[float]] = field(default_factory=dict)
    fired: dict[str, int] = field(default_factory=dict)
    cycles: float = 0.0
    placement: Placement | None = None
    channels: ChannelSet | None = None
    replica_map: dict[str, list[str]] = field(default_factory=dict)
    busy_cycles: dict[str, float] = field(default_factory=dict)
    wait_cycles: dict[str, dict[str, float]] = field(default_factory=dict)
    # worker -> {reason: cycles blocked} (traced runs only): credit =
    # output fifo full, starve = input empty — measure's stall/starve
    # columns under the virtual clock

    def inverse_throughput(self, worker: str, warmup_frac: float = 0.25) -> float:
        """Steady-state cycles per firing at one worker (drop pipeline fill)."""
        times = self.fire_times[worker]
        try:
            return steady_inverse(times, warmup_frac)
        except ValueError:
            raise ValueError(f"too few firings at {worker} ({len(times)})")

    def stage_inverse_throughput(self, stage: str,
                                 warmup_frac: float = 0.25) -> float:
        """Effective cycles per firing of a (possibly replicated) stage:
        merge all replicas' firings — round-robin replicas interleave, so
        the merged stream fires nr-times faster than one replica."""
        workers = self.replica_map.get(stage, [stage])
        merged = [t for w in workers for t in self.fire_times[w]]
        try:
            return steady_inverse(merged, warmup_frac)
        except ValueError:
            raise ValueError(f"too few firings at stage {stage}")

    def utilization(self, worker: str) -> float:
        times = self.fire_times[worker]
        if len(times) < 2:
            return 0.0
        span = times[-1] - times[0]
        return min(1.0, self.busy_cycles[worker] / span) if span > 0 else 1.0


def execute(stg: STG, sel, inputs: dict[str, list], *,
            devices=None, capacity_blocks: int = 2,
            fj: ForkJoinModel = LITERAL, max_firings: int = 1_000_000,
            max_cycles: float = 1e12, tracer=None) -> PipelineRun:
    """Materialise, place, and stream ``inputs`` through the pipeline.

    ``sel`` may be a Selection, a planner PlanResult, or a solver
    TradeoffResult — materialised through the package-level
    `as_selection` helper (the same rule the jax path uses).
    ``tracer``: optional `trace.Tracer` — the virtual-clock run emits
    the same typed event stream as the wall-clock backends (op spans in
    cycles, credit/starve waits, fifo occupancy counters)."""
    from . import as_selection
    sel = as_selection(sel)
    rg: ReplicatedGraph = materialize(stg, sel, fj)
    pl = place(stg, sel, devices, replica_map=rg.replica_map)
    # Fork/join workers are routing fabric, not pool PEs: each gets its own
    # router slot so tree hops don't contend with compute time-sharing.
    for name in rg.fork_join_nodes:
        pl.slices[name] = StageSlice(stage=name, worker=name, replica=0,
                                     tp=1, devices=(("router", name),))
    return execute_materialized(rg, pl, inputs,
                                capacity_blocks=capacity_blocks,
                                max_firings=max_firings,
                                max_cycles=max_cycles, tracer=tracer)


class _HostNode:
    """One materialised worker as an `engine.Program` (virtual clock).

    Owns the node-specific halves of the firing rule — token/rate
    readiness, FORK/JOIN port scheduling, source streams, backpressure
    probes, and busy-clock updates — while `engine.run_event_loop` owns
    when anything runs.  ``dispatch`` consumes tokens at ``driver.now``
    and returns the node-function thunk; ``retire`` produces outputs at
    ``now + latency``, advances the node/device busy clocks, and wakes
    the neighbours whose readiness may have changed."""

    def __init__(self, idx: int, name: str, ctx: "_HostContext"):
        self.idx = idx
        self.name = name
        self.n_replicas = 1
        self.fired = 0
        self.ctx = ctx
        g = ctx.g
        self.node = g.nodes[name]
        self.impl = ctx.sel.impl_of(g, name)
        self.in_chs = g.in_channels(name)
        self.out_chs = g.out_channels(name)
        self.slice = ctx.pl.slices.get(name)
        self._wake_pending: set[str] = set()
        self.wait_reason = None   # (reason, fifo) of the last deferral

    def _required_out_ports(self) -> list[int]:
        if self.node.kind == FORK:
            return [self.ctx.state[self.name] or 0]
        return [ch.src_port for ch in self.out_chs]

    def pending(self) -> int:
        """KPN nodes have no op count — firings are decided by token
        arrival, and a finite stream *terminates by quiescence* (no node
        fireable, nothing in flight), not by draining a schedule.  So
        pending is "fireable right now": both drivers then stop exactly
        at quiescence (the event loop via an empty heap, the wall-clock
        engine via its pending-or-inflight loop, cleanly — quiescence is
        normal KPN termination, not a deadlock), and
        `execute_materialized`'s wedge guard is the truncation check
        that tells end-of-stream apart from an undersized buffer."""
        op = self.peek()
        return 1 if op is not None and self.ready(op) is not None else 0

    def peek(self) -> Op | None:
        return Op(stage=self.idx, kind="N", seq=self.fired, rep=0)

    def ready(self, op: Op, count_stall: bool = False) -> float | None:
        """Earliest fire time, or None if blocked on tokens/space.

        ``count_stall``: record a producer stall on the blocking fifo —
        set only on the heap-pop re-check, so FifoStats counts scheduled
        firings actually deferred, not readiness probes."""
        ctx, node, name = self.ctx, self.node, self.name
        t = ctx.node_free[name]
        if self.slice is not None:
            for d in self.slice.devices:
                t = max(t, ctx.dev_free[d])
        # inputs
        if not self.in_chs:   # source: finite stream
            n_need = node.out_rates[0]
            if name not in ctx.src_streams or \
                    ctx.src_pos[name] + n_need > len(ctx.src_streams[name]):
                self.wait_reason = ("source", None)    # end of stream
                return None
        elif node.kind == JOIN:
            k = ctx.state[name] or 0
            q = ctx.cs[self.in_chs[k].key()]
            rt = q.ready_time(node.in_rates[k])
            if rt is None:
                self.wait_reason = ("starve", q)
                return None
            t = max(t, rt)
        else:
            for ch in self.in_chs:
                q = ctx.cs[ch.key()]
                rt = q.ready_time(node.in_rates[ch.dst_port])
                if rt is None:
                    self.wait_reason = ("starve", q)
                    return None
                t = max(t, rt)
        # backpressure: every port fired into must have block space now
        need_ports = set(self._required_out_ports())
        for ch in self.out_chs:
            if ch.src_port in need_ports:
                q = ctx.cs[ch.key()]
                if not q.can_push(node.out_rates[ch.src_port]):
                    if count_stall:
                        q.note_stall()
                    self.wait_reason = ("credit", q)
                    return None
        return t

    def dispatch(self, op: Op, driver):
        ctx, node, name = self.ctx, self.node, self.name
        # -- consume (at dispatch time: frees producer space immediately) ----
        ins: list[list] = [[] for _ in range(max(1, node.n_in))]
        wake: set[str] = set()
        if self.in_chs:
            if node.kind == JOIN:
                k = ctx.state[name] or 0
                ch = self.in_chs[k]
                ins[k] = ctx.cs[ch.key()].pop(node.in_rates[k])
                wake.add(ch.src)
            else:
                for ch in self.in_chs:
                    ins[ch.dst_port] = ctx.cs[ch.key()].pop(
                        node.in_rates[ch.dst_port])
                    wake.add(ch.src)
        else:
            n_need = node.out_rates[0]
            p = ctx.src_pos[name]
            ins[0] = ctx.src_streams[name][p:p + n_need]
            ctx.src_pos[name] = p + n_need
        self._wake_pending = wake
        return self._compute, (ins,)

    def _compute(self, ins):
        node, name = self.node, self.name
        state = self.ctx.state[name]
        if node.fn is not None:
            outs, state = node.fn(ins, state)
        elif not self.in_chs:
            outs = [ins[0]]
        else:
            outs = ([list(ins[0]) for _ in range(node.n_out)]
                    if self.out_chs else [list(ins[0])])
        return outs, state

    def retire(self, op: Op, result, driver) -> float:
        ctx, node, name = self.ctx, self.node, self.name
        outs, ctx.state[name] = result
        now = driver.now
        wake = self._wake_pending
        self._wake_pending = set()
        # -- produce ---------------------------------------------------------
        done = now + (self.impl.latency or self.impl.ii)
        if self.out_chs:
            for ch in self.out_chs:
                toks = outs[ch.src_port]
                if toks:
                    ctx.cs[ch.key()].push(toks, done)
                wake.add(ch.dst)
        else:
            for port_out in outs:
                ctx.outputs[name].extend(port_out)
        ctx.node_free[name] = now + self.impl.ii
        if self.slice is not None:
            for d in self.slice.devices:
                ctx.dev_free[d] = now + self.impl.ii
                wake.update(ctx.dev_workers[d])
        self.fired += 1
        driver.note_busy(name, self.impl.ii)
        driver.wake(*wake)
        return done

    def describe(self) -> str:
        return f"{self.name}: {self.fired} fired"


@dataclass
class _HostContext:
    """State shared by all of one run's `_HostNode` programs."""
    g: STG
    sel: Selection
    pl: Placement
    cs: ChannelSet
    state: dict
    node_free: dict
    dev_free: dict
    dev_workers: dict
    src_streams: dict
    src_pos: dict
    outputs: dict


def execute_materialized(rg: ReplicatedGraph, pl: Placement,
                         inputs: dict[str, list], *,
                         capacity_blocks: int = 2,
                         max_firings: int = 1_000_000,
                         max_cycles: float = 1e12,
                         tracer=None) -> PipelineRun:
    g = rg.stg
    for n in inputs:
        if n not in g.nodes:
            raise ValueError(f"inputs key {n!r} is not a node of the "
                             f"materialised graph (sources: {g.sources()})")
        if g.in_channels(n):
            raise ValueError(f"inputs key {n!r} is not a source node")
    run = PipelineRun(placement=pl, replica_map=dict(rg.replica_map))
    cs = ChannelSet.for_graph(g, capacity_blocks=capacity_blocks)
    run.channels = cs
    if tracer is not None:
        for key, fifo in cs.fifos.items():
            src_n, sp, dst_n, dp = key
            tracer.watch_fifo(fifo, f"{src_n}.{sp}->{dst_n}.{dp}",
                              src=src_n, dst=dst_n)

    dev_free: dict = {}
    dev_workers: dict = {}
    for w, sl in pl.slices.items():
        for d in sl.devices:
            dev_free.setdefault(d, 0.0)
            dev_workers.setdefault(d, set()).add(w)
    ctx = _HostContext(
        g=g, sel=rg.selection, pl=pl, cs=cs,
        state={n: g.nodes[n].init_state for n in g.nodes},
        node_free={n: 0.0 for n in g.nodes},
        dev_free=dev_free, dev_workers=dev_workers,
        src_streams={n: list(toks) for n, toks in inputs.items()},
        src_pos={n: 0 for n in inputs},
        outputs={n: [] for n in g.nodes if not g.out_channels(n)})

    programs = {n: _HostNode(i, n, ctx) for i, n in enumerate(g.nodes)}
    stats = run_event_loop(programs, max_firings=max_firings,
                           max_cycles=max_cycles, tracer=tracer)
    run.outputs = ctx.outputs
    run.fire_times = stats.fire_times
    run.fired = stats.fired
    run.busy_cycles = stats.busy_cycles
    run.cycles = stats.cycles
    run.wait_cycles = stats.wait_cycles
    # wedge guard: the loop ending with a full source block unconsumed means
    # no node could ever fire again (undersized buffer / malformed graph) —
    # fail loudly rather than hand back a silently-truncated stream.  Not a
    # wedge: the caller's own max_firings / max_cycles caps stopped us.
    if stats.total_fired < max_firings and not stats.hit_cycle_cap:
        for n, stream in ctx.src_streams.items():
            left = len(stream) - ctx.src_pos[n]
            if left >= g.nodes[n].out_rates[0]:
                raise RuntimeError(
                    f"pipeline wedged: source {n} has {left} unconsumed "
                    f"tokens but no node can fire (fired={run.fired})")
    return run
