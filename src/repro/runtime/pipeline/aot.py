"""AOT-precompiled stage programs: zero compiles inside a timed window.

The executors' hot paths used to call plain ``jax.jit`` functions, so the
first firing of every (stage, shape, device) combination paid its XLA
compile *inside* the engine's timed run — skewing the very measurements
`measure.replan_to_fixed_point` feeds back into the planner, and landing
multi-hundred-ms stalls in the middle of served requests.  ``jax.jit``'s
own dispatch cache cannot be warmed ahead of time from shapes alone
(``fn.lower(x).compile()`` does NOT populate it — verified: the next
``fn(x)`` call recompiles), so this module routes the hot path through
the ahead-of-time executables themselves:

  * `AotProgram` wraps one function the way the executors used to wrap it
    in ``jax.jit`` — same lowering, same executable, **bitwise-identical
    results** — but keeps a per-(aval, sharding) cache of
    ``.lower(...).compile()`` products and calls those.  ``precompile()``
    accepts concrete arrays or `jax.ShapeDtypeStruct`s (with shardings),
    so a pipeline compiles every stage program against its real shapes
    and placements before the first op of a run.
  * Tracing still works: when any argument is a JAX tracer (``jax.vjp``
    over a stage forward, ``jax.eval_shape`` shape chaining), the call
    transparently falls through to the wrapped ``jax.jit`` function — an
    `AotProgram` is a drop-in replacement for the jit it replaces.
  * ``donate_argnums`` flows through to both paths: the compiled
    executable aliases donated inputs to outputs (the KV-cache /
    grad-accumulator zero-copy updates), and a donated buffer is deleted
    at dispatch — a use-after-donate is a loud error, never silent reuse.
  * Every compile is accounted in a shared `CompileStats`: compiles that
    happen inside ``precompile()`` are *planned*; compiles triggered by a
    cache-miss call are *late* (they landed where a timed run could see
    them).  Pipelines expose this as ``pipe.compile_stats`` and tests
    assert ``late == 0`` after warmup.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax


@dataclass
class CompileStats:
    """Aggregate compile accounting for one pipeline's programs."""
    compiles: int = 0              # distinct executables built
    compile_s: float = 0.0         # total wall time spent compiling
    late: int = 0                  # compiles that landed INSIDE a timed
    #                                window (the engine was running) — the
    #                                number warmup exists to keep at zero
    misses: int = 0                # cache-miss compiles outside any window
    #                                (reference paths, warmup=False runs)
    calls: int = 0                 # hot-path calls routed through executables
    warm_exec_s: float = 0.0       # wall time of warmup *executions* (the
    #                                train vjp chain, which must keep its
    #                                eager call structure — see LMPipeline)
    in_window: bool = False        # set by the pipeline around engine.run()
    programs: dict[str, int] = field(default_factory=dict)  # name -> compiles
    # one stats object is shared by every program of a pipeline, and op
    # bodies run on the engine's worker pool — counter updates take a lock
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def note(self, name: str, seconds: float, on_miss: bool) -> None:
        with self._lock:
            self.compiles += 1
            self.compile_s += seconds
            self.programs[name] = self.programs.get(name, 0) + 1
            if on_miss:
                if self.in_window:
                    self.late += 1
                else:
                    self.misses += 1

    def count_call(self) -> None:
        with self._lock:
            self.calls += 1

    @contextmanager
    def window(self):
        """Mark a timed window (the engine is running): cache-miss
        compiles inside it count as ``late``.  Pipelines wrap
        ``engine.run()`` in this."""
        self.in_window = True
        try:
            yield
        finally:
            self.in_window = False

    def summary(self) -> str:
        per = ", ".join(f"{n}: {c}" for n, c in sorted(self.programs.items()))
        return (f"{self.compiles} compiles in {self.compile_s:.2f}s "
                f"({self.late} late, {self.misses} out-of-window misses), "
                f"{self.calls} aot calls, "
                f"warm exec {self.warm_exec_s:.2f}s [{per}]")


def _leaf_key(leaf):
    """Hashable identity of one argument leaf: shape, dtype, and placement
    (sharding participates — the same shapes lowered for two devices are
    two executables)."""
    shape = getattr(leaf, "shape", None)
    if shape is None:                     # python scalar: aval by type only
        return ("py", type(leaf).__name__)
    dtype = getattr(leaf, "dtype", None)
    return (tuple(shape), str(dtype), getattr(leaf, "sharding", None))


def _has_tracer(args) -> bool:
    return any(isinstance(l, jax.core.Tracer) for l in jax.tree.leaves(args))


class AotProgram:
    """One stage program, ahead-of-time compiled per (shape, placement).

    Drop-in for the ``jax.jit(fn, ...)`` it replaces: calling with
    concrete arrays routes through the per-aval compiled executable
    (compiling on miss, counted as *late*); calling under a trace
    (``jax.vjp``, ``jax.eval_shape``, an enclosing jit) falls through to
    the wrapped jit so the program stays composable.  ``precompile``
    takes the same positional args — concrete or `ShapeDtypeStruct` —
    and builds the executable without running it.
    """

    def __init__(self, fn, *, name: str = "", stats: CompileStats | None = None,
                 static_argnums: tuple = (), donate_argnums: tuple = ()):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "program")
        self.stats = stats if stats is not None else CompileStats()
        self._static = tuple(static_argnums)
        self._jit = jax.jit(fn, static_argnums=static_argnums,
                            donate_argnums=donate_argnums)
        self._compiled: dict = {}
        # op bodies run on the engine's worker pool: the compile path and
        # the stats counters are shared mutable state across threads
        self._lock = threading.Lock()

    def key_of(self, args) -> tuple:
        parts = []
        for i, a in enumerate(args):
            if i in self._static:
                parts.append(("static", a))
            else:
                leaves, treedef = jax.tree.flatten(a)
                parts.append((treedef, tuple(_leaf_key(l) for l in leaves)))
        return tuple(parts)

    def _compile(self, key: tuple, args, *, on_miss: bool):
        with self._lock:
            exe = self._compiled.get(key)
            if exe is not None:          # another thread won the race —
                return exe               # one compile, not two stalls
            t0 = time.perf_counter()
            exe = self._jit.lower(*args).compile()
            self.stats.note(self.name, time.perf_counter() - t0, on_miss)
            self._compiled[key] = exe
            return exe

    def precompile(self, *args) -> None:
        """Build (or reuse) the executable for these args — concrete
        arrays or ShapeDtypeStructs with shardings attached."""
        key = self.key_of(args)
        if key not in self._compiled:
            self._compile(key, args, on_miss=False)

    @property
    def n_compiled(self) -> int:
        return len(self._compiled)

    def __call__(self, *args):
        if _has_tracer(args):             # composing under vjp/eval_shape/jit
            return self._jit(*args)
        key = self.key_of(args)
        exe = self._compiled.get(key)
        if exe is None:
            exe = self._compile(key, args, on_miss=True)
        self.stats.count_call()
        if self._static:                  # statics are baked into the
            args = tuple(a for i, a in enumerate(args)   # executable
                         if i not in self._static)
        return exe(*args)


def tree_add_program(name: str, stats: CompileStats) -> AotProgram:
    """The donated gradient accumulator: ``acc <- acc + update`` as ONE
    compiled program whose output aliases the donated ``acc`` buffer —
    the pytree is updated in place on its resident device instead of a
    host-driven per-leaf dispatch allocating a fresh tree per microbatch.
    Bitwise-identical to ``jax.tree.map(jnp.add, acc, update)``."""
    import jax.numpy as jnp

    def tree_add(acc, update):
        return jax.tree.map(jnp.add, acc, update)

    return AotProgram(tree_add, name=name, stats=stats, donate_argnums=(0,))
