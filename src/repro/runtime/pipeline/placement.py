"""Placement: partition the device set into per-stage slices.

The solver's ``Selection`` says, per composite node, *which* implementation
and *how many* round-robin replicas.  Spatial execution gives each replica
its own slice of the device set, sized to the implementation's
tensor-parallel degree (LM impls carry ``tp`` in their meta / ``tpK`` name;
paper-style PE libraries map one replica to one PE worker).  Fork/join
routing between stages with mismatched replica counts is round-robin by
token index, mirroring ``core/transform.py``'s tree construction.

When the physical device pool is smaller than the plan's chip demand the
placement *oversubscribes*: slices wrap around the pool round-robin and the
executor time-shares them (per-device busy clocks in the interpreter; jax
falls back to same-device transfers).  ``Placement.oversubscription``
reports the folding factor so measurements can be caveated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

from ...core.stg import STG, Impl, Selection


def tp_of(impl: Impl) -> int:
    """Tensor-parallel degree (devices per replica) of an implementation.

    LM libraries (graphs/lm_graph.py) encode it as meta["tp"] / name "tpK";
    paper PE libraries (jpeg/streamit) are single-worker per replica.
    """
    if impl.meta and "tp" in impl.meta:
        return int(impl.meta["tp"])
    if impl.name.startswith("tp") and impl.name[2:].isdigit():
        return int(impl.name[2:])
    return 1


@dataclass(frozen=True)
class StageSlice:
    """One replica of one stage, pinned to a tuple of devices."""
    stage: str                 # logical (pre-materialisation) node name
    worker: str                # materialised node name (stage or stage@k)
    replica: int
    tp: int
    devices: tuple             # device handles (ints for the interpreter,
                               # jax.Device for the jax path)

    @property
    def chips(self) -> int:
        return self.tp

    @property
    def distinct(self) -> bool:
        """True when the slice owns ``tp`` *different* devices — the
        precondition for building a per-stage sub-mesh and actually
        sharding params over the slice.  A small pool folds a tp>1 slice
        onto repeated devices (oversubscription), where sub-mesh
        construction is invalid and the executor falls back to
        single-device placement."""
        return len(set(self.devices)) == len(self.devices)

    def resolve(self, pool: Sequence[Any]) -> tuple:
        """Device handles of this slice against a concrete pool: integer
        placements (the "enough hardware" default) index into ``pool``
        round-robin; real handles pass through."""
        return tuple(pool[d % len(pool)] if isinstance(d, int) else d
                     for d in self.devices)


@dataclass
class Placement:
    """Device assignment for every worker of a materialised STG."""
    slices: dict[str, StageSlice] = field(default_factory=dict)   # worker -> slice
    n_devices: int = 0
    demand: int = 0            # total devices the plan wants
    oversubscription: float = 1.0

    def slice_of(self, worker: str) -> StageSlice:
        return self.slices[worker]

    def replicas_of(self, stage: str) -> list[StageSlice]:
        out = [s for s in self.slices.values() if s.stage == stage]
        return sorted(out, key=lambda s: s.replica)

    def device_load(self) -> dict[Any, int]:
        """Workers per device — >1 anywhere means time-sharing."""
        load: dict[Any, int] = {}
        for s in self.slices.values():
            for d in s.devices:
                load[d] = load.get(d, 0) + 1
        return load

    def summary(self) -> str:
        stages: dict[str, list[StageSlice]] = {}
        for s in self.slices.values():
            stages.setdefault(s.stage, []).append(s)
        rows = []
        for name in sorted(stages):
            sl = sorted(stages[name], key=lambda s: s.replica)
            rows.append(f"  {name}: {len(sl)} replica(s) x tp{sl[0].tp} "
                        f"-> devices {[s.devices for s in sl]}")
        head = (f"placement: {self.demand} chip(s) wanted on "
                f"{self.n_devices} device(s), x{self.oversubscription:.1f} "
                f"oversubscribed")
        return head + "\n" + "\n".join(rows)


def place(stg: STG, sel: Selection, devices: Sequence[Any] | int | None = None,
          *, replica_map: dict[str, list[str]] | None = None) -> Placement:
    """Assign every worker a device slice, in topological stage order.

    ``stg``/``sel`` are the *logical* graph and selection (replicas still
    counts, not materialised nodes).  ``replica_map`` (from
    ``transform.materialize``) names the materialised workers; without it
    the canonical ``name@k`` naming is assumed.  ``devices`` is a device
    list or a pool size (defaults to exactly the plan's demand — the
    "enough hardware" placement).
    """
    demand = 0
    per_stage: list[tuple[str, Impl, int]] = []
    for name in stg.topo_order():
        impl = sel.impl_of(stg, name)
        nr = sel.replicas(name)
        tp = tp_of(impl)
        per_stage.append((name, impl, nr))
        demand += tp * nr

    if devices is None:
        pool: list[Any] = list(range(max(1, demand)))
    elif isinstance(devices, int):
        pool = list(range(devices))
    else:
        pool = list(devices)
    if not pool:
        raise ValueError("empty device pool")

    pl = Placement(n_devices=len(pool), demand=demand)
    cursor = 0
    for name, impl, nr in per_stage:
        tp = tp_of(impl)
        workers = (replica_map or {}).get(
            name, [name] if nr == 1 else [f"{name}@{k}" for k in range(nr)])
        if len(workers) != nr:
            raise ValueError(f"stage {name}: {nr} replicas but "
                             f"{len(workers)} workers in replica_map")
        for k, w in enumerate(workers):
            devs = tuple(pool[(cursor + j) % len(pool)] for j in range(tp))
            cursor += tp
            pl.slices[w] = StageSlice(stage=name, worker=w, replica=k,
                                      tp=tp, devices=devs)
    pl.oversubscription = max(1.0, demand / len(pool))
    return pl
