"""Microbatch pipeline schedules (GPipe fill-drain and 1F1B).

A schedule is, per pipeline stage, the ordered list of operations the
stage executes: ``("F", mb)`` forward of microbatch ``mb``, ``("B", mb)``
backward.  1F1B (PipeDream-flush) bounds in-flight activations per stage to
``n_stages - stage`` by interleaving one backward after each forward once
warmed up — the schedule the jax executor follows for train-shaped runs;
forward-only (serving) runs use the degenerate fill-drain stream.
"""
from __future__ import annotations

Op = tuple[str, int]


def fill_drain(n_stages: int, n_micro: int) -> list[list[Op]]:
    """GPipe-style: all forwards, then (if trained) all backwards — the
    forward half is exactly the streaming order, so serving uses this."""
    return [[("F", mb) for mb in range(n_micro)] for _ in range(n_stages)]


def one_f_one_b(n_stages: int, n_micro: int) -> list[list[Op]]:
    """1F1B: stage s runs ``min(n_stages - s, n_micro)`` warmup forwards,
    then alternates B/F in steady state, then drains remaining backwards.

    Invariants (asserted in tests): every stage sees each microbatch's F
    before its B; stage s never holds more than ``n_stages - s`` live
    activations; the last stage strictly alternates F,B,F,B,...
    """
    if n_stages < 1 or n_micro < 1:
        raise ValueError(f"bad schedule shape {n_stages}x{n_micro}")
    out: list[list[Op]] = []
    for s in range(n_stages):
        warmup = min(n_stages - s, n_micro)
        ops: list[Op] = [("F", mb) for mb in range(warmup)]
        nf, nb = warmup, 0
        # steady state: one B then one F while forwards remain
        while nf < n_micro:
            ops.append(("B", nb)); nb += 1
            ops.append(("F", nf)); nf += 1
        while nb < n_micro:
            ops.append(("B", nb)); nb += 1
        out.append(ops)
    return out


def fill_drain_bubble(n_stages: int, n_micro: int) -> float:
    """Analytic pipeline-bubble fraction of a fill-drain stream: of the
    ``n_micro + n_stages - 1`` slot-times the last stage observes, the
    first ``n_stages - 1`` are ramp (no output) — the idle share a
    perfectly overlapped executor could at best recover by hiding
    transfers and host dispatch inside compute.  The benchmark's
    recovered-bubble column reports measured overlap-off minus overlap-on
    wall time against this ceiling."""
    if n_stages < 1 or n_micro < 1:
        raise ValueError(f"bad schedule shape {n_stages}x{n_micro}")
    return (n_stages - 1) / (n_stages - 1 + n_micro)


def max_live_activations(ops: list[Op]) -> int:
    live = peak = 0
    for kind, _ in ops:
        live += 1 if kind == "F" else -1
        peak = max(peak, live)
    return peak
