"""Microbatch pipeline schedules as first-class plan objects.

The paper's tool keeps *what* a node computes separate from *how* its
implementation is scheduled onto the array; this module does the same for
microbatch pipelines.  A `Schedule` is **data**, not executor control
flow: per physical stage, the ordered stream of ``SchedOp(kind, mb,
chunk)`` operations the stage executes — built by the free functions here
(`fill_drain`, `one_f_one_b`, `interleaved_1f1b`) and *consumed* by
executor programs.  Neither clock domain generates schedules:
`jax_pipe.LMPipeline` accepts ``schedule=`` and runs whatever object it
is handed, and the same object runs under the virtual-clock driver
through `ScheduleProgram` / `simulate_schedule` (schedule dynamics —
bubble fraction, stalls — measured without touching hardware).  New
schedules (zero-bubble, looped serving) drop in without touching either
driver.

``chunk`` is the virtual-stage index of interleaved/looped schedules: a
physical stage hosting ``v`` chunks executes model stage ``chunk *
n_stages + s`` for each op — round-robin, so chunk 0 of every physical
stage covers the first ``n_stages`` model stages, chunk 1 the next, and
the activation/gradient edges remain the plain linear chain of model
stages.  Plain schedules use ``chunk == 0`` everywhere.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, NamedTuple

from .channels import Fifo
from .engine import (Engine, EventLoopStats, Op, describe_position,
                     run_event_loop)


class SchedOp(NamedTuple):
    """One scheduled operation: forward ("F") or backward ("B") of
    microbatch ``mb`` on virtual-stage ``chunk`` of its physical stage."""
    kind: str
    mb: int
    chunk: int = 0

    def describe(self) -> str:
        return f"{self.kind}(mb={self.mb},chunk={self.chunk})"


def _check_shape(n_stages: int, n_micro: int, n_chunks: int = 1) -> None:
    """The one shape gate every schedule factory and bubble model uses —
    including the ``n_micro < n_stages`` warmup degeneracy, which is legal
    (warmup simply saturates at ``n_micro``) but must be *handled*, never
    silently produce a stage with more warmup forwards than microbatches."""
    if n_stages < 1 or n_micro < 1 or n_chunks < 1:
        raise ValueError(f"bad schedule shape: {n_stages} stage(s) x "
                         f"{n_micro} microbatch(es) x {n_chunks} chunk(s)")


@dataclass
class Schedule:
    """A pipeline schedule as a first-class plan object.

    ``stage_ops[s]`` is physical stage ``s``'s ordered op stream;
    ``live_bounds[s]`` is the *analytic* in-flight-activation ceiling the
    stream is guaranteed to respect (checked by `validate`, asserted at
    runtime by the executors).  ``n_stages`` counts physical stages
    (programs); the model is cut into ``n_stages * n_chunks`` model
    stages, model stage of (s, chunk) being ``chunk * n_stages + s``.
    """
    name: str
    n_stages: int
    n_micro: int
    n_chunks: int
    stage_ops: list[list[SchedOp]]
    live_bounds: list[int] = field(default_factory=list)

    @property
    def n_model_stages(self) -> int:
        return self.n_stages * self.n_chunks

    @property
    def trains(self) -> bool:
        return any(op.kind == "B" for ops in self.stage_ops for op in ops)

    def model_stage(self, s: int, chunk: int) -> int:
        return chunk * self.n_stages + s

    def __len__(self) -> int:
        return self.n_stages

    def __getitem__(self, s: int) -> list[SchedOp]:
        return self.stage_ops[s]

    def __iter__(self):
        return iter(self.stage_ops)

    def flatten(self) -> list[tuple[int, SchedOp]]:
        """Every (physical stage, op) pair, stage-major in schedule order."""
        return [(s, op) for s, ops in enumerate(self.stage_ops)
                for op in ops]

    def validate(self) -> "Schedule":
        """Structural invariants every executable schedule must satisfy:
        each stage's stream covers every (mb, chunk) forward exactly once
        (and, for training schedules, every backward exactly once, each
        after its forward), and in-flight activations never exceed the
        declared ``live_bounds``.  Returns self, so factories end with
        ``return sched.validate()``."""
        if len(self.stage_ops) != self.n_stages:
            raise ValueError(f"{self.name}: {len(self.stage_ops)} op "
                             f"streams for {self.n_stages} stages")
        want_f = {(mb, c) for mb in range(self.n_micro)
                  for c in range(self.n_chunks)}
        for s, ops in enumerate(self.stage_ops):
            fs = [(op.mb, op.chunk) for op in ops if op.kind == "F"]
            bs = [(op.mb, op.chunk) for op in ops if op.kind == "B"]
            if len(fs) + len(bs) != len(ops):
                bad = {op.kind for op in ops} - {"F", "B"}
                raise ValueError(f"{self.name}: stage {s} has op kinds {bad}")
            if set(fs) != want_f or len(fs) != len(want_f):
                raise ValueError(
                    f"{self.name}: stage {s} forwards cover "
                    f"{len(set(fs))}/{len(want_f)} (mb, chunk) pairs "
                    f"({len(fs)} ops)")
            if bs and (set(bs) != want_f or len(bs) != len(want_f)):
                raise ValueError(
                    f"{self.name}: stage {s} backwards cover "
                    f"{len(set(bs))}/{len(want_f)} (mb, chunk) pairs")
            seen_f = set()
            for op in ops:
                if op.kind == "F":
                    seen_f.add((op.mb, op.chunk))
                elif (op.mb, op.chunk) not in seen_f:
                    raise ValueError(
                        f"{self.name}: stage {s} schedules B(mb={op.mb}, "
                        f"chunk={op.chunk}) before its F")
            live = max_live_activations(ops)
            bound = self.live_bounds[s] if self.live_bounds else live
            if live > bound:
                raise ValueError(
                    f"{self.name}: stage {s} holds {live} live "
                    f"activations, bound is {bound}")
        return self


def fill_drain(n_stages: int, n_micro: int) -> Schedule:
    """GPipe-style forward streaming: every stage runs all forwards in
    microbatch order — exactly the streaming order, so serving uses this."""
    _check_shape(n_stages, n_micro)
    ops = [[SchedOp("F", mb) for mb in range(n_micro)]
           for _ in range(n_stages)]
    return Schedule("fill_drain", n_stages, n_micro, 1, ops,
                    [n_micro] * n_stages).validate()


def one_f_one_b(n_stages: int, n_micro: int) -> Schedule:
    """1F1B (PipeDream-flush): stage s runs ``min(n_stages - s, n_micro)``
    warmup forwards, alternates B/F in steady state, then drains remaining
    backwards — bounding in-flight activations per stage to
    ``min(n_stages - s, n_micro)``.  ``n_micro < n_stages`` degenerates
    honestly: warmup saturates at ``n_micro`` and the steady phase is
    empty (pure fill-then-drain)."""
    _check_shape(n_stages, n_micro)
    stage_ops: list[list[SchedOp]] = []
    bounds: list[int] = []
    for s in range(n_stages):
        warmup = min(n_stages - s, n_micro)
        ops = [SchedOp("F", mb) for mb in range(warmup)]
        nf, nb = warmup, 0
        while nf < n_micro:                 # steady: one B then one F
            ops.append(SchedOp("B", nb)); nb += 1
            ops.append(SchedOp("F", nf)); nf += 1
        while nb < n_micro:                 # drain
            ops.append(SchedOp("B", nb)); nb += 1
        stage_ops.append(ops)
        bounds.append(warmup)
    return Schedule("one_f_one_b", n_stages, n_micro, 1, stage_ops,
                    bounds).validate()


def interleaved_1f1b(n_stages: int, n_micro: int, v: int) -> Schedule:
    """Interleaved (looped) 1F1B with ``v`` virtual chunks per physical
    stage — the Megatron-LM schedule.  The model is cut into
    ``n_stages * v`` chunks assigned round-robin (physical stage s hosts
    model stages ``c * n_stages + s``), so each warmup/drain element is
    one chunk (1/v of a stage's per-microbatch work) and the pipeline
    bubble shrinks by ~v (see `interleaved_bubble`), at the cost of up to
    ``(v - 1) * n_stages`` extra in-flight activations per stage.

    ``v == 1`` returns plain `one_f_one_b`.  For ``v > 1``,
    ``n_micro`` must be a multiple of ``n_stages`` (microbatches stream
    in groups of ``n_stages`` per chunk); ``n_micro == n_stages`` runs
    the all-warmup degenerate form.
    """
    _check_shape(n_stages, n_micro, v)
    if v == 1:
        return one_f_one_b(n_stages, n_micro)
    p, m = n_stages, n_micro
    if m % p:
        raise ValueError(
            f"interleaved_1f1b: n_micro={m} must be a multiple of "
            f"n_stages={p} (microbatches stream in groups of n_stages "
            f"per chunk)")
    total = m * v

    def f_id(k: int) -> tuple[int, int]:      # k-th forward -> (mb, chunk)
        return (k // (p * v)) * p + k % p, (k // p) % v

    def b_id(k: int) -> tuple[int, int]:      # k-th backward -> (mb, chunk)
        return (k // (p * v)) * p + k % p, v - 1 - (k // p) % v

    stage_ops: list[list[SchedOp]] = []
    bounds: list[int] = []
    for r in range(p):
        # m == p cannot sustain a steady phase: run all-warmup (Megatron's
        # special case) — fill everything, then drain everything
        warmup = total if m == p else \
            min(total, (p - r - 1) * 2 + (v - 1) * p)
        ops = [SchedOp("F", *f_id(k)) for k in range(warmup)]
        for j in range(total - warmup):       # steady: F then B
            ops.append(SchedOp("F", *f_id(warmup + j)))
            ops.append(SchedOp("B", *b_id(j)))
        for j in range(total - warmup, total):  # drain
            ops.append(SchedOp("B", *b_id(j)))
        stage_ops.append(ops)
        bounds.append(min(total, warmup + (1 if total > warmup else 0)))
    return Schedule(f"interleaved_1f1b(v={v})", p, m, v, stage_ops,
                    bounds).validate()


# ===========================================================================
# analytic bubble models
# ===========================================================================
def fill_drain_bubble(n_stages: int, n_micro: int) -> float:
    """Analytic pipeline-bubble fraction of a fill-drain stream: of the
    ``n_micro + n_stages - 1`` slot-times the last stage observes, the
    first ``n_stages - 1`` are ramp (no output) — the idle share a
    perfectly overlapped executor could at best recover by hiding
    transfers and host dispatch inside compute."""
    _check_shape(n_stages, n_micro)
    return (n_stages - 1) / (n_stages - 1 + n_micro)


def interleaved_bubble(n_stages: int, n_micro: int, v: int = 1) -> float:
    """Analytic bubble-fraction ceiling of (interleaved) 1F1B: warmup +
    drain idle ``(n_stages - 1)`` *chunk*-times per stage against
    ``v * n_micro`` chunk-times of useful work, so

        bubble = (p - 1) / (v * m + p - 1)

    ``v == 1`` is plain 1F1B's bubble (equal to fill-drain's — 1F1B
    bounds memory, not bubble); larger ``v`` divides the warmup/drain
    cost by the chunk count, the measurable payoff `simulate_schedule`
    and ``bench_pipeline`` line this ceiling up against."""
    _check_shape(n_stages, n_micro, v)
    return (n_stages - 1) / (v * n_micro + n_stages - 1)


# ===========================================================================
# live-activation accounting
# ===========================================================================
def max_live_activations(ops: list) -> int:
    """Peak forwards-minus-backwards over one stage's op stream — the
    activation (vjp residual) count the stage must hold."""
    live = peak = 0
    for op in ops:
        live += 1 if op[0] == "F" else -1
        peak = max(peak, live)
    return peak


def max_live_by_chunk(ops: list) -> dict[int, int]:
    """Chunk-aware live-activation peaks: per virtual chunk, the most
    (mb, chunk) activations simultaneously held — what the interleaved
    *and* plain 1F1B runtime asserts check (plain schedules are the
    single-chunk special case)."""
    live: dict[int, int] = {}
    peak: dict[int, int] = {}
    for op in ops:
        c = op.chunk if isinstance(op, SchedOp) else \
            (op[2] if len(op) > 2 else 0)
        live[c] = live.get(c, 0) + (1 if op[0] == "F" else -1)
        peak[c] = max(peak.get(c, 0), live[c])
    return peak


# ===========================================================================
# the schedule made executable: one Program, either driver
# ===========================================================================
class ScheduleProgram:
    """One physical stage's op stream as an engine `Program`, with a cost
    model standing in for the stage body.

    This is the schedule *itself* running on the executor core: real
    bounded FIFOs between model stages (activations forward, gradients
    backward), real credit accounting, op-by-op dispatch — only the
    compute is abstract (``cost(s, op)`` time units per op).  The same
    program objects run under **either driver**: `engine.run_event_loop`
    advances a virtual clock by each op's cost (deterministic schedule
    dynamics — the bubble measurement `bench_pipeline` reports), and
    `engine.Engine` executes the identical streams under the wall clock
    (optionally sleeping ``cost * wall_scale`` per op) — the two-drivers
    contract the engine tests pin.
    """

    def __init__(self, s: int, schedule: Schedule, acts: list[Fifo],
                 grds: list[Fifo], *, cost: Callable[[int, SchedOp], float],
                 trace: list, wall_scale: float = 0.0):
        self.s = s
        self.schedule = schedule
        self.name = f"stage{s}"
        self.n_replicas = 1
        self.ops = schedule.stage_ops[s]
        self.pos = 0
        self.acts = acts
        self.grds = grds
        self.cost = cost
        self.trace = trace
        self.wall_scale = wall_scale
        self.free_at = 0.0
        self.stall_mark = -1
        self.wait_reason = None   # (reason, fifo) of the last deferral
        self._f_done: dict[tuple[int, int], float] = {}   # (chunk, mb)
        self._peers: list[str] = [f"stage{r}"
                                  for r in range(schedule.n_stages)]
        self.M = schedule.n_model_stages

    def pending(self) -> int:
        return len(self.ops) - self.pos

    def peek(self) -> Op | None:
        if self.pos >= len(self.ops):
            return None
        k = self.ops[self.pos]
        return Op(stage=self.s, kind=k.kind, seq=k.mb, rep=0, chunk=k.chunk,
                  is_firing=(k.kind == "F"))

    def _model_stage(self, op: Op) -> int:
        return self.schedule.model_stage(self.s, op.chunk)

    def ready(self, op: Op, count_stall: bool = False) -> float | None:
        """Stalls are counted once per deferred op (``stall_mark`` dedup)
        under EITHER driver — same semantics as the jax/decode programs —
        so FifoStats agree between a wall-clock and a virtual-clock run
        of the same schedule."""
        i, mb, M = self._model_stage(op), op.seq, self.M
        if op.kind == "F":
            t = 0.0
            if i > 0:
                rt = self.acts[i - 1].ready_time(1)
                if rt is None:
                    self.wait_reason = ("starve", self.acts[i - 1])
                    return None
                t = rt
            if i < M - 1 and not self.acts[i].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.acts[i].note_stall()
                self.wait_reason = ("credit", self.acts[i])
                return None
        else:
            done = self._f_done.get((op.chunk, mb))
            if done is None:
                self.wait_reason = ("dep", None)
                return None                    # own forward not retired yet
            t = done
            if i < M - 1:
                rt = self.grds[i].ready_time(1)
                if rt is None:
                    self.wait_reason = ("starve", self.grds[i])
                    return None
                t = max(t, rt)
            if i > 0 and not self.grds[i - 1].can_push(1):
                if self.stall_mark != self.pos:
                    self.stall_mark = self.pos
                    self.grds[i - 1].note_stall()
                self.wait_reason = ("credit", self.grds[i - 1])
                return None
        return max(t, self.free_at)

    def dispatch(self, op: Op, driver):
        i, mb, M = self._model_stage(op), op.seq, self.M
        if op.kind == "F":
            if i > 0:
                got, _ = self.acts[i - 1].pop_hold(1)[0]
                assert got == mb, f"act order broke: {got}!={mb}"
                op.releases.append((self.acts[i - 1], 1))
            if i < M - 1:
                self.acts[i].reserve(1)
        else:
            if i < M - 1:
                got, _ = self.grds[i].pop_hold(1)[0]
                assert got == mb, f"grd order broke: {got}!={mb}"
                op.releases.append((self.grds[i], 1))
            if i > 0:
                self.grds[i - 1].reserve(1)
        self.pos += 1
        c = self.cost(self.s, self.ops[self.pos - 1])
        if driver.virtual:
            start = driver.now
            return (lambda: start + c), ()
        dt = c * self.wall_scale

        def body():
            if dt > 0:
                time.sleep(dt)
            return time.perf_counter()
        return body, ()

    def retire(self, op: Op, result, driver) -> float:
        t_done = result
        i, mb, M = self._model_stage(op), op.seq, self.M
        if op.kind == "F":
            self._f_done[(op.chunk, mb)] = t_done
            if i < M - 1:
                driver.ordered_push(self.acts[i], mb, (mb, i), t_done)
        else:
            del self._f_done[(op.chunk, mb)]
            if i > 0:
                driver.ordered_push(self.grds[i - 1], mb, (mb, i), t_done)
        self.free_at = t_done
        driver.note_busy(self.name, t_done - op.t_dispatch)
        self.trace.append((self.s, op.kind, mb, op.chunk,
                           op.t_dispatch, t_done))
        driver.wake(*self._peers)
        return t_done

    def describe(self) -> str:
        return describe_position(self.name, self.pos, self.ops,
                                 SchedOp.describe)


def schedule_programs(schedule: Schedule, *,
                      f_cost: float | Callable = 1.0,
                      b_cost: float | Callable | None = None,
                      capacity_blocks: int = 4,
                      wall_scale: float = 0.0
                      ) -> tuple[list[ScheduleProgram], list]:
    """Build the programs + FIFO edges that execute ``schedule`` under
    either driver.  Costs are time units per op — scalars or callables
    ``(stage, op) -> float``; ``b_cost`` defaults to ``f_cost``.
    Returns ``(programs, trace)`` — the shared trace list fills with
    ``(stage, kind, mb, chunk, t_start, t_done)`` rows as ops retire."""
    fc = f_cost if callable(f_cost) else (lambda s, op: f_cost)
    bc = (b_cost if callable(b_cost) else (lambda s, op: b_cost)) \
        if b_cost is not None else fc

    def cost(s: int, op: SchedOp) -> float:
        return fc(s, op) if op.kind == "F" else bc(s, op)

    M = schedule.n_model_stages
    acts = [Fifo(block=1, capacity_blocks=capacity_blocks)
            for _ in range(M - 1)]
    grds = [Fifo(block=1, capacity_blocks=capacity_blocks)
            for _ in range(M - 1)] if schedule.trains else []
    trace: list = []
    programs = [ScheduleProgram(s, schedule, acts, grds, cost=cost,
                                trace=trace, wall_scale=wall_scale)
                for s in range(schedule.n_stages)]
    return programs, trace


@dataclass
class ScheduleRun:
    """One schedule execution under the virtual clock: the measured
    counterpart of the analytic bubble models."""
    schedule: Schedule
    makespan: float
    busy: dict[str, float]
    trace: list
    stats: EventLoopStats

    @property
    def bubble(self) -> float:
        """Measured bubble fraction (`measure.measured_bubble` over the
        event-loop stats): the idle share of the run's total stage-time
        budget — directly comparable to `interleaved_bubble` /
        `fill_drain_bubble` ceilings."""
        from .measure import measured_bubble
        return measured_bubble(self.stats)


def simulate_schedule(schedule: Schedule, *,
                      f_cost: float | Callable = 1.0,
                      b_cost: float | Callable | None = None,
                      capacity_blocks: int = 4,
                      tracer=None) -> ScheduleRun:
    """Execute ``schedule`` under the virtual-clock driver and measure
    its dynamics — dependency stalls, backpressure, and the realised
    bubble fraction — with per-op costs instead of hardware.  Raises if
    the schedule wedges (an infeasible op order deadlocks the FIFOs)
    rather than returning a silently truncated run."""
    programs, trace = schedule_programs(
        schedule, f_cost=f_cost, b_cost=b_cost,
        capacity_blocks=capacity_blocks)
    if tracer is not None:
        for i in range(len(programs[0].acts)):
            tracer.watch_fifo(programs[0].acts[i], f"act{i}",
                              src=f"stage{i}", dst=f"stage{i + 1}")
        for i in range(len(programs[0].grds)):
            tracer.watch_fifo(programs[0].grds[i], f"grd{i}",
                              src=f"stage{i + 1}", dst=f"stage{i}")
    stats = run_event_loop({p.name: p for p in programs}, tracer=tracer)
    stuck = [p.describe() for p in programs if p.pending()]
    if stuck:
        raise RuntimeError(
            f"schedule {schedule.name} wedged under simulation — "
            f"infeasible op order or undersized buffers ({'; '.join(stuck)})")
    return ScheduleRun(schedule=schedule, makespan=stats.cycles,
                       busy=dict(stats.busy_cycles), trace=trace,
                       stats=stats)
