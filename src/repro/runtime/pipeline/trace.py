"""Structured pipeline tracing: a ring-buffer tracer both drivers feed.

The paper's loop — find the bottleneck or the excess capacity, then
reselect/replicate/split — needs *measured evidence* of where time goes.
`PipelineReport` says how fast each stage ran; this module says **why**:
which ops occupied which replica when, which stage sat blocked pushing
into a full FIFO (credit wait — the downstream party is too slow), which
sat blocked on an empty input (starve — the upstream party is), and how
every channel's occupancy evolved.  TAPA-style FIFO instrumentation for
a software pipeline.

Design constraints, in order:

  * **Low overhead.**  Events are `NamedTuple`s appended to a bounded
    ``collections.deque`` — no locks (the drivers emit from one thread),
    no formatting, no timestamps beyond what the driver already read.
    Tracing is strictly opt-in: every hook in the engine/channels is a
    ``if tracer is not None`` guard, so the default path executes the
    exact pre-trace instruction stream.  The serve smoke bench asserts
    the enabled-tracing tokens/s penalty stays under 3%.
  * **One event model for both clock domains.**  The tracer hooks into
    the shared `engine.Driver` base, so the wall-clock `Engine` and the
    virtual-clock `EventLoop` emit the *same* typed events for the same
    `Program` — `track_sequences()` is driver-invariant (the property
    `tests/test_trace.py` pins), only the timestamps differ (seconds
    vs cycles).
  * **Ring buffer + aggregates.**  The ring keeps the last ``capacity``
    events for export/diagnostics; monotone aggregates (busy seconds,
    wait seconds by (stage, reason, edge), retire-latency samples per
    (stage, replica)) are accumulated separately so long runs do not
    lose their totals to ring eviction.  `metrics.registry_from_trace`
    turns the aggregates into a counters/gauges/histograms registry.

Export is Chrome-trace / Perfetto JSON (`to_chrome_trace` / `save`):
one duration track per (stage, replica) — op spans dispatch→retire, the
replica's busy/idle profile — one "waits" track per stage with the
blocked spans and their reason, and one counter track per watched FIFO
with its occupancy after every push/pop.  Open the file at
https://ui.perfetto.dev (or chrome://tracing).
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass
from typing import NamedTuple

# event kinds ---------------------------------------------------------------
EV_DISPATCH = "dispatch"     # op handed to its replica
EV_RETIRE = "retire"         # op complete; t0 carries the dispatch time
EV_WAIT = "wait"             # a stage's blocked span closed (name = reason)
EV_PUSH = "push"             # fifo gained tokens; value = occupancy after
EV_POP = "pop"               # fifo lost tokens; value = occupancy after
EV_FAILOVER = "failover"     # a replica died and its work moved; t0 is
#                              the fault time, t the recovery-complete
#                              time, value the number of replayed ops

# wait reasons (the bottleneck-vs-excess-capacity signal) -------------------
WAIT_CREDIT = "credit"       # output fifo full: the DOWNSTREAM side is slow
WAIT_STARVE = "starve"       # input fifo empty: the UPSTREAM side is slow
WAIT_REORDER = "reorder"     # input empty but tokens sit in the driver's
#                              reorder buffer — an out-of-order replica
#                              retirement, not a rate mismatch
WAIT_DEP = "dep"             # intra-stage dependency (B before its own F)
WAIT_BLOCKED = "blocked"     # program gave no reason


class TraceEvent(NamedTuple):
    """One typed event.  ``track`` is ``"<stage>/r<replica>"`` for op
    events, the stage name for waits, and the fifo label for push/pop.
    ``t``/``t0`` are run-relative (seconds under the wall clock, cycles
    under the virtual one)."""
    kind: str
    track: str
    t: float
    name: str = ""           # op kind (F/B/P/D/N) or wait reason
    seq: int = -1
    chunk: int = 0
    t0: float = 0.0          # span start (retire / wait events)
    value: int = -1          # fifo occupancy after the event
    edge: str = ""           # blocking fifo label (wait events)


@dataclass
class FifoWatch:
    """Registry entry for one watched fifo: its identity for counter
    tracks, capacity for the occupancy invariant, and the producing /
    consuming stage names for bottleneck attribution."""
    label: str
    fifo: object
    capacity: int
    src: str | None = None
    dst: str | None = None


_SAMPLE_CAP = 4096           # retire-latency samples kept per replica


class Tracer:
    """Ring-buffer event collector shared by every driver and channel of
    one run (or one session — aggregates accumulate across runs that
    reuse the tracer).  Thread-safety: both drivers emit from their
    scheduling thread; ``deque.append`` is atomic, so concurrent fifo
    events from a worker (there are none today) would not corrupt it."""

    def __init__(self, capacity: int = 65536):
        self.events: deque[TraceEvent] = deque(maxlen=capacity)
        self.capacity = capacity
        self._clock = None                     # bound by the driver
        # monotone aggregates (survive ring eviction)
        self.busy: dict[str, float] = {}               # track -> busy time
        self.wait_s: dict[tuple, float] = {}           # (stage, reason, edge)
        self.retire_samples: dict[tuple, list] = {}    # (stage, rep) -> [dt]
        self.n_dispatch: dict[str, int] = {}           # track -> count
        self.n_retire: dict[str, int] = {}
        self.failovers: list[tuple] = []   # (stage, rep, t_fault, t_rec, n)
        self.fifo_watch: dict[str, FifoWatch] = {}     # label -> watch entry
        self.virtual = False

    # -- clock binding (drivers call at run start) --------------------------
    def bind_wall(self, t0: float) -> None:
        self._clock = lambda: time.perf_counter() - t0
        self.virtual = False

    def bind_virtual(self, loop) -> None:
        self._clock = lambda: loop.now
        self.virtual = True

    def now(self) -> float:
        return self._clock() if self._clock is not None else 0.0

    # -- emit hooks (hot path: tuple build + deque append) ------------------
    def op_dispatch(self, stage: str, rep: int, kind: str, seq: int,
                    chunk: int, t: float) -> None:
        track = f"{stage}/r{rep}"
        self.events.append(TraceEvent(EV_DISPATCH, track, t, kind,
                                      seq, chunk))
        self.n_dispatch[track] = self.n_dispatch.get(track, 0) + 1

    def op_retire(self, stage: str, rep: int, kind: str, seq: int,
                  chunk: int, t0: float, t: float) -> None:
        track = f"{stage}/r{rep}"
        self.events.append(TraceEvent(EV_RETIRE, track, t, kind,
                                      seq, chunk, t0))
        self.n_retire[track] = self.n_retire.get(track, 0) + 1
        self.busy[track] = self.busy.get(track, 0.0) + (t - t0)
        samples = self.retire_samples.setdefault((stage, rep), [])
        if len(samples) < _SAMPLE_CAP:
            samples.append(t - t0)
        else:                                  # deterministic ring reservoir
            samples[self.n_retire[track] % _SAMPLE_CAP] = t - t0

    def wait(self, stage: str, reason: str, edge: str,
             t0: float, t: float) -> None:
        self.events.append(TraceEvent(EV_WAIT, stage, t, reason,
                                      t0=t0, edge=edge))
        key = (stage, reason, edge)
        self.wait_s[key] = self.wait_s.get(key, 0.0) + (t - t0)

    def fifo_event(self, kind: str, label: str, occupancy: int) -> None:
        self.events.append(TraceEvent(kind, label, self.now(),
                                      value=occupancy))

    def failover(self, stage: str, rep: int, kind: str, t_fault: float,
                 t_recovered: float, n_replayed: int) -> None:
        """One replica died and its work was adopted by survivors: span
        from fault detection to routing/caches/replay-queue restored
        (the replayed ops themselves complete later, on the engine's
        normal clock)."""
        self.events.append(TraceEvent(EV_FAILOVER, f"{stage}/r{rep}",
                                      t_recovered, kind, seq=n_replayed,
                                      t0=t_fault))
        self.failovers.append((stage, rep, t_fault, t_recovered, n_replayed))

    # -- fifo registration ---------------------------------------------------
    def watch_fifo(self, fifo, label: str, *, src: str | None = None,
                   dst: str | None = None) -> None:
        """Attach this tracer to ``fifo``: every push/pop emits a counter
        event under ``label``; ``src``/``dst`` name the producing and
        consuming stages (`metrics.attribute_bottleneck` needs them to
        blame the right party for a wait)."""
        fifo.tracer = self
        fifo.label = label
        self.fifo_watch[label] = FifoWatch(
            label=label, fifo=fifo, capacity=fifo.capacity,
            src=src, dst=dst)

    # -- derived views -------------------------------------------------------
    def stage_wait_s(self) -> dict[str, dict[str, float]]:
        """Per-stage blocked time by reason, summed over edges — the raw
        material for `measure`'s stall/starve columns."""
        out: dict[str, dict[str, float]] = {}
        for (stage, reason, _edge), s in self.wait_s.items():
            d = out.setdefault(stage, {})
            d[reason] = d.get(reason, 0.0) + s
        return out

    def track_sequences(self) -> dict[str, list[tuple]]:
        """Per-track event sequences with timestamps stripped — the
        driver-invariant view (wall and virtual clocks emit identical
        sequences for the same `Program`).  Wait events are excluded:
        *when* a driver observes blockage is clock policy, not program
        semantics."""
        out: dict[str, list[tuple]] = {}
        for ev in self.events:
            if ev.kind == EV_WAIT:
                continue
            out.setdefault(ev.track, []).append(
                (ev.kind, ev.name, ev.seq, ev.chunk, ev.value))
        return out

    def fifo_snapshot(self) -> list[str]:
        """Occupancy of every watched fifo right now — the deadlock
        report's who-holds-what line."""
        out = []
        for label, w in sorted(self.fifo_watch.items()):
            f = w.fifo
            line = f"{label}: {len(f)}/{f.capacity}"
            if f.inflight_slots:
                line += f" (+{f.inflight_slots} in flight)"
            out.append(line)
        return out

    def tail(self, stage: str | None = None, n: int = 8) -> list[TraceEvent]:
        """The last ``n`` events, optionally only those on ``stage``'s
        tracks — what each stuck party last did before a hang."""
        if stage is None:
            evs = list(self.events)
        else:
            evs = [ev for ev in self.events
                   if ev.track == stage or ev.track.startswith(stage + "/")]
        return evs[-n:]

    # -- export --------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome-trace / Perfetto JSON: "X" duration slices on one track
        per (stage, replica) (op spans) plus one per stage (wait spans),
        and "C" counter tracks for fifo occupancy."""
        tids: dict[str, int] = {}
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": "virtual clock (cycles as us)"
                     if self.virtual else "pipeline"}}]

        def tid(track: str) -> int:
            t = tids.get(track)
            if t is None:
                t = tids[track] = len(tids) + 1
                events.append({"name": "thread_name", "ph": "M", "pid": 0,
                               "tid": t, "args": {"name": track}})
            return t

        # cycles export 1:1 as us — relative spans are what matter
        scale = 1.0 if self.virtual else 1e6
        for ev in self.events:
            if ev.kind == EV_RETIRE:
                events.append({
                    "name": f"{ev.name}{ev.seq}", "ph": "X", "pid": 0,
                    "tid": tid(ev.track), "ts": ev.t0 * scale,
                    "dur": max(0.0, (ev.t - ev.t0)) * scale,
                    "args": {"seq": ev.seq, "chunk": ev.chunk}})
            elif ev.kind == EV_WAIT:
                events.append({
                    "name": ev.name, "ph": "X", "pid": 0,
                    "tid": tid(f"{ev.track}/waits"), "ts": ev.t0 * scale,
                    "dur": max(0.0, (ev.t - ev.t0)) * scale,
                    "args": {"edge": ev.edge}})
            elif ev.kind in (EV_PUSH, EV_POP):
                events.append({
                    "name": f"fifo {ev.track}", "ph": "C", "pid": 0,
                    "ts": ev.t * scale,
                    "args": {"occupancy": ev.value}})
            elif ev.kind == EV_FAILOVER:
                events.append({
                    "name": f"failover ({ev.name})", "ph": "X", "pid": 0,
                    "tid": tid(ev.track), "ts": ev.t0 * scale,
                    "dur": max(0.0, (ev.t - ev.t0)) * scale,
                    "args": {"replayed_ops": ev.seq}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path
