"""Batched LM serving runtime (prefill + decode rounds).

Round-based batching: take up to ``max_batch`` queued requests, left-align
them into a padded prompt matrix, one jitted prefill builds the KV/SSM
caches, then jitted single-token decode steps run until every slot hits
EOS or its token budget.  Prompt lengths are bucketed to powers of two so
the prefill compiles once per bucket, not once per request mix.

Two backends:

  * **single-device** (default): one jitted prefill + decode loop over the
    whole model, rounds served sequentially.
  * **pipelined** (``pipeline=runtime.pipeline.DecodePipeline(...)``):
    rounds become serving-slot *groups* streamed concurrently through a
    planned, placed, replicated stage pipeline — per-stage KV-cache
    slices stay resident on their placement slices and sampled tokens
    feed back over a continuous token-stream channel.  Completions are
    token-identical to the single-device backend under greedy sampling
    (same grouping, bucketing, and EOS/budget bookkeeping).

Throughput accounting distinguishes prefill tokens (prompt side) from
decode tokens (generated) — the two shapes the dry-run cells
(``prefill_32k`` / ``decode_32k``) lower at production scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding_ctx as sctx
from ..configs.base import ModelConfig
from ..models import build_model


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int = 32


@dataclass
class Completion:
    uid: int
    tokens: list[int]
    prompt_len: int
    prefill_s: float
    decode_s: float


@dataclass
class ServeStats:
    requests: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    rounds: int = 0
    compiles: set = field(default_factory=set)
    decode_step_s: list = field(default_factory=list)
    # per-decode-step wall gaps (single-device backend): the `int(nxt[i])`
    # conversions host-sync every step, so each gap is a real step time —
    # honest p50/p95 material, not a per-request mean smeared flat
    slo: dict | None = None        # last pipelined serve's client-side
    #                                percentiles (`ServeRunResult.slo()`);
    #                                None on the single-device backend

    def summary(self) -> dict:
        out = {
            "requests": self.requests,
            "rounds": self.rounds,
            "prefill_tok_per_s": self.prefill_tokens / self.prefill_s
            if self.prefill_s else 0.0,
            "decode_tok_per_s": self.decode_tokens / self.decode_s
            if self.decode_s else 0.0,
            "decode_tokens": self.decode_tokens,
        }
        if self.slo is not None:
            out["slo"] = dict(self.slo)
        return out


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class LMServer:
    def __init__(self, cfg: ModelConfig, *, max_batch: int = 8,
                 eos_id: int = 1, params=None, seed: int = 0,
                 mesh=None, temperature: float = 0.0, pipeline=None,
                 tracer=None, injector=None, health=None,
                 preflight: bool = True, impl: str | None = None):
        """``pipeline``: a `runtime.pipeline.DecodePipeline` — when set,
        ``serve``/``serve_round`` stream request groups through it instead
        of the single-device prefill/decode loop.  Build it with the same
        ``seed`` (or pass the server's ``params``) for token parity.
        ``injector`` (a `failures.ReplicaFaultPlan`) and ``health`` (a
        `pipeline.health.HealthController`) ride along on every pipelined
        serve — chaos drills and self-healing, pipelined backend only.
        ``preflight``: statically verify each pipelined serve's plan
        (`core.verify`) before launch; False skips the check (the
        single-device backend has no plan to verify either way).
        ``impl``: kernel implementation for every model call
        (`kernels.ops.resolve_impl` tier — None = auto; ``"ref"`` pins
        the bitwise-historical decode path for A/B runs)."""
        self.cfg = cfg
        self.max_batch = max_batch
        self.eos_id = eos_id
        self.temperature = temperature
        self.mesh = mesh
        self.pipeline = pipeline
        self.preflight = preflight
        self.tracer = tracer         # optional pipeline Tracer (pipelined
        #                              backend only; None = tracing off)
        self.injector = injector     # optional ReplicaFaultPlan (chaos)
        self.health = health         # optional HealthController
        self.impl = impl
        self.model = build_model(cfg, impl)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(seed))
        self.stats = ServeStats()
        self._prefill = jax.jit(
            lambda p, batch, cap: self.model.prefill(p, batch, capacity=cap),
            static_argnums=(2,))
        # the cache is donated: `decode_step` returns it with identical
        # avals leaf-for-leaf (`decode_cache_structs` contract), so the
        # steady-state decode loop updates the ring buffers in place —
        # zero new cache allocations per token.  The loop below rebinds
        # `cache` every step and never touches the donated value again.
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._key = jax.random.PRNGKey(seed ^ 0xC0FFEE)

    # -- one round ----------------------------------------------------------
    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits[:, -1, :] / self.temperature, axis=-1).astype(jnp.int32)

    def serve_round(self, reqs: list[Request]) -> list[Completion]:
        if self.pipeline is not None:
            return self._serve_pipelined(reqs)
        assert 0 < len(reqs) <= self.max_batch
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        bucket = _bucket(plen)
        cap = bucket + max(r.max_new for r in reqs)
        self.stats.compiles.add((B, bucket, cap))
        toks = np.zeros((B, bucket), np.int32)
        for i, r in enumerate(reqs):               # right-align prompts so
            toks[i, bucket - len(r.prompt):] = r.prompt   # last token is real
        batch = {"tokens": jnp.asarray(toks)}

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cap)
        last = self._sample(logits)
        jax.block_until_ready(last)
        t_prefill = time.perf_counter() - t0

        out_tokens = [[int(last[i])] for i in range(B)]
        done = np.array([t[0] == self.eos_id for t in out_tokens])
        budget = np.array([r.max_new for r in reqs])

        t1 = time.perf_counter()
        t_step = t1
        steps = 0
        cur = last[:, None]
        while not done.all() and steps < budget.max() - 1:
            logits, cache = self._decode(self.params, cache, cur)
            nxt = self._sample(logits)
            steps += 1
            for i in range(B):
                if not done[i] and steps < budget[i]:
                    tok = int(nxt[i])
                    out_tokens[i].append(tok)
                    if tok == self.eos_id:
                        done[i] = True
                elif not done[i]:
                    done[i] = True
            now = time.perf_counter()
            self.stats.decode_step_s.append(now - t_step)
            t_step = now
            cur = nxt[:, None]
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t1

        self.stats.requests += B
        self.stats.rounds += 1
        self.stats.prefill_tokens += B * bucket
        self.stats.decode_tokens += sum(len(t) for t in out_tokens)
        self.stats.prefill_s += t_prefill
        self.stats.decode_s += t_decode
        return [Completion(uid=r.uid, tokens=out_tokens[i],
                           prompt_len=len(r.prompt),
                           prefill_s=t_prefill, decode_s=t_decode)
                for i, r in enumerate(reqs)]

    def serve(self, reqs: list[Request]) -> list[Completion]:
        """Drain a queue in max_batch-sized rounds.  The pipelined backend
        streams *all* rounds concurrently through the stage pipeline (each
        round = one serving-slot group); the single-device backend serves
        them sequentially."""
        if self.pipeline is not None:
            return self._serve_pipelined(reqs)
        out: list[Completion] = []
        for i in range(0, len(reqs), self.max_batch):
            ctx = sctx.activate(sctx.from_mesh(self.mesh)) if self.mesh \
                else _null()
            with ctx:
                out.extend(self.serve_round(reqs[i:i + self.max_batch]))
        return out

    def _serve_pipelined(self, reqs: list[Request]) -> list[Completion]:
        """Stream request groups through the decode pipeline.

        Per-completion prefill/decode times are the group's pipeline spans
        (dispatch -> first sampled token -> last token).  Aggregate stats
        use run-level wall windows — groups overlap in the pipeline, so
        summing per-group spans would double-count time."""
        if not reqs:
            return []          # match the single-device backend on an
        #                        empty queue instead of raising
        run = self.pipeline.serve(
            [r.prompt for r in reqs], [r.max_new for r in reqs],
            eos_id=self.eos_id, group_size=self.max_batch,
            temperature=self.temperature, tracer=self.tracer,
            injector=self.injector, health=self.health,
            preflight=self.preflight)
        self.stats.requests += len(reqs)
        self.stats.rounds += len(run.groups)
        self.stats.slo = run.slo()
        self.stats.prefill_tokens += run.prefill_tokens
        self.stats.decode_tokens += run.decode_tokens
        # wall windows (they overlap under pipelining): prefill counts
        # until the LAST group's prefill lands — interleaved decode makes
        # the reported prefill rate a lower bound, never an inflated one
        first_prefill = min(g.t_prefill_done for g in run.groups)
        self.stats.prefill_s += max(g.t_prefill_done for g in run.groups)
        self.stats.decode_s += max(
            max(g.t_last for g in run.groups) - first_prefill, 0.0)
        for g in run.groups:
            self.stats.compiles.add((g.batch, g.bucket, g.cap))
        out: list[Completion] = []
        for i, (r, toks) in enumerate(zip(reqs, run.tokens)):
            g = run.groups[run.group_of[i]]
            out.append(Completion(
                uid=r.uid, tokens=toks, prompt_len=len(r.prompt),
                prefill_s=g.t_prefill_done - g.t_start,
                decode_s=max(g.t_last - g.t_prefill_done, 0.0)))
        return out


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
