"""Elastic scaling: re-plan + reshard when the chip budget changes.

The paper's motivation is exactly this ("scaling a program to a larger or
smaller processor array requires manually re-programming all objects and
channels"); here the planner re-solves the trade-off and the checkpoint
layer reshards the state:

    1. drain + checkpoint (atomic)
    2. planner.replan(cfg, shape, old_plan, new_chips)  -> new ExecutionPlan
    3. build the new mesh/shardings; restore the checkpoint against them
       (restore_checkpoint(..., shardings=new))   -> resharded state
    4. resume the step loop (recompile happens on first step)

``rescale()`` performs 2-3 and returns everything the trainer needs; the
scale-change drill in tests/test_system.py runs a full
train -> shrink -> train -> grow -> train cycle and asserts loss continuity
and bitwise data-order determinism.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from .. import sharding_ctx as sctx
from ..configs.base import ModelConfig, ShapeCfg
from ..core import planner
from ..launch import sharding as shd


@dataclass
class RescaleResult:
    plan: planner.PlanResult
    execution: planner.ExecutionPlan
    mesh: object
    diff: dict

    def summary(self) -> str:
        o, n = self.diff["chips"]
        return (f"rescale: {o:.0f} -> {n:.0f} chips, "
                f"throughput x{self.diff['throughput_ratio']:.2f}, "
                f"{len(self.diff['stages_changed'])} stages re-laid-out, "
                f"mesh {self.execution.mesh_shape}")


def plan_for_chips(cfg: ModelConfig, shape: ShapeCfg, chips: int,
                   engine: str = "heuristic") -> planner.PlanResult:
    return planner.plan(cfg, shape, chips=chips, engine=engine)


def rescale(cfg: ModelConfig, shape: ShapeCfg, old_plan: planner.PlanResult,
            *, new_chips: int, devices=None,
            engine: str = "heuristic") -> RescaleResult:
    """Re-plan for ``new_chips`` and build the new mesh/shardings.

    ``devices``: the devices to build the mesh over (defaults to all local;
    at pod scale this is the post-repair slice).  The logical (dp, tp)
    comes from the plan projected onto however many devices exist.
    """
    new_plan, diff = planner.replan(cfg, shape, old_plan,
                                    new_chips=new_chips, engine=engine)
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    ex = planner.to_execution(new_plan, cfg=cfg, chips=n)
    import numpy as np
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(devices).reshape(ex.mesh_shape), ex.mesh_axes)
    return RescaleResult(plan=new_plan, execution=ex, mesh=mesh, diff=diff)


def reshard_tree(tree, mesh, cfg: ModelConfig,
                 policy: shd.ShardingPolicy | None = None):
    """device_put an existing (restored) pytree against a new mesh."""
    policy = policy or shd.ShardingPolicy()
    sh = shd.tree_shardings(tree, mesh, cfg, policy)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh), sh


# ===========================================================================
# elastic rescale of a live serving pool
# ===========================================================================
@dataclass
class ServingRescale:
    """A re-planned serving pipeline, ready to adopt a drained pool's
    live state via ``pipe.resume(state)``."""
    pipe: object                    # the new DecodePipeline
    plan: planner.PlanResult
    diff: dict

    def summary(self) -> str:
        o, n = self.diff["chips"]
        return (f"serving rescale: {o:.0f} -> {n:.0f} chips, "
                f"throughput x{self.diff['throughput_ratio']:.2f}, "
                f"{len(self.diff['stages_changed'])} stages re-laid-out")


def rescale_serving(pipe, cfg: ModelConfig, shape: ShapeCfg,
                    old_plan: planner.PlanResult, *, new_chips: int, stg,
                    devices=None, engine: str = "heuristic",
                    periods_per_stage: int | None = None,
                    measured_ratio: dict[str, float] | None = None
                    ) -> ServingRescale:
    """Re-plan a *serving* pool for ``new_chips`` and build the successor
    pipeline on the same weights.

    The live-rescale protocol (no request dropped):

        1. old run drains:  ``res = pipe.serve(..., pause_after_tokens=N)``
           — admission pauses, in-flight groups park with caches resident,
           ``res.resume_state`` exports them.
        2. ``rs = rescale_serving(pipe, cfg, shape, old_plan,
           new_chips=..., stg=stg)`` — this function: one solver call, a
           new `DecodePipeline` over the re-planned placement, *sharing*
           ``pipe``'s parameter tree (device_put reshards per stage; the
           PR-5 donation discipline applies unchanged because caches are
           rebuilt or transferred per group, never aliased across pools).
        3. ``rs.pipe.resume(res.resume_state)`` — parked groups' KV
           slices are adopted (transferred when stage spans match,
           replayed from token history when the cut points moved) and
           decoding continues to completion.

    ``measured_ratio`` (e.g. a `HealthController.replan_advice`) routes
    straggler measurements into the re-solve — the measurement-guided
    re-planning loop of the paper, closed over a live pool.  Advice keys
    may be *pipeline stage* names (what the controller observes —
    ``blocks00`` may group several graph nodes) or graph node names;
    stage keys fan out to every graph node the stage owns via
    ``pipe.graph_stage_map()`` before they reach the solver."""
    if measured_ratio:
        stage_of = pipe.graph_stage_map()        # graph node -> stage name
        fanned: dict[str, float] = {}
        for key, ratio in measured_ratio.items():
            owners = [n for n, s in stage_of.items() if s == key] or [key]
            for n in owners:
                fanned[n] = max(fanned.get(n, 1.0), ratio)
        measured_ratio = fanned
    new_plan, diff = planner.replan(cfg, shape, old_plan,
                                    new_chips=new_chips, engine=engine,
                                    measured_ratio=measured_ratio)
    from .pipeline.decode import DecodePipeline
    new_pipe = DecodePipeline(
        cfg, stg, new_plan, devices=devices,
        periods_per_stage=(pipe.periods_per_stage
                           if periods_per_stage is None else periods_per_stage),
        seed=pipe.seed, params=pipe._init_params, overlap=pipe.overlap,
        replica_queue=pipe.replica_queue, workers=pipe.workers,
        temperature=pipe.temperature, fusion_plan=pipe.fusion_plan,
        impl=pipe.impl)
    return ServingRescale(pipe=new_pipe, plan=new_plan, diff=diff)
