"""Elastic scaling: re-plan + reshard when the chip budget changes.

The paper's motivation is exactly this ("scaling a program to a larger or
smaller processor array requires manually re-programming all objects and
channels"); here the planner re-solves the trade-off and the checkpoint
layer reshards the state:

    1. drain + checkpoint (atomic)
    2. planner.replan(cfg, shape, old_plan, new_chips)  -> new ExecutionPlan
    3. build the new mesh/shardings; restore the checkpoint against them
       (restore_checkpoint(..., shardings=new))   -> resharded state
    4. resume the step loop (recompile happens on first step)

``rescale()`` performs 2-3 and returns everything the trainer needs; the
scale-change drill in tests/test_system.py runs a full
train -> shrink -> train -> grow -> train cycle and asserts loss continuity
and bitwise data-order determinism.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from .. import sharding_ctx as sctx
from ..configs.base import ModelConfig, ShapeCfg
from ..core import planner
from ..launch import sharding as shd


@dataclass
class RescaleResult:
    plan: planner.PlanResult
    execution: planner.ExecutionPlan
    mesh: object
    diff: dict

    def summary(self) -> str:
        o, n = self.diff["chips"]
        return (f"rescale: {o:.0f} -> {n:.0f} chips, "
                f"throughput x{self.diff['throughput_ratio']:.2f}, "
                f"{len(self.diff['stages_changed'])} stages re-laid-out, "
                f"mesh {self.execution.mesh_shape}")


def plan_for_chips(cfg: ModelConfig, shape: ShapeCfg, chips: int,
                   engine: str = "heuristic") -> planner.PlanResult:
    return planner.plan(cfg, shape, chips=chips, engine=engine)


def rescale(cfg: ModelConfig, shape: ShapeCfg, old_plan: planner.PlanResult,
            *, new_chips: int, devices=None,
            engine: str = "heuristic") -> RescaleResult:
    """Re-plan for ``new_chips`` and build the new mesh/shardings.

    ``devices``: the devices to build the mesh over (defaults to all local;
    at pod scale this is the post-repair slice).  The logical (dp, tp)
    comes from the plan projected onto however many devices exist.
    """
    new_plan, diff = planner.replan(cfg, shape, old_plan,
                                    new_chips=new_chips, engine=engine)
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    ex = planner.to_execution(new_plan, cfg=cfg, chips=n)
    import numpy as np
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(devices).reshape(ex.mesh_shape), ex.mesh_axes)
    return RescaleResult(plan=new_plan, execution=ex, mesh=mesh, diff=diff)


def reshard_tree(tree, mesh, cfg: ModelConfig,
                 policy: shd.ShardingPolicy | None = None):
    """device_put an existing (restored) pytree against a new mesh."""
    policy = policy or shd.ShardingPolicy()
    sh = shd.tree_shardings(tree, mesh, cfg, policy)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh), sh
