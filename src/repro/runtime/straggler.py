"""Straggler detection & mitigation hooks.

Two granularities live here:

  * `StragglerMonitor` — pod-scale step-time outliers under synchronous
    data parallelism (rolling median of step durations per host);
  * `detect_replica_stragglers` — pipeline-scale replica outliers from
    the observability layer's per-(stage, replica) retire-latency
    histograms (`runtime.pipeline.metrics.registry_from_trace`).

Pod-scale rationale: with synchronous data parallelism one slow host sets
the step time for all N.  The monitor keeps a rolling median of step
durations (per host when per-host timings are available — multi-host
deployments feed heartbeat times; single-process runs feed their own) and
flags steps slower than ``threshold``x the median.  Mitigation is a
pluggable callback; the default logs and counts.  Real deployments attach
actions like: demote the host from the next slice assignment (elastic
re-plan, see runtime.elastic), or switch the data loader to skip-straggler
mode (drop the slowest host's microbatch — bounded staleness).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class StragglerEvent:
    step: int
    host: int
    duration: float
    median: float

    @property
    def slowdown(self) -> float:
        return self.duration / max(self.median, 1e-9)


@dataclass
class StragglerMonitor:
    window: int = 32
    threshold: float = 2.5
    warmup_steps: int = 3          # compile/first-touch steps are not stragglers
    on_straggler: Callable[[StragglerEvent], None] | None = None
    registry: object | None = None  # optional MetricsRegistry: counts firings
    _history: list[float] = field(default_factory=list)
    events: list[StragglerEvent] = field(default_factory=list)
    observed: int = 0

    def observe(self, step: int, duration: float | dict[int, float]) -> list[StragglerEvent]:
        """Feed one step's duration (or {host: duration}).  Returns events
        flagged for this step."""
        per_host = duration if isinstance(duration, dict) else {0: duration}
        self.observed += 1
        flagged: list[StragglerEvent] = []
        # one median per observe: flagging and the healthy-filter below must
        # judge against the same pre-update baseline
        med = statistics.median(self._history) if self._history else 0.0
        if self._history and self.observed > self.warmup_steps:
            for host, dur in per_host.items():
                if dur > self.threshold * med:
                    ev = StragglerEvent(step=step, host=host, duration=dur,
                                        median=med)
                    flagged.append(ev)
                    self.events.append(ev)
                    if self.registry is not None:
                        self.registry.counter("straggler.flagged",
                                              host=str(host)).inc()
                    if self.on_straggler is not None:
                        self.on_straggler(ev)
        if self.observed > self.warmup_steps:
            # the median tracks healthy steps; don't let stragglers poison it
            healthy = [d for d in per_host.values()
                       if not self._history or d <= self.threshold * med]
            self._history.extend(healthy or per_host.values())
        else:
            self._history.extend(per_host.values())
        if len(self._history) > self.window:
            self._history = self._history[-self.window:]
        return flagged

    def new_incarnation(self) -> None:
        """Restart boundary: the next ``warmup_steps`` steps recompile and
        must not be flagged."""
        self.observed = 0
        self._history.clear()

    @property
    def median(self) -> float:
        return statistics.median(self._history) if self._history else 0.0


@dataclass
class StragglerReport:
    """One flagged replica."""
    stage: str
    replica: int
    p50_us: float              # this replica's median retire latency
    peer_p50_us: float         # median of the OTHER replicas' medians
    samples: int

    @property
    def ratio(self) -> float:
        return self.p50_us / self.peer_p50_us if self.peer_p50_us > 0 else 1.0

    def describe(self) -> str:
        return (f"{self.stage}/r{self.replica}: p50 {self.p50_us:.0f}us vs "
                f"peer median {self.peer_p50_us:.0f}us "
                f"(x{self.ratio:.2f}, {self.samples} samples)")


def detect_replica_stragglers(registry, *,
                              threshold: float = 1.5,
                              min_samples: int = 8) -> list[StragglerReport]:
    """Flag replicas whose median retire latency exceeds ``threshold`` x
    the median of its *peers'* medians (leave-self-out).

    Medians on both sides deliberately: a straggler is a *shifted
    distribution*, not a tail event — one slow op (a late compile, a GC
    pause) moves a mean or a p99 but not a median, and the
    median-of-medians baseline keeps the straggler itself from dragging
    the reference the way a pooled mean would.  The baseline excludes
    the replica under judgement: with exactly two replicas an inclusive
    median-of-medians IS the slower replica's own median, which made a
    2-replica stage's straggler structurally undetectable.  Replicas
    with fewer than ``min_samples`` observations are skipped (a replica
    that retired three ops has no distribution to judge).  Stages with a
    single replica are skipped — there are no peers to lag behind.

    Returns reports sorted worst-first; empty when nothing is flagged.
    """
    # (stage, replica) -> Histogram, from the registry's labelled metrics
    # (lazy import: runtime.pipeline.__init__ re-exports this module)
    from .pipeline.metrics import Histogram
    by_stage: dict[str, dict[int, Histogram]] = {}
    for labels, metric in registry.find("pipeline.retire_latency_us"):
        ld = dict(labels)
        try:
            rep = int(ld.get("replica", -1))
        except (TypeError, ValueError):
            continue
        stage = ld.get("stage")
        if stage is None or rep < 0 or not isinstance(metric, Histogram):
            continue
        by_stage.setdefault(stage, {})[rep] = metric

    out: list[StragglerReport] = []
    for stage, reps in by_stage.items():
        eligible = {r: h for r, h in reps.items() if h.count >= min_samples}
        if len(eligible) < 2:
            continue
        medians = {r: h.percentile(50) for r, h in eligible.items()}
        for r, p50 in medians.items():
            peers = sorted(v for k, v in medians.items() if k != r)
            peer_p50 = peers[len(peers) // 2]
            if peer_p50 <= 0:
                continue
            if p50 > threshold * peer_p50:
                out.append(StragglerReport(
                    stage=stage, replica=r, p50_us=p50,
                    peer_p50_us=peer_p50, samples=eligible[r].count))
    out.sort(key=lambda s: -s.ratio)
    return out
