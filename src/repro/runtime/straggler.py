"""Straggler detection & mitigation hooks.

Pod-scale rationale: with synchronous data parallelism one slow host sets
the step time for all N.  The monitor keeps a rolling median of step
durations (per host when per-host timings are available — multi-host
deployments feed heartbeat times; single-process runs feed their own) and
flags steps slower than ``threshold``x the median.  Mitigation is a
pluggable callback; the default logs and counts.  Real deployments attach
actions like: demote the host from the next slice assignment (elastic
re-plan, see runtime.elastic), or switch the data loader to skip-straggler
mode (drop the slowest host's microbatch — bounded staleness).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class StragglerEvent:
    step: int
    host: int
    duration: float
    median: float

    @property
    def slowdown(self) -> float:
        return self.duration / max(self.median, 1e-9)


@dataclass
class StragglerMonitor:
    window: int = 32
    threshold: float = 2.5
    warmup_steps: int = 3          # compile/first-touch steps are not stragglers
    on_straggler: Callable[[StragglerEvent], None] | None = None
    _history: list[float] = field(default_factory=list)
    events: list[StragglerEvent] = field(default_factory=list)
    observed: int = 0

    def observe(self, step: int, duration: float | dict[int, float]) -> list[StragglerEvent]:
        """Feed one step's duration (or {host: duration}).  Returns events
        flagged for this step."""
        per_host = duration if isinstance(duration, dict) else {0: duration}
        self.observed += 1
        flagged: list[StragglerEvent] = []
        if self._history and self.observed > self.warmup_steps:
            med = statistics.median(self._history)
            for host, dur in per_host.items():
                if dur > self.threshold * med:
                    ev = StragglerEvent(step=step, host=host, duration=dur,
                                        median=med)
                    flagged.append(ev)
                    self.events.append(ev)
                    if self.on_straggler is not None:
                        self.on_straggler(ev)
        if self.observed > self.warmup_steps:
            # the median tracks healthy steps; don't let stragglers poison it
            healthy = [d for d in per_host.values()
                       if not self._history
                       or d <= self.threshold * statistics.median(self._history)]
            self._history.extend(healthy or per_host.values())
        else:
            self._history.extend(per_host.values())
        if len(self._history) > self.window:
            self._history = self._history[-self.window:]
        return flagged

    def new_incarnation(self) -> None:
        """Restart boundary: the next ``warmup_steps`` steps recompile and
        must not be flagged."""
        self.observed = 0
        self._history.clear()

    @property
    def median(self) -> float:
        return statistics.median(self._history) if self._history else 0.0
