from . import pipeline
from .failures import FailureInjector, SimulatedNodeFailure
from .straggler import (StragglerMonitor, StragglerReport,
                        detect_replica_stragglers)
from .trainer import TrainLoopConfig, run_resilient, train_loop

__all__ = ["FailureInjector", "SimulatedNodeFailure", "StragglerMonitor",
           "StragglerReport", "detect_replica_stragglers",
           "TrainLoopConfig", "run_resilient", "train_loop", "pipeline"]
