from . import pipeline
from .failures import FailureInjector, SimulatedNodeFailure
from .straggler import StragglerMonitor
from .trainer import TrainLoopConfig, run_resilient, train_loop

__all__ = ["FailureInjector", "SimulatedNodeFailure", "StragglerMonitor",
           "TrainLoopConfig", "run_resilient", "train_loop", "pipeline"]
