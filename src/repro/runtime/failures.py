"""Deterministic failure injection for fault-tolerance tests/drills.

At real pod scale, failures arrive as ICI timeouts, host kernel panics and
preemptions; the runtime's contract is the same either way: the step loop
dies, the job controller restarts it, and training resumes from the last
committed checkpoint with identical data order.  The injector reproduces
that contract deterministically so it can be asserted in CI.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class SimulatedNodeFailure(RuntimeError):
    """A node 'died' (injected). The trainer must not catch this per-step;
    only the resilient wrapper restarts from the last checkpoint."""


@dataclass
class FailureInjector:
    """Schedule: {step: kind}; kind in {"crash", "stall:<seconds>"}.

    ``crash``  — raise SimulatedNodeFailure before the step executes.
    ``stall:x``— sleep x seconds (a straggler; the monitor should flag it).
    Each entry fires once (restarts don't re-fire a consumed failure —
    mirroring a replaced node).
    """
    schedule: dict[int, str] = field(default_factory=dict)
    fired: set[int] = field(default_factory=set)
    log: list[tuple[int, str]] = field(default_factory=list)

    def maybe_fail(self, step: int) -> None:
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return
        self.fired.add(step)
        self.log.append((step, kind))
        if kind == "crash":
            raise SimulatedNodeFailure(f"injected node failure at step {step}")
        if kind.startswith("stall:"):
            time.sleep(float(kind.split(":", 1)[1]))
            return
        raise ValueError(f"unknown failure kind {kind!r}")
