"""Deterministic failure injection for fault-tolerance tests/drills.

At real pod scale, failures arrive as ICI timeouts, host kernel panics and
preemptions; the runtime's contract is the same either way: the step loop
dies, the job controller restarts it, and training resumes from the last
committed checkpoint with identical data order.  The injector reproduces
that contract deterministically so it can be asserted in CI.

Two granularities live here:

  * `FailureInjector` — step-granularity crashes/stalls for the training
    step loop (the resilient-trainer drill: die, restart, resume from
    checkpoint).  ``new_incarnation()`` re-arms the schedule so a
    multi-restart drill can kill the *same* step twice — a replaced node
    and a flaky node are different fault models, and only the caller
    knows which one a drill wants.
  * `ReplicaFaultPlan` — op-granularity faults against a specific
    ``(stage, replica)`` of a running pipeline.  Both executor drivers
    (`pipeline.engine.Engine` and `EventLoop`) consult the plan right
    before every op dispatch, so a chaos drill fires at a deterministic
    point in the op stream on either clock domain.  ``crash`` marks the
    replica dead and triggers the engine's failover path; ``stall:<s>``
    wraps the op body in a host-side sleep (a straggler — wall-clock
    driver only; the virtual clock has no host time to burn, so stalls
    are recorded as skipped there).

`PipelineFailure` is the *structured* escalation the engine raises when
failover is impossible (a single-replica stage died, or a program has no
failover hook): it carries the failed (stage, replica), the fault kind,
and the same diagnostic bundle the deadlock report prints — fifo
occupancy, per-stage wait reasons, schedule positions, trace tail — so
an unrecoverable fault surfaces as evidence, not as a hang.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class SimulatedNodeFailure(RuntimeError):
    """A node 'died' (injected). The trainer must not catch this per-step;
    only the resilient wrapper restarts from the last checkpoint."""


class ReplicaFault(RuntimeError):
    """One pipeline replica 'died' (injected or real): raised from an op
    body, or synthesised by the driver when a `ReplicaFaultPlan` entry
    fires.  The engine converts it into failover (surviving replicas
    adopt the dead one's work) or a `PipelineFailure` escalation."""

    def __init__(self, message: str, *, stage: str = "", replica: int = -1):
        super().__init__(message)
        self.stage = stage
        self.replica = replica


class PipelineFailure(RuntimeError):
    """An unrecoverable pipeline fault, with evidence attached.

    ``stage``/``replica`` name the party that died; ``reason`` is the
    fault kind ("crash", "stall:..", or a backend-specific string);
    ``diagnostics`` is the engine's forensic bundle (fifo occupancy,
    wait reasons, schedule positions, failover history, trace tail) —
    the same material the deadlock report prints, structured."""

    def __init__(self, message: str, *, stage: str = "", replica: int = -1,
                 reason: str = "replica-fault",
                 diagnostics: dict | None = None):
        super().__init__(message)
        self.stage = stage
        self.replica = replica
        self.reason = reason
        self.diagnostics = dict(diagnostics or {})

    def describe(self) -> str:
        lines = [f"pipeline failure at {self.stage}/r{self.replica} "
                 f"({self.reason}): {self}"]
        for key, val in sorted(self.diagnostics.items()):
            lines.append(f"  {key}: {val}")
        return "\n".join(lines)


@dataclass
class FailureInjector:
    """Schedule: {step: kind}; kind in {"crash", "stall:<seconds>"}.

    ``crash``  — raise SimulatedNodeFailure before the step executes.
    ``stall:x``— sleep x seconds (a straggler; the monitor should flag it).
    Each entry fires once *per incarnation*: within one incarnation a
    re-scheduled step does not re-fire (a replaced node stays replaced),
    but `new_incarnation()` / `reset()` re-arms the schedule so a
    multi-restart drill can model a flaky node that keeps failing after
    every restart.  ``log`` records (incarnation, step, kind) for every
    firing across the drill.
    """
    schedule: dict[int, str] = field(default_factory=dict)
    fired: set[int] = field(default_factory=set)
    log: list[tuple[int, int, str]] = field(default_factory=list)
    incarnation: int = 0

    def maybe_fail(self, step: int) -> None:
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return
        self.fired.add(step)
        self.log.append((self.incarnation, step, kind))
        if kind == "crash":
            raise SimulatedNodeFailure(f"injected node failure at step {step}")
        if kind.startswith("stall:"):
            time.sleep(float(kind.split(":", 1)[1]))
            return
        raise ValueError(f"unknown failure kind {kind!r}")

    def reset(self) -> None:
        """Re-arm every schedule entry (the drill's restart boundary):
        ``fired`` is per-incarnation state, not drill-lifetime state."""
        self.fired.clear()
        self.incarnation += 1

    # restart-boundary alias, mirroring StragglerMonitor.new_incarnation
    new_incarnation = reset


# ===========================================================================
# op-granularity replica faults (pipeline chaos drills)
# ===========================================================================
@dataclass
class ReplicaFaultSpec:
    """Kill or stall ``(stage, replica)`` at a chosen point in its op
    stream.  ``unit`` selects the trigger coordinate: ``"op"`` counts the
    replica's own dispatches (the Nth op this replica runs), ``"tok"``
    watches the global sequence number (the Nth token/op of the whole
    stream — what a serving drill means by "kill r1 at token 64").
    ``repeat`` lets a stall recur (a persistently slow replica); a crash
    is permanent after one firing regardless."""
    stage: str
    replica: int
    at: int
    unit: str = "op"             # "op" | "tok"
    kind: str = "crash"          # "crash" | "stall:<seconds>"
    repeat: int = 1

    def describe(self) -> str:
        return f"{self.stage}:r{self.replica}@{self.unit}{self.at}={self.kind}"

    @property
    def stall_s(self) -> float:
        return float(self.kind.split(":", 1)[1]) \
            if self.kind.startswith("stall:") else 0.0


@dataclass
class ReplicaFaultPlan:
    """A deterministic chaos schedule over pipeline op dispatches.

    Drivers call ``check(stage, replica, seq)`` before every dispatch;
    the plan counts that replica's dispatches and returns the first
    armed `ReplicaFaultSpec` whose trigger is reached (``None``
    otherwise).  Like `FailureInjector`, entries fire once per
    incarnation (``repeat`` raises the per-incarnation budget for
    stalls) and ``reset()``/``new_incarnation()`` re-arms them."""
    faults: list[ReplicaFaultSpec] = field(default_factory=list)
    log: list[tuple] = field(default_factory=list)
    incarnation: int = 0
    _fired: dict[int, int] = field(default_factory=dict)   # spec idx -> count
    _dispatched: dict[tuple, int] = field(default_factory=dict)

    @classmethod
    def parse(cls, *specs: str) -> "ReplicaFaultPlan":
        """Build a plan from compact drill strings, e.g.
        ``"blocks00:r1@tok64=crash"`` or ``"embed:r0@op8=stall:0.05x16"``
        (``x16`` = repeat budget).  Grammar:
        ``<stage>:r<replica>@<op|tok><N>=<crash|stall:<s>[x<repeat>]>``.
        """
        out = []
        for spec in specs:
            try:
                where, kind = spec.split("=", 1)
                stage, at_part = where.split(":r", 1)
                rep, trigger = at_part.split("@", 1)
                unit = "tok" if trigger.startswith("tok") else "op"
                if not trigger.startswith(unit):
                    raise ValueError(f"trigger {trigger!r}")
                at = int(trigger[len(unit):])
                repeat = 1
                if kind.startswith("stall:") and "x" in kind.split(":", 1)[1]:
                    secs, reps = kind.split(":", 1)[1].split("x", 1)
                    kind, repeat = f"stall:{secs}", int(reps)
                if kind.startswith("stall:"):
                    float(kind.split(":", 1)[1])
                elif kind != "crash":
                    raise ValueError(f"kind {kind!r}")
                out.append(ReplicaFaultSpec(
                    stage=stage, replica=int(rep), at=at, unit=unit,
                    kind=kind, repeat=repeat))
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad fault spec {spec!r} (want "
                    f"'<stage>:r<N>@<op|tok><K>=<crash|stall:<s>[xR]>'): {e}"
                ) from e
        return cls(faults=out)

    def check(self, stage: str, replica: int,
              seq: int) -> ReplicaFaultSpec | None:
        """Account one imminent dispatch on ``(stage, replica)`` and
        return the spec to fire now, if any."""
        key = (stage, replica)
        nth = self._dispatched[key] = self._dispatched.get(key, 0) + 1
        for i, spec in enumerate(self.faults):
            if spec.stage != stage or spec.replica != replica:
                continue
            budget = 1 if spec.kind == "crash" else max(1, spec.repeat)
            if self._fired.get(i, 0) >= budget:
                continue
            coord = nth if spec.unit == "op" else seq
            if coord >= spec.at:
                self._fired[i] = self._fired.get(i, 0) + 1
                self.log.append((self.incarnation, stage, replica, seq,
                                 spec.kind))
                return spec
        return None

    @property
    def fired(self) -> int:
        """Total firings this incarnation (drills assert the fault
        actually happened — a chaos run that never fired is vacuous)."""
        return sum(self._fired.values())

    def reset(self) -> None:
        """Restart boundary: re-arm every spec and restart the per-replica
        dispatch counters for the next incarnation."""
        self._fired.clear()
        self._dispatched.clear()
        self.incarnation += 1

    new_incarnation = reset
