from .roofline import HW_V5E, RooflineReport, analyze_compiled  # noqa: F401
