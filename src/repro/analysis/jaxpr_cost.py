"""Exact global FLOP/byte counting by jaxpr traversal.

XLA's cost analysis counts while-loop bodies once and reports per-device
numbers on the CPU backend; for the roofline we need whole-step, whole-
slice counts.  Jaxprs carry static scan lengths, so traversal is exact:
scan bodies multiply by trip count, remat/pjit/custom_* recurse.

flops:       2*M*N*K per dot_general (batch dims included), conv ignored
             (none in these models).
major_bytes: operand+result bytes of dot_general / gather / scatter /
             dynamic-slice/update ops — the HBM-traffic-dominant ops
             (weights, caches, activations at matmul boundaries).  An
             fusion-unaware upper bound for elementwise chains is NOT
             included; see EXPERIMENTS.md §Roofline method note.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import reduce

import jax
import numpy as np


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=float)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    batch = reduce(lambda a, b: a * b, (lhs.shape[i] for i in lb), 1)
    contract = reduce(lambda a, b: a * b, (lhs.shape[i] for i in lc), 1)
    lfree = reduce(lambda a, b: a * b,
                   (d for i, d in enumerate(lhs.shape) if i not in lc + lb), 1)
    rfree = reduce(lambda a, b: a * b,
                   (d for i, d in enumerate(rhs.shape) if i not in rc + rb), 1)
    return 2.0 * batch * contract * lfree * rfree


_MAJOR = {"dot_general", "gather", "scatter", "scatter-add", "dynamic_slice",
          "dynamic_update_slice", "conv_general_dilated", "take"}


@dataclass
class Cost:
    flops: float = 0.0
    major_bytes: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.major_bytes + o.major_bytes)

    def __mul__(self, k: float):
        return Cost(self.flops * k, self.major_bytes * k)


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for an eqn's sub-computations."""
    prim = eqn.primitive.name
    p = eqn.params
    if prim == "scan":
        return [(p["jaxpr"].jaxpr, float(p["length"]))]
    if prim == "while":
        # assume the common fori pattern; trip count unknown -> 1 (flagged)
        return [(p["body_jaxpr"].jaxpr, 1.0)]
    if prim == "cond":
        return [(b.jaxpr, 1.0 / max(1, len(p["branches"])))
                for b in p["branches"]]
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            return [(getattr(j, "jaxpr", j), 1.0)]
    out = []
    for k, v in p.items():
        for x in (v if isinstance(v, (list, tuple)) else (v,)):
            if hasattr(x, "jaxpr") and hasattr(getattr(x, "jaxpr"), "eqns"):
                out.append((x.jaxpr, 1.0))
            elif hasattr(x, "eqns"):
                out.append((x, 1.0))
    return out


def _count(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            for sub, mult in subs:
                total = total + _count(sub) * mult
            continue
        if prim == "dot_general":
            c = Cost(_dot_flops(eqn),
                     sum(_nbytes(v.aval) for v in eqn.invars)
                     + sum(_nbytes(v.aval) for v in eqn.outvars))
            total = total + c
        elif prim in _MAJOR:
            total = total + Cost(0.0,
                                 sum(_nbytes(v.aval) for v in eqn.invars
                                     if hasattr(v, "aval"))
                                 + sum(_nbytes(v.aval) for v in eqn.outvars))
    return total


def count_step(fn, *arg_specs) -> Cost:
    """Trace fn abstractly and count global FLOPs / major bytes."""
    closed = jax.make_jaxpr(fn)(*arg_specs)
    return _count(closed.jaxpr)
