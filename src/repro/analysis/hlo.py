"""Post-SPMD HLO text walker: collective traffic with loop multipliers.

XLA prints one computation per block; while-ops name their body/condition
computations and scan-derived conditions compare a counter against a
constant, so trip counts are recoverable.  We walk from the entry
computation, multiplying collective byte counts by the product of
enclosing loop trip counts — this is what `compiled.cost_analysis()`
doesn't do (it counts loop bodies once).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,?.*?condition=\s*%?([\w.\-]+).*?body=\s*%?([\w.\-]+)",
    re.DOTALL)
_CALL_RE = re.compile(
    r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=\s*%?([\w.\-]+)")
_CONST_CMP = re.compile(r"compare\(")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLLECTIVE_LINE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\d]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_REPLICA_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _shape_bytes(s: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m and m.group(1).strip():
        return len(m.group(1).split(","))
    return default


def split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        m = _COMP_HEADER.match(line.strip()) if "{" in line and "->" in line else None
        if m and not line.lstrip().startswith(("ROOT", "//")):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def trip_count(cond_lines: list[str]) -> float:
    """Heuristic: scan-derived conditions compare a counter to an s32
    constant (possibly behind a wrapped-compare fusion)."""
    consts = []
    for l in cond_lines:
        consts += [int(x) for x in _S32_CONST.findall(l)]
    return float(max(consts)) if consts else 1.0


@dataclass
class CollectiveTraffic:
    wire_bytes: dict[str, float] = field(default_factory=dict)
    counts: dict[str, float] = field(default_factory=dict)

    def add(self, kind: str, b: float, mult: float):
        self.wire_bytes[kind] = self.wire_bytes.get(kind, 0.0) + b * mult
        self.counts[kind] = self.counts.get(kind, 0.0) + mult

    def total(self) -> float:
        return sum(self.wire_bytes.values())


def collect(hlo: str, n_devices: int) -> CollectiveTraffic:
    comps = split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: computation named main*
        entry = next((c for c in comps if c.startswith("main")), None)
    out = CollectiveTraffic()
    seen: set[tuple[str, float]] = set()

    def walk(comp: str, mult: float, depth=0):
        if comp not in comps or depth > 50 or (comp, mult) in seen:
            return
        seen.add((comp, mult))
        for line in comps[comp]:
            cm = _COLLECTIVE_LINE.search(line)
            if cm:
                kind = cm.group(2)
                g = _group_size(line, n_devices)
                if g > 1:
                    shard = _shape_bytes(cm.group(1))
                    if kind == "all-reduce":
                        per_dev = 2 * (g - 1) / g * shard
                    elif kind == "all-gather":
                        per_dev = (g - 1) / g * shard
                    elif kind == "reduce-scatter":
                        per_dev = (g - 1) * shard
                    elif kind == "all-to-all":
                        per_dev = (g - 1) / g * shard
                    else:
                        per_dev = shard
                    out.add(kind, per_dev * n_devices, mult)
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                walk(body, mult * trip_count(comps.get(cond, [])), depth + 1)
                continue
            fm = _CALL_RE.search(line)
            if fm:
                walk(fm.group(1), mult, depth + 1)

    if entry:
        walk(entry, 1.0)
    return out
