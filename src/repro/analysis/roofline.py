"""Roofline analysis from compiled AOT artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs  / (chips * peak_FLOP/s)
    memory     = HLO_bytes  / (chips * HBM_bw)
    collective = wire_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-module,
i.e. already summed over partitions).  wire_bytes is parsed from the
post-SPMD-partitioning HLO text: per collective op we charge the ring cost
(all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n, all-to-all
(n-1)/n, collective-permute 1x) on the shard bytes, times the number of
participating devices (total traffic), divided by chips*link_bw.
"""
from __future__ import annotations

import json
import math
import re
import time
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float       # per chip, bf16
    hbm_bw: float           # bytes/s per chip
    link_bw: float          # bytes/s per ICI link
    hbm_bytes: float        # capacity per chip


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                  link_bw=50e9, hbm_bytes=16e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"all-reduce-start|all-gather-start|reduce-scatter-start|"
    r"collective-permute-start)\b(.*)$")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPLICA_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [ngroups,group_size]<=iota
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip() != ""])
    return default


@dataclass
class CollectiveStats:
    op_bytes: dict[str, float] = field(default_factory=dict)   # shard bytes by kind
    wire_bytes: dict[str, float] = field(default_factory=dict)  # ring-cost traffic
    counts: dict[str, int] = field(default_factory=dict)

    def total_wire(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Sum collective traffic over the partitioned module (per step)."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        shape_str, kind, rest = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        g = _group_size(rest, n_devices)
        if g <= 1:
            continue
        shard_bytes = _shape_bytes(shape_str)  # result shape (per device)
        if kind == "all-reduce":
            # in == out shape; ring moves 2(n-1)/n of the buffer, per device
            per_dev = 2 * (g - 1) / g * shard_bytes
        elif kind == "all-gather":
            # result is the gathered buffer; each device receives (n-1)/n of it
            per_dev = (g - 1) / g * shard_bytes
        elif kind == "reduce-scatter":
            # result is the scattered shard; each device sends (n-1) shards
            per_dev = (g - 1) * shard_bytes
        elif kind == "all-to-all":
            per_dev = (g - 1) / g * shard_bytes
        else:  # collective-permute
            per_dev = shard_bytes
        total = per_dev * n_devices  # total wire traffic across the slice
        st.op_bytes[kind] = st.op_bytes.get(kind, 0.0) + shard_bytes * n_devices
        st.wire_bytes[kind] = st.wire_bytes.get(kind, 0.0) + total
        st.counts[kind] = st.counts.get(kind, 0) + 1
    return st


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    collectives: dict
    per_device_peak_memory: float | None = None
    step_time_bound_s: float = 0.0
    tokens_per_s: float = 0.0
    mfu: float = 0.0
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def analyze_compiled(compiled, *, arch: str, shape_name: str, mesh_name: str,
                     n_devices: int, model_flops: float, tokens: float,
                     step_flops: float, step_bytes: float,
                     hw: Hardware = HW_V5E, hlo_text: str | None = None) -> RooflineReport:
    """step_flops / step_bytes: exact whole-step global counts from
    `repro.analysis.jaxpr_cost.count_step` (XLA's own cost analysis counts
    loop bodies once and is per-device on CPU — see EXPERIMENTS.md).

    Collective traffic is walked from the post-SPMD HLO with while-loop
    trip multipliers (`repro.analysis.hlo.collect`)."""
    from . import hlo as hlo_mod

    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = hlo_mod.collect(text, n_devices)

    compute_s = step_flops / (n_devices * hw.peak_flops)
    memory_s = step_bytes / (n_devices * hw.hbm_bw)
    collective_s = coll.total() / (n_devices * hw.link_bw)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = (float(getattr(ma, "temp_size_in_bytes", 0))
               + float(getattr(ma, "argument_size_in_bytes", 0)))
    except Exception:
        pass

    bound = max(terms.values())
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=step_flops, hlo_bytes=step_bytes, wire_bytes=coll.total(),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=(model_flops / step_flops) if step_flops else 0.0,
        collectives={"counts": coll.counts, "wire_bytes": coll.wire_bytes},
        per_device_peak_memory=mem,  # the compiled module is per-device
        step_time_bound_s=bound,
        tokens_per_s=(tokens / bound) if bound else 0.0,
        mfu=(model_flops / (n_devices * hw.peak_flops)) / bound if bound else 0.0,
    )


# ===========================================================================
# Per-decode-step bytes-moved bound (the decode-kernel roofline)
# ===========================================================================
# A single-token decode step is memory-bound by construction: at batch B
# every weight byte serves B MACs, so the floor on step time is bytes
# streamed, not FLOPs.  `decode_stage_bytes` counts the *unavoidable*
# traffic per step for a span of layers: every parameter the span touches
# read once, the live KV prefix read once (k and v, at `cache_len`), one
# ring slot written back, Mamba conv/ssm state read + written, plus the
# (B, D) activation in/out.  Embed adds the B gathered rows (the table is
# indexed, not streamed); head streams the (D, V) projection and writes
# the (B, V) logits.  `bench_serve` divides this by the *measured* host
# bandwidth (`measure_host_bandwidth`) to get a per-stage lower bound on
# step time, and reports measured-vs-bound as `fraction_of_roofline`.

def _dtype_size(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(name, 4)


def decode_stage_bytes(cfg, batch: int, cache_len: int, *,
                       span: tuple[int, int] | None = None,
                       has_embed: bool = False,
                       has_head: bool = False) -> float:
    """Bytes a pipeline stage must move for ONE decode step.

    ``span``: (lo, hi) *period* range the stage owns (layers
    [lo*len(pattern), hi*len(pattern))); None = no block layers.
    ``cache_len``: live KV slots per attention layer (callers clamp to the
    ring capacity).  Returns float bytes; divide by measured bandwidth
    for the stage's step-time floor.
    """
    d = cfg.d_model
    pb = _dtype_size(cfg.param_dtype)
    ab = _dtype_size(cfg.compute_dtype)
    gated = cfg.act == "silu_glu"
    total = 0.0

    def ffn_bytes(d_ff):
        return ((3 if gated else 2) * d * d_ff) * pb

    layers = [] if span is None \
        else list(cfg.block_pattern) * (span[1] - span[0])
    for mixer, mlp in layers:
        total += d * 4                          # mixer norm (f32)
        if mixer == "attn":
            a = cfg.attn
            hd, h, kv = a.head_dim, a.n_heads, a.n_kv_heads
            total += d * (h + 2 * kv) * hd * pb + h * hd * d * pb
            if a.qkv_bias:
                total += (h + 2 * kv) * hd * pb
            # live prefix read (k + v) + one slot written (k + v)
            total += batch * cache_len * kv * hd * ab * 2
            total += batch * kv * hd * ab * 2
        else:
            m = cfg.mamba
            di = m.d_inner(d)
            H = m.n_ssm_heads(d)
            N = m.d_state
            total += (d * 2 * di + d * (2 * m.n_groups * N + H)
                      + m.d_conv * di + di * d) * pb
            total += (3 * H + di) * 4           # dt_bias/a_log/d_skip/gate_norm
            # conv history r+w (act dtype) and ssm state r+w (f32)
            total += 2 * batch * (m.d_conv - 1) * di * ab
            total += 2 * batch * H * m.head_dim * N * 4
        total += d * 4                          # mlp norm (f32)
        if mlp == "dense":
            if cfg.d_ff:
                total += ffn_bytes(cfg.d_ff)
        else:
            e = cfg.moe
            total += d * e.n_experts * 4        # router (f32)
            # at most top_k*batch distinct experts' weights stream per step
            total += min(e.top_k * batch, e.n_experts) * ffn_bytes(e.d_ff)
            if e.shared_expert:
                total += ffn_bytes(e.d_ff)
        total += 2 * batch * d * ab             # activation in/out
    if has_embed:
        total += batch * d * pb                 # gathered rows only
    if has_head:
        total += d * 4                          # final norm
        total += d * cfg.padded_vocab * pb + batch * cfg.padded_vocab * ab
    return total


def measure_host_bandwidth(mbytes: int = 256, repeats: int = 5) -> float:
    """Achievable host memory bandwidth (bytes/s), measured.

    One `numpy` buffer copy (read + write) over a buffer far larger than
    any cache level, best of ``repeats`` — the realistic peak for
    roofline fractions on the CPU dev/CI host, where `HW_V5E`'s
    datasheet numbers would be fiction.  On-accelerator runs should use
    the `Hardware` table instead.
    """
    import numpy as np
    n = mbytes * (1 << 20) // 8
    src = np.ones(n, np.float64)
    dst = np.empty_like(src)
    best = float("inf")
    np.copyto(dst, src)                  # warm: fault pages, warm TLBs
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2 * n * 8 / best


def fraction_of_roofline(step_bytes: float, measured_s: float,
                         bw: float) -> float:
    """measured step time vs its bytes/bw floor: 1.0 = at the roofline;
    > 1 means the bound is loose for this run (e.g. the working set sits
    in cache levels above DRAM, common for smoke-sized models)."""
    if measured_s <= 0 or bw <= 0:
        return float("nan")
    return (step_bytes / bw) / measured_s
