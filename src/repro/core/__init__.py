"""Core of the paper's contribution: automated space/time scaling of STGs."""
from . import fork_join, heuristic, ilp, intra_node, restructure, simulate, throughput, transform  # noqa: F401
from .fork_join import JPEG_CALIBRATED, LITERAL, ForkJoinModel  # noqa: F401
from .restructure import (FusionScore, RestructuredGraph, auto_fusion,  # noqa: F401
                          combine, enumerate_fusions, score_fusion, split,
                          validate_restructure)
from .stg import STG, Channel, Impl, Node, Selection  # noqa: F401
from .verify import (ERROR, WARN, EdgeSpec, Finding,  # noqa: F401
                     PlanVerificationError, VerificationReport,
                     verify_decode_plan, verify_graph, verify_lm_plan)
