"""Core of the paper's contribution: automated space/time scaling of STGs."""
from . import fork_join, heuristic, ilp, intra_node, simulate, throughput, transform  # noqa: F401
from .fork_join import JPEG_CALIBRATED, LITERAL, ForkJoinModel  # noqa: F401
from .stg import STG, Channel, Impl, Node, Selection  # noqa: F401
