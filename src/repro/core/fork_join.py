"""Fork/join replication trees and node combining (paper §II.B.2.c, Eq. 8-14).

Replicating a bottleneck node D ``nr = v_D / v_S`` times (Eq. 8) requires
round-robin fork (and, symmetrically, join) trees when ``nr`` exceeds the
fabric fan-out ``nf``.  The paper's literal overhead for one tree reaching
``nr = nf^H`` leaves (Eq. 9):

    A_O = sum_{i=0}^{H-1} nf^i ,   H = ceil(log_nf nr)

Node *combining* (Fig. 8, Eq. 10-14) replaces a layer of pass-through fork
nodes with a slower re-implementation S' of the producer fused with ``nf``
copies of D, cutting the overhead to Eq. 14 and saving ``nf^(H-1)`` nodes
(>75% for nf = 4).

``ForkJoinModel`` parameterises the cost model.  Two presets:

  * LITERAL          — Eq. 9 verbatim (nf = 4, unit-area pass-through nodes).
  * JPEG_CALIBRATED  — nf = 4 with pass-through PEs costing 16 area units,
    which reproduces the published Table-2 ILP overhead column for the
    extreme rows (nr=512 -> 10912 vs published 10880; nr=128 -> 2720 vs
    2688).  The paper's own Eq. 9 cannot produce its Table-2 overheads
    (341 vs 10880 for nr=512); see EXPERIMENTS.md §Reproduction notes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def tree_height(nr: int, nf: int) -> int:
    """H = ceil(log_nf nr) (paper, below Eq. 8)."""
    if nr <= 1:
        return 0
    return math.ceil(math.log(nr) / math.log(nf) - 1e-12)


def tree_overhead_eq9(nr: int, nf: int) -> int:
    """Literal Eq. 9: number of routing nodes in one tree to nr leaves."""
    H = tree_height(nr, nf)
    return sum(nf ** i for i in range(H))


def combined_tree_overhead_eq14(nr: int, nf: int) -> int:
    """Eq. 14: overhead after one combining step (tree of nr' = nr/nf)."""
    H = tree_height(nr, nf)
    return sum(nf ** i for i in range(max(0, H - 1)))


def combining_savings(nr: int, nf: int) -> int:
    """Nodes saved by one combining step: Eq. 9 minus Eq. 14 = nf^(H-1)."""
    H = tree_height(nr, nf)
    if H == 0:
        return 0
    return nf ** (H - 1)


def layer_rates(v_s: float, v_d: float, nf: int, h: int, H: int) -> tuple[float, float]:
    """Eq. 10-11: inverse throughputs seen at fork-tree layer h (1-indexed).

    v_in^h  = v_S * nf^(h-1) = v_D / nf^(H+1-h)    (paper Eq. 10)
    v_out^h = v_in^h * nf                          (paper Eq. 11)
    """
    v_in = v_s * nf ** (h - 1)
    return v_in, v_in * nf


def replicas_needed(v_d: float, v_s: float) -> int:
    """Eq. 8: nr = v_D / v_S, rounded up to an integer."""
    return max(1, math.ceil(v_d / v_s - 1e-12))


@dataclass(frozen=True)
class ForkJoinModel:
    """Cost model for round-robin distribution/collection trees.

    nf:         fabric fan-out/fan-in per node.
    node_area:  area of one pass-through routing PE.
    count_root: Eq. 9 counts the layer adjacent to the source (True matches
                the published equation); False grants the paper's stated
                free fan-out of nf from the source node itself.
    """

    nf: int = 4
    node_area: float = 1.0
    count_root: bool = True

    def tree_nodes(self, fan: int) -> int:
        """Routing nodes for one source reaching ``fan`` destinations."""
        if fan <= 1:
            return 0
        if not self.count_root and fan <= self.nf:
            return 0
        n = tree_overhead_eq9(fan, self.nf)
        if not self.count_root:
            n = max(0, n - 1)
        return n

    def overhead(self, nr_src: int, nr_dst: int) -> float:
        """Area overhead to connect nr_src producer replicas to nr_dst
        consumer replicas round-robin.  The side with fewer replicas grows a
        tree per replica toward the other side; equal counts pair up freely."""
        lo, hi = sorted((max(1, nr_src), max(1, nr_dst)))
        if hi == lo:
            return 0.0
        fan = math.ceil(hi / lo)
        return lo * self.tree_nodes(fan) * self.node_area

    def channel_overhead(self, nr_src: int, nr_dst: int) -> float:
        return self.overhead(nr_src, nr_dst)

    def replication_overhead(self, nr: int, fork: bool = True, join: bool = True) -> float:
        """Overhead of replicating an isolated node nr times from/to
        unreplicated neighbours (one fork tree + one join tree)."""
        total = 0.0
        if fork:
            total += self.overhead(1, nr)
        if join:
            total += self.overhead(nr, 1)
        return total


LITERAL = ForkJoinModel(nf=4, node_area=1.0, count_root=True)
# Calibrated so ILP-mode replication overhead matches the published Table 2
# (fork+join trees of non-free pass-through PEs; see module docstring).
JPEG_CALIBRATED = ForkJoinModel(nf=4, node_area=16.0, count_root=True)
