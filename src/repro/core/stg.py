"""Streaming Task Graph (STG) intermediate representation.

The paper's front-end produces a feed-forward Kahn Process Network: composite
nodes joined by blocking FIFO channels.  Each node consumes ``in_rates[j]``
tokens per firing on input port ``j`` and produces ``out_rates[k]`` tokens on
output port ``k``.  Each node has a library of *implementations* with an area
cost ``A`` (number of primitive PEs) and an initiation interval ``II`` (cycles
per firing).  Inverse throughputs follow Eq. (1) of the paper:

    v_in(P)  = II(P) / In(f)
    v_out(P) = II(P) / Out(f)

Feedback cycles are rejected (the paper handles feed-forward STGs only).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace
from fractions import Fraction
from typing import Any, Callable, Iterable, Sequence


@dataclass(frozen=True)
class Impl:
    """One implementation of a composite node.

    area: number of primitive PEs (paper: CLB-equivalent units).
    ii:   initiation interval, cycles between successive firings.
    latency: cycles from consuming inputs to producing outputs (>= ii).
    meta: free-form provenance (e.g. clustering decisions) for reporting.
    """

    name: str
    area: float
    ii: float
    latency: float | None = None
    meta: dict | None = None

    def __post_init__(self):
        if self.ii <= 0 or self.area < 0:
            raise ValueError(f"bad impl {self.name}: area={self.area} ii={self.ii}")
        if self.latency is None:
            object.__setattr__(self, "latency", float(self.ii))

    def v_in(self, in_rate: int) -> float:
        return self.ii / in_rate

    def v_out(self, out_rate: int) -> float:
        return self.ii / out_rate


# Node kinds.  FORK / JOIN are inserted by transforms (round-robin routing);
# they matter to the simulator and to area accounting.
COMPUTE, FORK, JOIN, SOURCE, SINK = "compute", "fork", "join", "source", "sink"


@dataclass
class Node:
    name: str
    impls: tuple[Impl, ...]
    in_rates: tuple[int, ...] = (1,)
    out_rates: tuple[int, ...] = (1,)
    kind: str = COMPUTE
    # Functional behaviour for the KPN simulator:
    #   fn(inputs: list[list[token]], state) -> (outputs: list[list[token]], state)
    # ``inputs[j]`` has exactly in_rates[j] tokens.  Pure nodes ignore state.
    fn: Callable | None = None
    init_state: Any = None

    def __post_init__(self):
        if not self.impls:
            raise ValueError(f"node {self.name} has no implementations")
        seen = set()
        for im in self.impls:
            if im.name in seen:
                raise ValueError(f"duplicate impl {im.name} in node {self.name}")
            seen.add(im.name)

    @property
    def n_in(self) -> int:
        return len(self.in_rates)

    @property
    def n_out(self) -> int:
        return len(self.out_rates)

    def impl(self, name: str) -> Impl:
        for im in self.impls:
            if im.name == name:
                return im
        raise KeyError(f"{self.name} has no impl {name}")

    def fastest(self) -> Impl:
        return min(self.impls, key=lambda im: (im.ii, im.area))

    def smallest(self) -> Impl:
        return min(self.impls, key=lambda im: (im.area, im.ii))

    def pareto(self) -> list[Impl]:
        """Implementations not dominated in (area, ii)."""
        out = []
        for im in sorted(self.impls, key=lambda im: (im.ii, im.area)):
            if not out or im.area < out[-1].area:
                out.append(im)
        return out


@dataclass(frozen=True)
class Channel:
    src: str
    dst: str
    src_port: int = 0
    dst_port: int = 0

    def key(self) -> tuple:
        return (self.src, self.src_port, self.dst, self.dst_port)


class STG:
    """A feed-forward streaming task graph (multirate SDF-style rates)."""

    def __init__(self, nodes: Iterable[Node] = (), channels: Iterable[Channel] = ()):
        self.nodes: dict[str, Node] = {}
        self.channels: list[Channel] = []
        for n in nodes:
            self.add_node(n)
        for c in channels:
            self.add_channel(c)

    # -- construction ------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        return node

    def add_channel(self, ch: Channel) -> Channel:
        for end, port, n_ports in ((ch.src, ch.src_port, "n_out"), (ch.dst, ch.dst_port, "n_in")):
            if end not in self.nodes:
                raise ValueError(f"channel references unknown node {end}")
            if port >= getattr(self.nodes[end], n_ports):
                raise ValueError(f"channel {ch} port out of range on {end}")
        for other in self.channels:
            if (other.src, other.src_port) == (ch.src, ch.src_port):
                raise ValueError(f"output port reused: {ch}")
            if (other.dst, other.dst_port) == (ch.dst, ch.dst_port):
                raise ValueError(f"input port reused: {ch}")
        self.channels.append(ch)
        return ch

    def connect(self, src: str, dst: str, src_port: int = 0, dst_port: int = 0) -> Channel:
        return self.add_channel(Channel(src, dst, src_port, dst_port))

    def copy(self) -> "STG":
        g = STG()
        g.nodes = dict(self.nodes)
        g.channels = list(self.channels)
        return g

    # -- queries -----------------------------------------------------------
    def in_channels(self, name: str) -> list[Channel]:
        return sorted((c for c in self.channels if c.dst == name), key=lambda c: c.dst_port)

    def out_channels(self, name: str) -> list[Channel]:
        return sorted((c for c in self.channels if c.src == name), key=lambda c: c.src_port)

    def sources(self) -> list[str]:
        return [n for n in self.nodes if not self.in_channels(n)]

    def sinks(self) -> list[str]:
        return [n for n in self.nodes if not self.out_channels(n)]

    def topo_order(self) -> list[str]:
        # Heap-ordered Kahn: the order is the lexicographically-smallest
        # topological sort, independent of node-insertion order, so plans
        # and simulations are reproducible across graph constructions.
        indeg = {n: len(self.in_channels(n)) for n in self.nodes}
        ready = [n for n, d in indeg.items() if d == 0]
        heapq.heapify(ready)
        order: list[str] = []
        while ready:
            n = heapq.heappop(ready)
            order.append(n)
            for c in self.out_channels(n):
                indeg[c.dst] -= 1
                if indeg[c.dst] == 0:
                    heapq.heappush(ready, c.dst)
        if len(order) != len(self.nodes):
            raise ValueError("STG has feedback (cycle); the tool handles feed-forward graphs only")
        return order

    def validate(self) -> None:
        self.topo_order()
        # every non-source input port must be driven; every non-sink output used
        for name, node in self.nodes.items():
            ins = {c.dst_port for c in self.in_channels(name)}
            outs = {c.src_port for c in self.out_channels(name)}
            if ins and ins != set(range(node.n_in)):
                raise ValueError(f"{name}: input ports driven {ins} != 0..{node.n_in-1}")
            if outs and outs != set(range(node.n_out)):
                raise ValueError(f"{name}: output ports used {outs} != 0..{node.n_out-1}")

    # -- multirate balance (repetition vector) ------------------------------
    def repetition_vector(self) -> dict[str, int]:
        """Smallest positive integer firing counts q with, per channel,
        q[src] * out_rate == q[dst] * in_rate (SDF balance equations)."""
        q: dict[str, Fraction] = {}
        order = self.topo_order()
        if not order:
            return {}
        for name in order:
            if name not in q:
                q[name] = Fraction(1)
            for c in self.out_channels(name):
                produced = q[name] * self.nodes[name].out_rates[c.src_port]
                want = produced / self.nodes[c.dst].in_rates[c.dst_port]
                if c.dst in q:
                    if q[c.dst] != want:
                        raise ValueError(
                            f"inconsistent rates on {c}: {q[c.dst]} vs {want}")
                else:
                    q[c.dst] = want
        # verify channels whose dst was visited before src
        for c in self.channels:
            lhs = q[c.src] * self.nodes[c.src].out_rates[c.src_port]
            rhs = q[c.dst] * self.nodes[c.dst].in_rates[c.dst_port]
            if lhs != rhs:
                raise ValueError(f"rate mismatch on {c}: {lhs} != {rhs}")
        lcm = 1
        for f in q.values():
            lcm = lcm * f.denominator // math.gcd(lcm, f.denominator)
        out = {n: int(f * lcm) for n, f in q.items()}
        g = 0
        for v in out.values():
            g = math.gcd(g, v)
        return {n: v // g for n, v in out.items()}


@dataclass
class Selection:
    """A solution: per node, which implementation and how many replicas."""

    choices: dict[str, tuple[str, int]] = field(default_factory=dict)

    def impl_of(self, stg: STG, name: str) -> Impl:
        return stg.nodes[name].impl(self.choices[name][0])

    def replicas(self, name: str) -> int:
        return self.choices[name][1]

    def set(self, name: str, impl: str, nr: int = 1) -> "Selection":
        self.choices[name] = (impl, int(nr))
        return self

    def impl_area(self, stg: STG) -> float:
        return sum(stg.nodes[n].impl(i).area * nr for n, (i, nr) in self.choices.items())

    @classmethod
    def fastest(cls, stg: STG) -> "Selection":
        return cls({n: (stg.nodes[n].fastest().name, 1) for n in stg.nodes})

    @classmethod
    def smallest(cls, stg: STG) -> "Selection":
        return cls({n: (stg.nodes[n].smallest().name, 1) for n in stg.nodes})


def scale_impls(impls: Sequence[Impl], ratio: float,
                floor: float = 0.05) -> tuple[Impl, ...]:
    """Scale an implementation library's IIs (and latencies) by a measured
    /analytic throughput ratio — the single calibration rule shared by
    measurement-guided re-planning (runtime.pipeline.measure.calibrate and
    graphs.lm_graph.build_stg(ii_scale=...)).  ``floor`` guards against a
    noisy measurement collapsing an II toward zero."""
    r = max(floor, float(ratio))
    return tuple(replace(im, ii=im.ii * r, latency=(im.latency or im.ii) * r)
                 for im in impls)


def unit_rate_node(name: str, impls: Sequence[Impl], n_in: int = 1, n_out: int = 1,
                   fn: Callable | None = None, kind: str = COMPUTE,
                   init_state: Any = None) -> Node:
    return Node(name=name, impls=tuple(impls), in_rates=(1,) * n_in,
                out_rates=(1,) * n_out, fn=fn, kind=kind, init_state=init_state)
