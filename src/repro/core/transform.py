"""Graph transforms: materialise a Selection as an explicit replicated STG.

Replication semantics (paper §II.B.2.c): ``nr`` replicas of a node receive
tokens round-robin and their outputs are collected round-robin, preserving
the original stream order (KPN determinism).  When the fan between producer
and consumer replica groups exceeds ``nf``, explicit FORK/JOIN tree nodes
are inserted.

Round-robin tree indexing: a fork tree over ``nd = nf^H`` leaves routes token
``t`` along its little-endian base-nf digits, so leaf index == t mod nd —
exact round-robin with no permutation.  Join trees mirror the construction.
The simulator (`repro.core.simulate`) verifies functional equivalence of the
transformed graph against the original.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .fork_join import ForkJoinModel, LITERAL
from .stg import COMPUTE, FORK, JOIN, STG, Channel, Impl, Node, Selection


def _fork_fn(n_out: int):
    def fn(inputs, state):
        k = state or 0
        outs = [[] for _ in range(n_out)]
        outs[k].extend(inputs[0])  # one block to the scheduled output
        return outs, (k + 1) % n_out
    return fn


def _join_fn(n_in: int):
    def fn(inputs, state):
        # fires with one block on exactly one input (the scheduled one);
        # the simulator's JOIN firing rule only requires that port.
        k = state or 0
        return [list(inputs[k])], (k + 1) % n_in
    return fn


def _fork_node(name: str, n_out: int, fj: ForkJoinModel, block: int = 1) -> Node:
    return Node(name=name, kind=FORK,
                impls=(Impl("fork", area=fj.node_area, ii=float(block)),),
                in_rates=(block,), out_rates=(block,) * n_out,
                fn=_fork_fn(n_out), init_state=0)


def _join_node(name: str, n_in: int, fj: ForkJoinModel, block: int = 1) -> Node:
    return Node(name=name, kind=JOIN,
                impls=(Impl("join", area=fj.node_area, ii=float(block)),),
                in_rates=(block,) * n_in, out_rates=(block,),
                fn=_join_fn(n_in), init_state=0)


@dataclass
class ReplicatedGraph:
    stg: STG
    selection: Selection            # per materialised node (replicas -> 1)
    replica_map: dict[str, list[str]] = field(default_factory=dict)
    fork_join_nodes: list[str] = field(default_factory=list)

    def overhead_area(self) -> float:
        return sum(self.stg.nodes[n].impls[0].area for n in self.fork_join_nodes)


def _build_fork_tree(g: STG, sel: Selection, fj: ForkJoinModel, src: str,
                     src_port: int, dests: list[tuple[str, int]],
                     tag: str, created: list[str], block: int = 1) -> None:
    """Connect one producer output to len(dests) destinations round-robin."""
    fan = len(dests)
    if fan == 1:
        g.connect(src, dests[0][0], src_port, dests[0][1])
        return
    f = _fork_node(f"{tag}.fork", min(fan, fj.nf), fj, block)
    g.add_node(f)
    created.append(f.name)
    sel.set(f.name, "fork", 1)
    g.connect(src, f.name, src_port, 0)
    if fan <= fj.nf:
        for k, (d, dp) in enumerate(dests):
            g.connect(f.name, d, k, dp)
        return
    # split dests into nf groups by digit (t mod nf) — little-endian routing
    groups: list[list[tuple[str, int]]] = [[] for _ in range(fj.nf)]
    for t, d in enumerate(dests):
        groups[t % fj.nf].append(d)
    for k, grp in enumerate(groups):
        _build_fork_tree(g, sel, fj, f.name, k, grp, f"{tag}.{k}", created, block)


def _build_join_tree(g: STG, sel: Selection, fj: ForkJoinModel,
                     srcs: list[tuple[str, int]], dst: str, dst_port: int,
                     tag: str, created: list[str], block: int = 1) -> None:
    fan = len(srcs)
    if fan == 1:
        g.connect(srcs[0][0], dst, srcs[0][1], dst_port)
        return
    j = _join_node(f"{tag}.join", min(fan, fj.nf), fj, block)
    g.add_node(j)
    created.append(j.name)
    sel.set(j.name, "join", 1)
    if fan <= fj.nf:
        for k, (s, sp) in enumerate(srcs):
            g.connect(s, j.name, sp, k)
        g.connect(j.name, dst, 0, dst_port)
        return
    groups: list[list[tuple[str, int]]] = [[] for _ in range(fj.nf)]
    for t, s in enumerate(srcs):
        groups[t % fj.nf].append(s)
    for k, grp in enumerate(groups):
        _build_join_tree(g, sel, fj, grp, j.name, k, f"{tag}.{k}", created, block)
    g.connect(j.name, dst, 0, dst_port)


def materialize(stg: STG, sel: Selection, fj: ForkJoinModel = LITERAL) -> ReplicatedGraph:
    """Expand a Selection into an explicit graph with replicas + fork/join.

    Requires replica counts on connected nodes to divide each other (the
    heuristic produces nf-aligned counts); raises otherwise.
    """
    g = STG()
    out_sel = Selection()
    rmap: dict[str, list[str]] = {}
    created: list[str] = []

    for name, node in stg.nodes.items():
        impl_name, nr = sel.choices[name]
        names = [name] if nr == 1 else [f"{name}@{k}" for k in range(nr)]
        rmap[name] = names
        for rn in names:
            g.add_node(Node(name=rn, impls=(node.impl(impl_name),),
                            in_rates=node.in_rates, out_rates=node.out_rates,
                            kind=node.kind, fn=node.fn, init_state=node.init_state))
            out_sel.set(rn, impl_name, 1)

    for ch in stg.channels:
        s_reps, d_reps = rmap[ch.src], rmap[ch.dst]
        ns, nd = len(s_reps), len(d_reps)
        tag = f"{ch.src}.{ch.src_port}->{ch.dst}.{ch.dst_port}"
        out_rate = stg.nodes[ch.src].out_rates[ch.src_port]
        in_rate = stg.nodes[ch.dst].in_rates[ch.dst_port]
        if (ns > 1 or nd > 1) and out_rate != in_rate:
            raise ValueError(
                f"replication across rate-changing channel {tag} "
                f"({out_rate}->{in_rate}) is not supported; re-block the graph")
        block = in_rate
        if nd >= ns:
            if nd % ns:
                raise ValueError(f"replica counts not aligned on {tag}: {ns}->{nd}")
            gsize = nd // ns
            for i, s in enumerate(s_reps):
                dests = [(d_reps[i + j * ns], ch.dst_port) for j in range(gsize)]
                _build_fork_tree(g, out_sel, fj, s, ch.src_port, dests,
                                 f"{tag}#{i}", created, block)
        else:
            if ns % nd:
                raise ValueError(f"replica counts not aligned on {tag}: {ns}->{nd}")
            gsize = ns // nd
            for i, d in enumerate(d_reps):
                srcs = [(s_reps[i + j * nd], ch.src_port) for j in range(gsize)]
                _build_join_tree(g, out_sel, fj, srcs, d, ch.dst_port,
                                 f"{tag}#{i}", created, block)

    g.validate()
    return ReplicatedGraph(g, out_sel, rmap, created)
