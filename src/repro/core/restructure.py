"""Stage combining & splitting: plan-level rewrites of an STG + Selection.

The paper's signature move beyond implementation selection + replication is
*restructuring* the graph itself: **combining** adjacent nodes into one
(deleting the FIFO between them and its fork/join routing overhead) and
**splitting** a bottleneck node at an internal cut-point into two pipelined
halves (unlocking finer placement).  ``core/transform.py`` materializes
replication; this module materializes the other two axes, in the same
shape as hwtHls's netlist transformation passes: a semantics-preserving
graph rewrite, validated structurally, that downstream layers (planner,
placement, executors) consume unchanged.

``combine(stg, sel, names)`` merges a contiguous linear chain of nodes
into one node whose chosen implementation is the *sequential composition*
of the members' chosen implementations:

    II(fused)      = sum of member IIs        (one firing does all the work)
    area(fused)    = sum of member areas      (the deleted FIFO / fork-join
                                               overhead is charged per
                                               *channel* by the cost models,
                                               so it disappears with the
                                               internal channel)
    latency(fused) = sum of member latencies

The fused impl's ``meta`` records the member nodes and choices exactly, so
``split`` of a combined node restores the originals bit-for-bit —
``split(combine(a, b)) == (a, b)`` on IIs, areas, and impl libraries.
``split`` of a *plain* node takes a declared cut fraction and produces two
pipelined halves whose IIs/areas/latencies partition the original's; the
halves carry ``split_of`` provenance so ``combine(split(x)) == x``.

``auto_fusion`` is the planner-side scorer: it enumerates contiguous
partitions of a stage chain and ranks them on the virtual clock with
measured per-stage host dispatch cost folded in as a per-stage fixed cost
(the ``measured_ratio``-style calibration loop).  The structural guard is
the ``heavy`` set — stages that own pipeline state (KV-cache period spans)
may not fuse with each other, because merging them is the planner's
``periods_per_stage`` axis, not fusion; fusion's job is absorbing the
stateless endpoint stages (embed, head) into their neighbours, which
deletes their dispatch + FIFO hop without moving any resident state.
"""
from __future__ import annotations

from dataclasses import dataclass

from .stg import COMPUTE, STG, Channel, Impl, Node, Selection


@dataclass
class RestructuredGraph:
    """An STG + Selection after a combine/split rewrite.

    ``groups`` maps rewritten names: for ``combine``, fused name -> the
    member names it replaced; for ``split``, original name -> the part
    names that replaced it.  ``deleted_channels`` are the internal FIFOs
    a combine removed (their fork/join overhead disappears with them).
    """

    stg: STG
    selection: Selection
    groups: dict[str, tuple[str, ...]]
    deleted_channels: tuple[Channel, ...] = ()


def _chain_channels(stg: STG, names: list[str]) -> list[Channel]:
    """The internal channels of a contiguous linear chain, validated."""
    internal = []
    for a, b in zip(names, names[1:]):
        ab = [c for c in stg.channels if c.src == a and c.dst == b]
        if len(ab) != 1:
            raise ValueError(f"combine: expected exactly one channel {a}->{b}, "
                             f"found {len(ab)}")
        if [c.key() for c in stg.out_channels(a)] != [ab[0].key()]:
            raise ValueError(f"combine: {a} has outputs besides {a}->{b}; "
                             "members must form a linear chain")
        if [c.key() for c in stg.in_channels(b)] != [ab[0].key()]:
            raise ValueError(f"combine: {b} has inputs besides {a}->{b}; "
                             "members must form a linear chain")
        internal.append(ab[0])
    return internal


def _compose_fns(members: list[Node]):
    """Sequential composition of member KPN functions (None if any member
    is analytic-only).  State is the tuple of member states."""
    if any(m.fn is None for m in members):
        return None, None
    init = tuple(m.init_state for m in members)

    def fn(inputs, state):
        state = list(state)
        toks = inputs
        for i, m in enumerate(members):
            toks, state[i] = m.fn(toks, state[i])
        return toks, tuple(state)

    return fn, init


def _split_parent(sel: Selection, stg: STG, names: list[str]):
    """If ``names`` are exactly the parts of one earlier split (in order),
    return the (node, impl_name, nr) to restore; else None."""
    metas = []
    for n in names:
        im = sel.impl_of(stg, n)
        if not im.meta or "split_of" not in im.meta:
            return None
        metas.append(im.meta["split_of"])
    node0, impl0, nr0, _, n_parts = metas[0]
    if n_parts != len(names):
        return None
    for i, (node, impl, nr, idx, total) in enumerate(metas):
        if node is not node0 or idx != i or total != n_parts:
            return None
    return node0, impl0, nr0


def combine(stg: STG, sel: Selection, names, *,
            fused_name: str | None = None) -> RestructuredGraph:
    """Merge a contiguous linear chain of nodes into one node.

    Members must be given in chain order, each internal boundary must be a
    single channel with no side edges, all members must fire at the same
    repetition count, and the Selection must give them equal replica
    counts (the fused node gets one replica count).  Combining the parts
    of an earlier ``split`` restores the original node exactly.
    """
    names = list(names)
    if len(names) < 2:
        raise ValueError("combine needs at least two members")
    for n in names:
        if n not in stg.nodes:
            raise KeyError(f"combine: unknown node {n}")
        if stg.nodes[n].kind != COMPUTE:
            raise ValueError(f"combine: {n} is {stg.nodes[n].kind}, "
                             "only compute nodes combine")
    internal = _chain_channels(stg, names)
    q = stg.repetition_vector()
    if len({q[n] for n in names}) != 1:
        raise ValueError(f"combine: members fire at different repetition "
                         f"counts {[q[n] for n in names]}")
    nrs = {sel.replicas(n) for n in names}
    if len(nrs) != 1:
        raise ValueError(f"combine: members have different replica counts "
                         f"{sorted(nrs)}; align replication first")
    nr = nrs.pop()

    restored = _split_parent(sel, stg, names)
    if restored is not None:
        node, impl_name, _ = restored
        fused = node
        choice = (impl_name, nr)
    else:
        members = [stg.nodes[n] for n in names]
        chosen = [sel.impl_of(stg, n) for n in names]
        fn, init = _compose_fns(members)
        impl = Impl(
            name="+".join(im.name for im in chosen),
            area=sum(im.area for im in chosen),
            ii=sum(im.ii for im in chosen),
            latency=sum(im.latency for im in chosen),
            meta={"members": tuple(names),
                  "member_nodes": tuple(members),
                  "member_choices": tuple(sel.choices[n] for n in names),
                  "internal_channels": tuple(internal)})
        fused = Node(name=fused_name or "+".join(names), impls=(impl,),
                     in_rates=members[0].in_rates,
                     out_rates=members[-1].out_rates,
                     kind=COMPUTE, fn=fn, init_state=init)
        choice = (impl.name, nr)

    new = STG()
    member_set = set(names)
    for n, node in stg.nodes.items():
        if n not in member_set:
            new.add_node(node)
    new.add_node(fused)
    internal_keys = {c.key() for c in internal}
    for c in stg.channels:
        if c.key() in internal_keys:
            continue
        src = fused.name if c.src in member_set else c.src
        dst = fused.name if c.dst in member_set else c.dst
        new.add_channel(Channel(src, dst, c.src_port, c.dst_port))

    new_sel = Selection({n: v for n, v in sel.choices.items()
                         if n not in member_set})
    new_sel.set(fused.name, *choice)
    rg = RestructuredGraph(stg=new, selection=new_sel,
                           groups={fused.name: tuple(names)},
                           deleted_channels=tuple(internal))
    validate_restructure(stg, rg, touched=member_set | {fused.name})
    return rg


def split(stg: STG, sel: Selection, name: str, *, cut: float = 0.5,
          part_names: tuple[str, str] | None = None) -> RestructuredGraph:
    """Cut one node into two pipelined halves.

    A node produced by ``combine`` is restored to its exact members
    (``split(combine(a, b)) == (a, b)`` on IIs/areas/impls).  A plain node
    is cut at the declared internal point ``cut`` in (0, 1): the first
    half gets ``cut`` of the II/area/latency, the second the rest; both
    carry ``split_of`` provenance so a later ``combine`` restores the
    original exactly.  Fresh halves are analytic-only (``fn=None`` — a
    black-box kernel has no functional midpoint); the restored form keeps
    the original ``fn``.
    """
    if name not in stg.nodes:
        raise KeyError(f"split: unknown node {name}")
    node = stg.nodes[name]
    chosen = sel.impl_of(stg, name)
    nr = sel.replicas(name)

    if chosen.meta and "member_nodes" in chosen.meta:
        parts = list(chosen.meta["member_nodes"])
        choices = list(chosen.meta["member_choices"])
        internal = list(chosen.meta["internal_channels"])
    else:
        if not (0.0 < cut < 1.0):
            raise ValueError(f"split: cut={cut} must be in (0, 1)")
        a, b = part_names or (f"{name}.0", f"{name}.1")
        fracs = (cut, 1.0 - cut)
        parts, choices = [], []
        for i, (pn, fr) in enumerate(zip((a, b), fracs)):
            im = Impl(name=chosen.name, area=chosen.area * fr,
                      ii=chosen.ii * fr, latency=chosen.latency * fr,
                      meta={"split_of": (node, chosen.name, nr, i, 2)})
            # the halves stream at the original rates on the cut channel:
            # the first half keeps the node's input signature, the second
            # its output signature, and one unit-rate channel joins them.
            parts.append(Node(name=pn, impls=(im,),
                              in_rates=node.in_rates if i == 0 else (1,),
                              out_rates=(1,) if i == 0 else node.out_rates,
                              kind=COMPUTE))
            choices.append((chosen.name, nr))
        internal = [Channel(a, b)]

    new = STG()
    for n, nd in stg.nodes.items():
        if n != name:
            new.add_node(nd)
    for p in parts:
        new.add_node(p)
    head, tail = parts[0].name, parts[-1].name
    for c in stg.channels:
        if c.dst == name:
            new.add_channel(Channel(c.src, head, c.src_port, c.dst_port))
        elif c.src == name:
            new.add_channel(Channel(tail, c.dst, c.src_port, c.dst_port))
        else:
            new.add_channel(c)
    for c in internal:
        new.add_channel(c)

    new_sel = Selection({n: v for n, v in sel.choices.items() if n != name})
    for p, ch in zip(parts, choices):
        new_sel.set(p.name, *ch)
    rg = RestructuredGraph(stg=new, selection=new_sel,
                           groups={name: tuple(p.name for p in parts)})
    validate_restructure(stg, rg, touched={name} | {p.name for p in parts})
    return rg


def validate_restructure(old: STG, rg: RestructuredGraph, *,
                         touched: set[str]) -> None:
    """Structural validation of a rewrite: the new graph is a legal
    feed-forward STG with consistent rates, the Selection covers exactly
    its nodes, and every channel not incident to a rewritten node is
    preserved verbatim."""
    rg.stg.validate()
    rg.stg.repetition_vector()          # raises on rate inconsistency
    have = set(rg.selection.choices)
    want = set(rg.stg.nodes)
    if have != want:
        raise ValueError(f"selection does not cover the rewritten graph: "
                         f"missing {want - have}, extra {have - want}")
    old_keys = {c.key() for c in old.channels
                if c.src not in touched and c.dst not in touched}
    new_keys = {c.key() for c in rg.stg.channels
                if c.src not in touched and c.dst not in touched}
    if old_keys != new_keys:
        raise ValueError(f"rewrite disturbed untouched channels: "
                         f"{old_keys ^ new_keys}")


# ===========================================================================
# planner-side fusion scoring (virtual clock + measured host cost)
# ===========================================================================
@dataclass(frozen=True)
class FusionScore:
    """One candidate partition of the stage chain, scored on the virtual
    clock.  ``period_us`` is the steady-state pipeline period: the host
    dispatches fused programs serially (sum of one dispatch per group)
    and the slowest group bounds the device side."""

    groups: tuple[tuple[str, ...], ...]
    period_us: float
    host_us: float          # total dispatch cost per token (serial)
    bottleneck_us: float    # slowest group: device + its one dispatch

    @property
    def fused(self) -> bool:
        return any(len(g) > 1 for g in self.groups)


def _stage_host(name, host_us) -> float:
    if name in host_us:
        return float(host_us[name])
    # measured on an already-fused run: a member of a fused stage costs
    # one dispatch on its own too, and a dispatch costs what a dispatch
    # costs — inherit the fused measurement, don't apportion it.
    for key, v in host_us.items():
        if name in key.split("+"):
            return float(v)
    return 1.0


def _group_host(group, host_us) -> float:
    key = "+".join(group)
    if key in host_us:          # measured on an already-fused run
        return float(host_us[key])
    return max(_stage_host(n, host_us) for n in group)


def score_fusion(groups, *, host_us=None, dev_us=None,
                 replicas=None) -> FusionScore:
    """Virtual-clock score of one partition.  ``host_us`` is the measured
    per-stage dispatch cost (``per_stage_host_us``) folded in as a fixed
    cost per firing — one dispatch per *group* after fusion.  Keys may be
    base stage names or ``+``-joined fused names (so re-scoring with
    measurements from a fused run reaches the same fixed point)."""
    host_us = host_us or {}
    dev_us = dev_us or {}
    replicas = replicas or {}
    groups = tuple(tuple(g) for g in groups)
    serial = sum(_group_host(g, host_us) for g in groups)
    bottleneck = 0.0
    for g in groups:
        nr = min(int(replicas.get(n, 1)) for n in g)
        dev = sum(float(dev_us.get(n, 0.0)) for n in g) / max(1, nr)
        bottleneck = max(bottleneck, dev + _group_host(g, host_us))
    return FusionScore(groups=groups, period_us=max(serial, bottleneck),
                       host_us=serial, bottleneck_us=bottleneck)


def enumerate_fusions(names, *, heavy=(), max_group: int | None = None):
    """All contiguous partitions of the stage chain with at most one
    ``heavy`` member per group.  Heavy stages own resident pipeline state
    (KV-cache period spans): fusing two of them is the planner's
    ``periods_per_stage`` axis, not stage combining, and would relocate
    live state — so those candidates are structurally excluded."""
    names = list(names)
    heavy = set(heavy)
    out = []

    def rec(i, acc):
        if i == len(names):
            out.append(tuple(acc))
            return
        for j in range(i + 1, len(names) + 1):
            g = tuple(names[i:j])
            if max_group is not None and len(g) > max_group:
                break
            if sum(1 for n in g if n in heavy) > 1:
                break
            rec(j, acc + [g])

    rec(0, [])
    return out


def auto_fusion(names, *, host_us=None, dev_us=None, heavy=(),
                replicas=None, slack: float = 1.0,
                max_group: int | None = None,
                dev_in_score: bool = True) -> FusionScore:
    """Pick the fusion plan that minimizes the virtual-clock period.

    Candidates are contiguous partitions of the chain (``enumerate_fusions``
    structural rules).  Two further guards: members of a group must share a
    replica count (``combine`` requires it), and a group's summed device
    time may not exceed ``(1 + slack)`` x the unfused per-stage bottleneck
    — combining below the bottleneck deletes dispatch for free; raising the
    device bottleneck is the *splitting* direction's trade, not fusion's.
    Ties prefer the partition with more groups (least fusion).

    ``dev_in_score=False`` keeps device time in the guards but out of the
    score — the no-measurement mode, where host cost is a uniform
    placeholder and the score reduces to minimizing dispatch count
    (mixing placeholder units into microsecond device times would let the
    device term veto every fusion).
    """
    names = list(names)
    host_us = host_us or {}
    dev_us = dev_us or {}
    replicas = replicas or {}
    max_dev = max((float(dev_us.get(n, 0.0)) / max(1, int(replicas.get(n, 1)))
                   for n in names), default=0.0)
    best = None
    for cand in enumerate_fusions(names, heavy=heavy, max_group=max_group):
        ok = True
        for g in cand:
            if len({int(replicas.get(n, 1)) for n in g}) != 1:
                ok = False
                break
            nr = int(replicas.get(g[0], 1))
            dev = sum(float(dev_us.get(n, 0.0)) for n in g) / max(1, nr)
            if max_dev > 0 and dev > (1.0 + slack) * max_dev:
                ok = False
                break
        if not ok:
            continue
        sc = score_fusion(cand, host_us=host_us,
                          dev_us=dev_us if dev_in_score else None,
                          replicas=replicas)
        key = (sc.period_us, sc.host_us, -len(sc.groups))
        if best is None or key < best[0]:
            best = (key, sc)
    if best is None:
        raise ValueError("no feasible fusion candidate (replica counts "
                         "unalignable?)")
    return best[1]
