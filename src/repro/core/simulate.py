"""Cycle-approximate KPN/STG simulator (paper §III.A).

Deterministic Kahn semantics: nodes block on their input FIFOs; a node fires
when every required input port holds a full rate-block of ready tokens and
the node's PE is free (``t >= next_free``); outputs become visible after the
implementation's latency and the PE is busy for II cycles.

JOIN nodes are the one (deterministic) exception to the all-ports rule: a
round-robin collector only needs its *scheduled* port (paper §II.B.2.c), and
the schedule is part of the node state, so determinism is preserved.

Used to validate (a) functional equivalence of transformed graphs (token
streams identical to the original graph's) and (b) that measured steady-state
inverse throughput matches the analytical model of `repro.core.throughput`.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field

from .stg import JOIN, SOURCE, STG, Selection


@dataclass
class SimResult:
    outputs: dict[str, list] = field(default_factory=dict)   # sink node -> tokens
    fire_times: dict[str, list[float]] = field(default_factory=dict)
    cycles: float = 0.0
    fired: dict[str, int] = field(default_factory=dict)

    def inverse_throughput(self, sink: str, warmup_frac: float = 0.25) -> float:
        """Steady-state cycles per firing at a sink (discard pipeline fill)."""
        times = self.fire_times[sink]
        if len(times) < 4:
            raise ValueError(f"too few firings at {sink} ({len(times)})")
        k = max(1, int(len(times) * warmup_frac))
        window = times[k:]
        return (window[-1] - window[0]) / (len(window) - 1)


def run(stg: STG, sel: Selection, inputs: dict[str, list], max_cycles: float = 1e9,
        max_firings: int = 1_000_000) -> SimResult:
    """Simulate until all source streams drain and no node can fire.

    inputs: per source-node token list (sources emit their stream with the
    selected implementation's II)."""
    res = SimResult()
    fifos: dict[tuple, deque] = {}
    for ch in stg.channels:
        fifos[ch.key()] = deque()
    in_chs = {n: stg.in_channels(n) for n in stg.nodes}
    out_chs = {n: stg.out_channels(n) for n in stg.nodes}
    state = {n: stg.nodes[n].init_state for n in stg.nodes}
    next_free = {n: 0.0 for n in stg.nodes}
    src_streams = {n: deque(toks) for n, toks in inputs.items()}
    for n in stg.nodes:
        res.fired[n] = 0
        res.fire_times[n] = []
        if not out_chs[n]:
            res.outputs[n] = []

    def ready_time(name: str, now_hint: float) -> float | None:
        """Earliest time >= next_free when the node can fire, or None."""
        node = stg.nodes[name]
        chans = in_chs[name]
        if not chans:  # source
            if name not in src_streams or not src_streams[name]:
                return None
            if len(src_streams[name]) < node.out_rates[0]:
                return None
            return next_free[name]
        if node.kind == JOIN:
            k = state[name] or 0
            ch = chans[k]
            need = node.in_rates[k]
            q = fifos[ch.key()]
            if len(q) < need:
                return None
            t = max(next_free[name], max(q[i][1] for i in range(need)))
            return t
        t = next_free[name]
        for ch in chans:
            need = node.in_rates[ch.dst_port]
            q = fifos[ch.key()]
            if len(q) < need:
                return None
            t = max(t, max(q[i][1] for i in range(need)))
        return t

    # Event loop: fire the earliest-ready node; ties broken by name for
    # determinism (result streams are schedule-independent by KPN property).
    heap: list[tuple[float, str]] = []
    for n in stg.nodes:
        t = ready_time(n, 0.0)
        if t is not None:
            heapq.heappush(heap, (t, n))
    total_fired = 0
    now = 0.0
    while heap and total_fired < max_firings:
        now, name = heapq.heappop(heap)
        if now > max_cycles:
            break
        t = ready_time(name, now)
        if t is None:
            continue
        if t > now:
            heapq.heappush(heap, (t, name))
            continue
        node = stg.nodes[name]
        impl = sel.impl_of(stg, name)
        # -- consume
        ins: list[list] = [[] for _ in range(max(1, node.n_in))]
        if in_chs[name]:
            if node.kind == JOIN:
                k = state[name] or 0
                q = fifos[in_chs[name][k].key()]
                ins[k] = [q.popleft()[0] for _ in range(node.in_rates[k])]
            else:
                for ch in in_chs[name]:
                    q = fifos[ch.key()]
                    ins[ch.dst_port] = [q.popleft()[0]
                                        for _ in range(node.in_rates[ch.dst_port])]
        else:
            ins[0] = [src_streams[name].popleft() for _ in range(node.out_rates[0])]
        # -- compute
        if node.fn is not None:
            outs, state[name] = node.fn(ins, state[name])
        elif not in_chs[name]:
            outs = [ins[0]]  # source passes its stream through
        else:
            # pass-through default; sinks record their consumed stream
            outs = [list(ins[0]) for _ in range(node.n_out)] if out_chs[name] else [list(ins[0])]
        # -- produce
        done = now + (impl.latency or impl.ii)
        if out_chs[name]:
            for ch in out_chs[name]:
                for tok in outs[ch.src_port]:
                    fifos[ch.key()].append((tok, done))
        else:
            for port_out in outs:
                res.outputs[name].extend(port_out)
        res.fired[name] += 1
        res.fire_times[name].append(now)
        total_fired += 1
        next_free[name] = now + impl.ii
        res.cycles = max(res.cycles, done)
        # -- reschedule this node and downstream consumers
        cand = [name] + [ch.dst for ch in out_chs[name]]
        for c in set(cand):
            t = ready_time(c, now)
            if t is not None:
                heapq.heappush(heap, (t, c))
    return res


def run_functional(stg: STG, sel: Selection, inputs: dict[str, list],
                   max_firings: int = 1_000_000) -> dict[str, list]:
    """Timing-free run; returns sink streams (KPN determinism makes this the
    canonical output for equivalence checks)."""
    return run(stg, sel, inputs, max_firings=max_firings).outputs
