"""Intra-Node Optimizer (paper §II.A.1, Figs. 2-4).

A composite node's body is a DAG of *primitive operations*; each op kind has
an initiation interval (cycles a PE is busy per result: e.g. div = 8 on the
simple PE).  The optimizer enumerates implementations spanning the full
space/time range:

  * pipelining  — one PE per op; II = max op ii (Fig. 2: div stalls => II=8),
  * expansion   — replicate ops with ii > target round-robin (Fig. 3: 8
                  dividers => II=1),
  * clustering  — pack ops onto shared PEs; a cluster's II = sum of member
                  iis; node II = max cluster II (area savings, Fig. 4 right).

For a target II = t the greedy schedule packs topologically-sorted ops into
clusters with total ii <= t, and expands any single op with ii > t into
ceil(ii/t) round-robin copies.  area(t) = #clusters + total extra copies.
The resulting (II, area) frontier for the paper's N-body force node spans
II = 1 .. sum(ii) = 33 exactly as Fig. 4.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .stg import Impl

# Default primitive-op inverse throughputs on the simple PE (paper Fig. 2:
# division takes 8 cycles; mul is multi-cycle; add/sub single-cycle).
DEFAULT_OP_II: dict[str, float] = {
    "add": 1, "sub": 1, "neg": 1, "abs": 1, "min": 1, "max": 1, "cmp": 1,
    "shift": 1, "and": 1, "or": 1, "xor": 1, "copy": 1, "sel": 1,
    "mul": 2, "mac": 2,
    "div": 8, "sqrt": 8, "rsqrt": 8, "exp": 8, "log": 8,
    "lut": 1, "table": 1,
}


@dataclass(frozen=True)
class PrimOp:
    name: str
    kind: str
    deps: tuple[str, ...] = ()
    ii: float | None = None  # override library ii

    def resolved_ii(self, lib: dict[str, float]) -> float:
        if self.ii is not None:
            return float(self.ii)
        if self.kind not in lib:
            raise KeyError(f"unknown primitive op kind {self.kind!r}")
        return float(lib[self.kind])


@dataclass
class CompositeBody:
    """The primitive-op DAG inside one composite node."""

    ops: tuple[PrimOp, ...]
    op_lib: dict[str, float] = field(default_factory=lambda: dict(DEFAULT_OP_II))

    def __post_init__(self):
        names = set()
        for op in self.ops:
            if op.name in names:
                raise ValueError(f"duplicate op {op.name}")
            names.add(op.name)
        for op in self.ops:
            for d in op.deps:
                if d not in names:
                    raise ValueError(f"op {op.name} depends on unknown {d}")

    def topo(self) -> list[PrimOp]:
        by_name = {o.name: o for o in self.ops}
        seen: dict[str, int] = {}
        order: list[PrimOp] = []

        def visit(o: PrimOp):
            state = seen.get(o.name, 0)
            if state == 1:
                raise ValueError("cycle in primitive DAG")
            if state == 2:
                return
            seen[o.name] = 1
            for d in o.deps:
                visit(by_name[d])
            seen[o.name] = 2
            order.append(o)

        for o in self.ops:
            visit(o)
        return order

    def total_ii(self) -> float:
        return sum(op.resolved_ii(self.op_lib) for op in self.ops)

    def max_ii(self) -> float:
        return max(op.resolved_ii(self.op_lib) for op in self.ops)

    def critical_latency(self) -> float:
        """Longest dependence path (sum of iis) — pipeline fill latency."""
        lat: dict[str, float] = {}
        for op in self.topo():
            lat[op.name] = op.resolved_ii(self.op_lib) + max(
                (lat[d] for d in op.deps), default=0.0)
        return max(lat.values()) if lat else 0.0


@dataclass
class ScheduledImpl:
    """An implementation + its schedule provenance."""

    impl: Impl
    clusters: list[list[str]]
    expansions: dict[str, int]  # op name -> copies (round-robin expansion)


def schedule_for_target(body: CompositeBody, target_ii: float) -> ScheduledImpl:
    """Greedy topological packing for a target II (see module docstring)."""
    if target_ii <= 0:
        raise ValueError("target_ii must be positive")
    clusters: list[list[str]] = []
    expansions: dict[str, int] = {}
    cur: list[str] = []
    cur_ii = 0.0
    area = 0.0
    for op in body.topo():
        ii = op.resolved_ii(body.op_lib)
        if ii > target_ii:
            # Expansion (Fig. 3): round-robin copies bring effective ii to target.
            copies = math.ceil(ii / target_ii - 1e-12)
            if cur:
                clusters.append(cur)
                cur, cur_ii = [], 0.0
            clusters.append([op.name])
            expansions[op.name] = copies
            area += copies
            continue
        if cur_ii + ii > target_ii + 1e-12:
            clusters.append(cur)
            cur, cur_ii = [], 0.0
        cur.append(op.name)
        cur_ii += ii
    if cur:
        clusters.append(cur)
    area += sum(1 for c in clusters if c[0] not in expansions)
    achieved = 0.0
    for c in clusters:
        if c[0] in expansions:
            op = next(o for o in body.ops if o.name == c[0])
            achieved = max(achieved, op.resolved_ii(body.op_lib) / expansions[c[0]])
        else:
            achieved = max(achieved, sum(
                next(o for o in body.ops if o.name == n).resolved_ii(body.op_lib) for n in c))
    impl = Impl(name=f"ii{achieved:g}_a{area:g}", area=area, ii=achieved,
                latency=body.critical_latency(),
                meta={"target_ii": target_ii})
    return ScheduledImpl(impl, clusters, expansions)


def enumerate_impls(body: CompositeBody, targets: list[float] | None = None) -> list[Impl]:
    """Enumerate the Pareto frontier of (II, area) implementations.

    Candidate targets default to every achievable II between 1 (full
    expansion) and sum of op iis (single PE)."""
    if targets is None:
        hi = int(math.ceil(body.total_ii()))
        targets = sorted({float(t) for t in range(1, hi + 1)})
    impls: list[Impl] = []
    for t in targets:
        s = schedule_for_target(body, t)
        impls.append(s.impl)
    # Pareto-filter on (ii, area); dedupe by (ii, area).
    impls.sort(key=lambda im: (im.ii, im.area))
    frontier: list[Impl] = []
    for im in impls:
        if frontier and im.ii == frontier[-1].ii:
            continue
        if not frontier or im.area < frontier[-1].area:
            frontier.append(im)
    # Re-name canonically v1..vk (fastest first) to mirror the paper's tables.
    out = []
    for i, im in enumerate(frontier):
        out.append(Impl(name=f"v{i+1}", area=im.area, ii=im.ii,
                        latency=im.latency, meta=im.meta))
    return out
