"""Throughput analysis and propagation (paper §II.B.2.a/b, Eq. 1, 5, 6, 7).

All quantities are *inverse throughputs* (cycles per token), written ``v``.
Replication divides a node's effective inverse throughput: ``nr`` round-robin
replicas of an implementation with inverse throughput ``v`` sustain ``v / nr``.

Per channel (Eq. 5):   slack  v_s = v_mo - v_ei
  v_mo : producer's minimum output inverse throughput on the channel,
  v_ei : consumer's expected input inverse throughput on the channel.
A channel with v_s > 0 starves its consumer (producer is the bottleneck);
v_s < 0 means the consumer cannot keep up (consumer is the bottleneck).

Per node (Eq. 6):      weight W_m = (sum_out v_s - sum_in v_s) / (N_in + N_out)
High weight == critical bottleneck.

Propagation (Eq. 7):   v_out^k = min_j { v_in^j * In^j } / Out^k
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .stg import STG, Channel, Selection


@dataclass
class ChannelRates:
    channel: Channel
    v_mo: float   # producer min output inverse throughput (cycles/token)
    v_ei: float   # consumer expected input inverse throughput
    slack: float  # Eq. 5


@dataclass
class Analysis:
    channels: dict[tuple, ChannelRates] = field(default_factory=dict)
    weights: dict[str, float] = field(default_factory=dict)
    v_app: float = 0.0                 # application inverse throughput (cycles/graph iteration, normalised)
    cycles_per_iteration: float = 0.0  # max_m q_m * II_m / nr_m
    bottleneck: str | None = None
    node_iter_time: dict[str, float] = field(default_factory=dict)

    def ranked_bottlenecks(self) -> list[str]:
        return sorted(self.weights, key=lambda n: -self.weights[n])


def node_v_out(stg: STG, sel: Selection, name: str, port: int) -> float:
    impl = sel.impl_of(stg, name)
    nr = sel.replicas(name)
    return impl.ii / (stg.nodes[name].out_rates[port] * nr)


def node_v_in(stg: STG, sel: Selection, name: str, port: int) -> float:
    impl = sel.impl_of(stg, name)
    nr = sel.replicas(name)
    return impl.ii / (stg.nodes[name].in_rates[port] * nr)


def analyze(stg: STG, sel: Selection) -> Analysis:
    """Full-graph throughput analysis under a selection (Eq. 1, 5, 6)."""
    a = Analysis()
    q = stg.repetition_vector()
    # Per-node steady-state time per graph iteration.
    for name, node in stg.nodes.items():
        impl = sel.impl_of(stg, name)
        a.node_iter_time[name] = q[name] * impl.ii / sel.replicas(name)
    a.cycles_per_iteration = max(a.node_iter_time.values()) if a.node_iter_time else 0.0
    a.v_app = a.cycles_per_iteration

    for ch in stg.channels:
        v_mo = node_v_out(stg, sel, ch.src, ch.src_port)
        v_ei = node_v_in(stg, sel, ch.dst, ch.dst_port)
        a.channels[ch.key()] = ChannelRates(ch, v_mo, v_ei, v_mo - v_ei)

    for name, node in stg.nodes.items():
        ins = stg.in_channels(name)
        outs = stg.out_channels(name)
        s_in = sum(a.channels[c.key()].slack for c in ins)
        s_out = sum(a.channels[c.key()].slack for c in outs)
        denom = max(1, len(ins) + len(outs))
        a.weights[name] = (s_out - s_in) / denom  # Eq. 6

    a.bottleneck = max(a.node_iter_time, key=lambda n: a.node_iter_time[n]) if a.node_iter_time else None
    return a


def propagate_targets(stg: STG, v_tgt: float) -> dict[str, float]:
    """Propagate an application-level inverse-throughput target to every node
    (Eq. 7).  ``v_tgt`` is the inverse throughput demanded on each source
    node's input stream.  Returns, per node, the target inverse throughput
    *per firing* (i.e. the maximum II/nr the node may have)."""
    order = stg.topo_order()
    # Target v on each channel, keyed by channel key.
    chan_v: dict[tuple, float] = {}
    firing_v: dict[str, float] = {}
    for name in order:
        node = stg.nodes[name]
        ins = stg.in_channels(name)
        if ins:
            # Eq. 7 numerator: min over input channels of v_in^j * In^j.
            per_firing = min(chan_v[c.key()] * node.in_rates[c.dst_port] for c in ins)
        else:
            per_firing = v_tgt * node.in_rates[0] if node.in_rates else v_tgt
        firing_v[name] = per_firing
        for c in stg.out_channels(name):
            chan_v[c.key()] = per_firing / node.out_rates[c.src_port]  # Eq. 7
    return firing_v


def min_replicas(ii: float, v_firing_target: float) -> int:
    """Replicas needed so ii / nr <= target (Eq. 8 generalised)."""
    import math
    if v_firing_target <= 0:
        raise ValueError("target must be positive")
    return max(1, math.ceil(ii / v_firing_target - 1e-12))
