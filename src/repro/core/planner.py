"""Pod-scale parallelism planner = the paper's trade-off finder on LM STGs.

``plan()`` runs the paper's two optimisation modes over the LM task graph
built by ``repro.graphs.lm_graph``:

  * min_chips       (paper: min area s.t. v <= v_tgt)  — "hit this many
    tokens/s with as few chips as possible"
  * max_throughput  (paper: min v s.t. area <= A_C)    — "I have one pod
    (256 chips); make it as fast as possible"

Both engines run: the ILP (Eq. 3/4, stand-alone fork/join trees) and the
heuristic (bottleneck-driven + node combining).  On LM graphs the heuristic
exhibits the paper's headline behaviour — it aligns replica counts across
stage boundaries (combining) and deletes routing cost the ILP must pay.

``to_execution()`` projects a plan onto an executable GSPMD configuration
(mesh shape + ShardingPolicy knobs + grad accumulation) — the modal
(tp, nr) of the block stages; embed/head keep their own recommendation
via vocab sharding.  ``replan()`` is the elastic-scaling entry point: the
same graph re-solved for a new chip count (runtime.elastic drives it).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..analysis.roofline import HW_V5E, Hardware
from ..configs.base import ModelConfig, ShapeCfg
from ..graphs import lm_graph
from . import heuristic, ilp
from .ilp import TradeoffResult
from .throughput import analyze


@dataclass(frozen=True)
class StagePlan:
    name: str
    impl: str
    tp: int
    replicas: int

    @property
    def chips(self) -> int:
        return self.tp * self.replicas


@dataclass
class PlanResult:
    arch: str
    shape: str
    mode: str                    # min_chips | max_throughput
    engine: str                  # ilp | heuristic
    stages: list[StagePlan]
    total_chips: float           # incl. routing overhead chip-equivalents
    impl_chips: float
    overhead_chips: float
    v_firing_us: float
    tokens_per_s: float
    solve_seconds: float
    feasible: bool
    info: dict = field(default_factory=dict)

    def summary(self) -> str:
        head = (f"[{self.engine}/{self.mode}] {self.arch} x {self.shape}: "
                f"{self.total_chips:.0f} chips "
                f"({self.impl_chips:.0f} impl + {self.overhead_chips:.1f} routing), "
                f"v={self.v_firing_us:.1f}us/firing, "
                f"{self.tokens_per_s:,.0f} tok/s, "
                f"solve {self.solve_seconds*1e3:.0f}ms")
        groups: dict[tuple[str, int], list[str]] = {}
        for sp in self.stages:
            groups.setdefault((sp.impl, sp.replicas), []).append(sp.name)
        rows = [f"  {names[0]}..{names[-1]} ({len(names)}): {im} x{nr}"
                for (im, nr), names in groups.items()]
        return head + "\n" + "\n".join(rows)


def _stage_plans(res: TradeoffResult) -> list[StagePlan]:
    out = []
    for name, (impl_name, nr) in sorted(res.selection.choices.items()):
        tp = int(impl_name[2:]) if impl_name.startswith("tp") else 1
        out.append(StagePlan(name=name, impl=impl_name, tp=tp, replicas=nr))
    return out


def plan(cfg: ModelConfig, shape: ShapeCfg, *, chips: int | None = None,
         tokens_per_s: float | None = None, engine: str = "heuristic",
         hw: Hardware = HW_V5E, max_tp: int = 256, nf: int = 4,
         mb_seqs: int | None = None, fj_iters: int = 2,
         ii_scale: dict[str, float] | None = None) -> PlanResult:
    """Solve one trade-off mode.  Exactly one of chips / tokens_per_s.

    ``ii_scale``: per-stage measured/analytic inverse-throughput ratios
    from an executed pipeline (runtime.pipeline.measure) — the solver then
    sizes the plan to measured stage behaviour."""
    if (chips is None) == (tokens_per_s is None):
        raise ValueError("pass exactly one of chips= / tokens_per_s=")
    stg, info = lm_graph.build_stg(cfg, shape, hw=hw, max_tp=max_tp,
                                   mb_seqs=mb_seqs, ii_scale=ii_scale)
    eng = {"ilp": ilp, "heuristic": heuristic}[engine]

    if tokens_per_s is not None:
        mode = "min_chips"
        v_tgt_us = info["toks_per_firing"] / tokens_per_s * 1e6
        fj = lm_graph.tpu_fork_join(info["act_bytes"], v_tgt_us, hw=hw, nf=nf)
        res = eng.min_area(stg, v_tgt_us, fj)
    else:
        mode = "max_throughput"
        # router pricing depends on the achieved rate — fixed-point iterate
        from .stg import Selection
        v_est = analyze(stg, Selection.fastest(stg)).v_app
        res = None
        for _ in range(max(1, fj_iters)):
            fj = lm_graph.tpu_fork_join(info["act_bytes"], v_est, hw=hw, nf=nf)
            res = eng.max_throughput(stg, float(chips), fj)
            if res.v_app <= 0 or abs(res.v_app - v_est) / res.v_app < 0.05:
                break
            v_est = res.v_app
    v = res.v_app
    return PlanResult(
        arch=cfg.name, shape=shape.name, mode=mode, engine=engine,
        stages=_stage_plans(res),
        total_chips=res.total_area, impl_chips=res.impl_area,
        overhead_chips=res.overhead_area,
        v_firing_us=v,
        tokens_per_s=(info["toks_per_firing"] / v * 1e6) if v > 0 else 0.0,
        solve_seconds=res.solve_seconds, feasible=res.feasible,
        info={"toks_per_firing": info["toks_per_firing"],
              "act_bytes": info["act_bytes"], "n_firings": info["n_firings"]})


def plan_both(cfg: ModelConfig, shape: ShapeCfg, **kw) -> dict[str, PlanResult]:
    """ILP vs heuristic on the same problem (the paper's Table-2 shape)."""
    return {e: plan(cfg, shape, engine=e, **kw) for e in ("ilp", "heuristic")}


# ===========================================================================
# execution projection + elastic replanning
# ===========================================================================
@dataclass(frozen=True)
class ExecutionPlan:
    """Homogeneous GSPMD projection of a plan (what launch.* consumes)."""
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    dp: int
    tp: int
    grad_accum: int
    fsdp: bool
    notes: str = ""


def to_execution(p: PlanResult, *, cfg: ModelConfig | None = None,
                 chips: int = 256) -> ExecutionPlan:
    """Fold the spatial plan onto one fixed-size GSPMD mesh.

    The paper maps the STG *spatially* (each stage owns its PEs — pipeline
    parallelism).  A single jitted GSPMD program instead *timeshares* all
    stages over one mesh; the planner still decides the policy: the modal
    tensor-parallel degree of the block stages becomes the "model" axis,
    the rest of the chip budget becomes the "data" axis.  Heterogeneous
    residue (stages preferring another layout) is reported in ``notes`` —
    the analytic gap full heterogeneity would recover shows up in the
    roofline table.
    """
    blocks = [s for s in p.stages if s.name.startswith(("block", "enc"))]
    if not blocks:
        blocks = p.stages
    from collections import Counter
    tp, nr = Counter((s.tp, s.replicas) for s in blocks).most_common(1)[0][0]
    residue = [s.name for s in blocks if (s.tp, s.replicas) != (tp, nr)]
    hetero = ""
    if residue:
        hetero = (f"{len(residue)} stages prefer a different layout "
                  f"(e.g. {residue[:3]}); homogeneous projection keeps "
                  f"majority tp={tp}")
    tp = min(tp, chips)
    dp = max(1, chips // tp)
    accum = cfg.grad_accum if cfg is not None else 1
    big = cfg is not None and cfg.param_count() * 4 > 8e9
    return ExecutionPlan(
        mesh_shape=(dp, tp), mesh_axes=("data", "model"), dp=dp, tp=tp,
        grad_accum=accum, fsdp=big or dp * tp >= 64, notes=hetero)


def folded_tokens_per_s(cfg: ModelConfig, shape: ShapeCfg, *, chips: int,
                        tp: int, hw: Hardware = HW_V5E,
                        mb_seqs: int | None = None) -> dict:
    """Analytic throughput of the folded (single-mesh, timeshared) GSPMD
    layout: one microbatch per step over ALL chips, batch sharded dp =
    chips/tp, features/experts sharded tp.  Per-chip TP-collective bytes
    are ~ (tp-1) * toks_firing * d * b / chips per sync — so they GROW with
    tp at fixed chips (this is the lever the §Perf hillclimb measured:
    qwen tp16 -> tp1 cut the collective term 4.7x).  Stages whose state
    does not fit at the requested tp fall back to replicated-group
    execution and are counted in ``fallbacks``."""
    from ..graphs.lm_graph import BF16, stage_costs
    stages, info = stage_costs(cfg, shape, mb_seqs=mb_seqs)
    dp = max(1, chips // tp)
    total_us = 0.0
    per_stage = {}
    fallbacks = 0
    train = info["train"]
    for st in stages:
        if st.state_bytes / chips > 0.75 * hw.hbm_bytes:
            fallbacks += 1      # does not fit even fully sharded
        compute_s = st.flops / (chips * hw.peak_flops)
        memory_s = st.hbm_bytes / (chips * hw.hbm_bw)
        if st.tp_collectives != "none" and tp > 1:
            n_sync = 4 if train else 2
            factor = 2 if st.tp_collectives == "megatron" else 1
            per_chip = n_sync * factor * (tp - 1) / tp                 * st.act_out_bytes * tp / chips
            coll_s = per_chip / hw.link_bw
        else:
            coll_s = 0.0
        ii = max(compute_s, memory_s, coll_s) * 1e6
        total_us += ii
        per_stage[st.name] = ii
    tps = info["toks_per_firing"] / total_us * 1e6
    return {"tokens_per_s": tps, "firing_us": total_us, "dp": dp, "tp": tp,
            "per_stage_us": per_stage, "fallbacks": fallbacks}


def plan_fusion(cfg: ModelConfig, shape: ShapeCfg, plan_result: PlanResult, *,
                periods_per_stage: int = 1,
                host_us: dict[str, float] | None = None,
                hw: Hardware = HW_V5E, max_tp: int = 256,
                mb_seqs: int | None = None, slack: float = 1.0):
    """Score candidate stage-fusion plans for a decode pipeline on the
    virtual clock and return the winner (a ``restructure.FusionScore``).

    The candidate space is the runtime stage chain exactly as
    ``DecodePipeline`` builds it from this plan: ``embed``, one
    ``blocksNN`` per ``periods_per_stage`` block periods, ``head``.
    Device time per stage is the analytic II of its graph nodes under the
    plan's selection, calibrated to microseconds against the plan's
    ``v_firing_us`` (the ``measured_ratio``-style analytic->measured
    bridge).  ``host_us`` is measured ``per_stage_host_us`` from an
    executed pipeline, folded in as a per-stage fixed dispatch cost; when
    absent every stage costs one dispatch unit, so the score minimizes
    dispatch count subject to the structural guards.  Span-bearing
    ``blocksNN`` stages are ``heavy`` — they never fuse with each other
    (that axis is ``periods_per_stage``), so fusion absorbs the stateless
    ``embed``/``head`` endpoints into their neighbours.

    The loop closes on hardware: serve with the winner, feed the measured
    ``per_stage_host_us`` (keyed by the fused names) back in, and the
    re-score confirms the fixed point (``replan_to_fixed_point``-style).
    """
    from . import restructure
    stg, _info = lm_graph.build_stg(cfg, shape, hw=hw, max_tp=max_tp,
                                    mb_seqs=mb_seqs)
    choices = {s.name: (s.impl, s.replicas) for s in plan_result.stages}
    blocks = sorted(n for n in stg.nodes if n.startswith("block"))
    pps = max(1, int(periods_per_stage))
    spans = [(a, min(a + pps, len(blocks))) for a in range(0, len(blocks), pps)]
    stage_names = (["embed"]
                   + [f"blocks{i:02d}" for i in range(len(spans))]
                   + ["head"])
    owners = {"embed": ["embed"], "head": ["head"]}
    for i, (a, b) in enumerate(spans):
        owners[f"blocks{i:02d}"] = blocks[a:b]
    # analytic node iter time -> microseconds via the plan's firing period
    iter_t = {n: stg.nodes[n].impl(choices[n][0]).ii / max(1, choices[n][1])
              for n in stg.nodes if n in choices}
    v_app = max(iter_t.values())
    us_per_unit = (plan_result.v_firing_us / v_app) if v_app > 0 else 0.0
    dev_us, replicas = {}, {}
    for sn in stage_names:
        dev_us[sn] = sum(stg.nodes[n].impl(choices[n][0]).ii
                         for n in owners[sn]) * us_per_unit
        replicas[sn] = min(choices[n][1] for n in owners[sn])
    heavy = [sn for sn in stage_names if sn.startswith("blocks")]
    return restructure.auto_fusion(stage_names, host_us=host_us,
                                   dev_us=dev_us, heavy=heavy,
                                   replicas=replicas, slack=slack,
                                   dev_in_score=host_us is not None)


def replan(cfg: ModelConfig, shape: ShapeCfg, old: PlanResult, *,
           new_chips: int, engine: str = "heuristic",
           measured_ratio: dict[str, float] | None = None,
           fusion_host_us: dict[str, float] | None = None,
           periods_per_stage: int = 1,
           **kw) -> tuple[PlanResult, dict]:
    """Elastic rescale: re-solve for a new chip budget; diff vs old plan.

    This is the paper's core motivation ("scaling a program to a larger or
    smaller processor array requires manually re-programming all objects
    and channels" — here it is one solver call).

    ``measured_ratio``: measured/analytic per-stage ratios from an executed
    pipeline (PipelineReport.ratios()); when given, the re-solve runs on
    the measurement-calibrated graph (measurement-guided re-planning).

    ``fusion_host_us``: measured ``per_stage_host_us`` from the running
    pool; when given, the re-plan also re-scores stage fusion for the new
    plan (``plan_fusion``) and reports the winning groups in
    ``diff["fusion_groups"]`` — so an elastic rescale carries the
    dispatch-deletion decision forward instead of silently unfusing."""
    new = plan(cfg, shape, chips=new_chips, engine=engine,
               ii_scale=measured_ratio, **kw)
    changed = []
    old_by = {s.name: s for s in old.stages}
    for s in new.stages:
        o = old_by.get(s.name)
        if o is not None and (o.tp, o.replicas) != (s.tp, s.replicas):
            changed.append((s.name, (o.tp, o.replicas), (s.tp, s.replicas)))
    diff = {
        "chips": (old.total_chips, new.total_chips),
        "tokens_per_s": (old.tokens_per_s, new.tokens_per_s),
        "stages_changed": changed,
        "throughput_ratio": (new.tokens_per_s / old.tokens_per_s
                             if old.tokens_per_s else float("inf")),
    }
    if fusion_host_us is not None:
        diff["fusion_groups"] = plan_fusion(
            cfg, shape, new, periods_per_stage=periods_per_stage,
            host_us=fusion_host_us).groups
    return new, diff
