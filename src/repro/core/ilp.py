"""ILP trade-off finder (paper §II.B.1, Eq. 3-4).

Variables: binary x_{j,i} selecting implementation i for node j, and replica
counts nr_{j,i}.  Because the minimum feasible replica count for a chosen
implementation is determined by the propagated throughput target
(nr* = ceil(II / target), Eq. 8), the MILP is formulated over per-node
*choices* c = (impl, nr) with precomputed cost

    cost(c) = nr * A(impl) + forkjoin.replication_overhead(nr)

exactly matching the paper's ILP behaviour: "ILP replicates the bottleneck
without any attention to its neighbouring nodes" — overhead is charged as
stand-alone fork+join trees (Eq. 9), and node combining/splitting is NOT
expressible (the paper's stated shortcoming, which our heuristic exploits).

Two problems:
  * min_area       — Eq. 4: minimise A_A s.t. v_A <= v_tgt.
  * max_throughput — Eq. 3: minimise v_A s.t. A_A <= A_C.

Both are solved with scipy's HiGHS MILP when available; a pure-Python exact
branch-and-bound fallback is provided so the tool has no hard scipy
dependency.  Solve wall-time is reported (the paper claims the heuristic is
faster — benchmarks/bench_solver_speed.py checks that claim).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import numpy as np

from .fork_join import ForkJoinModel, LITERAL
from .stg import STG, Selection
from .throughput import analyze, propagate_targets

try:  # scipy is optional
    from scipy.optimize import Bounds, LinearConstraint, milp

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False


@dataclass
class TradeoffResult:
    selection: Selection
    impl_area: float
    overhead_area: float
    total_area: float
    v_app: float
    solver: str
    solve_seconds: float
    feasible: bool = True
    meta: dict = field(default_factory=dict)

    def summary(self) -> str:
        rows = [f"  {n}: {i} x{nr}" for n, (i, nr) in sorted(self.selection.choices.items())]
        return (f"[{self.solver}] v_app={self.v_app:g} area={self.total_area:g} "
                f"(impl {self.impl_area:g} + overhead {self.overhead_area:g})\n" + "\n".join(rows))


def _selectable(stg: STG) -> list[str]:
    """Nodes the solver selects implementations for (sources/sinks with a
    single zero-area impl are pass-through endpoints)."""
    return [n for n in stg.topo_order() if stg.nodes[n].kind == "compute"]


def _endpoint_selection(stg: STG) -> dict[str, tuple[str, int]]:
    return {n: (stg.nodes[n].impls[0].name, 1)
            for n in stg.nodes if stg.nodes[n].kind != "compute"}


@dataclass(frozen=True)
class _Choice:
    impl: str
    nr: int
    area: float      # nr * A(impl)
    overhead: float  # stand-alone fork/join tree cost for nr replicas
    v_firing: float  # II / nr  (per-firing inverse throughput)

    @property
    def cost(self) -> float:
        return self.area + self.overhead


def _choices_for_target(stg: STG, name: str, firing_target: float,
                        fj: ForkJoinModel) -> list[_Choice]:
    """All (impl, minimal nr) choices meeting a per-firing target."""
    out = []
    for im in stg.nodes[name].impls:
        nr = max(1, math.ceil(im.ii / firing_target - 1e-12))
        out.append(_Choice(im.name, nr, nr * im.area,
                           fj.replication_overhead(nr), im.ii / nr))
    return out


def _choice_grid(stg: STG, name: str, q: int, nr_cap: int,
                 fj: ForkJoinModel) -> list[_Choice]:
    """Pareto grid of (impl, nr) choices for the area-constrained problem."""
    cands: list[_Choice] = []
    for im in stg.nodes[name].impls:
        nr = 1
        while nr <= nr_cap:
            cands.append(_Choice(im.name, nr, nr * im.area,
                                 fj.replication_overhead(nr), im.ii / nr))
            nr *= 2
        exact = max(1, min(nr_cap, math.ceil(im.ii)))
        for nr2 in {exact, max(1, exact // 2), min(nr_cap, exact * 2)}:
            cands.append(_Choice(im.name, nr2, nr2 * im.area,
                                 fj.replication_overhead(nr2), im.ii / nr2))
    # Pareto filter on (v_firing, cost).
    cands.sort(key=lambda c: (c.v_firing, c.cost))
    front: list[_Choice] = []
    for c in cands:
        if front and c.v_firing == front[-1].v_firing:
            continue
        if not front or c.cost < front[-1].cost:
            front.append(c)
    return front


def _solve_selection_milp(per_node: dict[str, list[_Choice]],
                          extra_area_budget: float | None = None,
                          node_q: dict[str, int] | None = None):
    """Assemble and solve the 0/1 selection MILP with HiGHS.

    min sum cost*x   s.t.  per node sum x = 1  [, sum area*x <= budget]
    When a budget is given, additionally minimises the max normalised
    inverse throughput t with big-M linking constraints (Eq. 3 mode).
    Returns (chosen index per node, objective, bool used_milp).
    """
    names = list(per_node)
    idx: list[tuple[str, int]] = [(n, i) for n in names for i in range(len(per_node[n]))]
    nvar = len(idx)
    throughput_mode = extra_area_budget is not None
    ncols = nvar + (1 if throughput_mode else 0)  # [+ t]

    c = np.zeros(ncols)
    if throughput_mode:
        c[-1] = 1.0  # minimise t = v_app
    else:
        for k, (n, i) in enumerate(idx):
            c[k] = per_node[n][i].cost

    A_rows, lbs, ubs = [], [], []
    for n in names:  # one-hot per node
        row = np.zeros(ncols)
        for k, (nn, i) in enumerate(idx):
            if nn == n:
                row[k] = 1.0
        A_rows.append(row); lbs.append(1.0); ubs.append(1.0)
    if throughput_mode:
        row = np.zeros(ncols)
        for k, (n, i) in enumerate(idx):
            row[k] = per_node[n][i].cost
        A_rows.append(row); lbs.append(-np.inf); ubs.append(float(extra_area_budget))
        for k, (n, i) in enumerate(idx):
            # t >= v_c * x (valid linearisation: v_c, t >= 0 and x binary)
            row = np.zeros(ncols)
            row[k] = per_node[n][i].v_firing * node_q[n]
            row[-1] = -1.0
            A_rows.append(row); lbs.append(-np.inf); ubs.append(0.0)

    if not _HAVE_SCIPY:
        return None
    integrality = np.ones(ncols)
    lo = np.zeros(ncols)
    hi = np.ones(ncols)
    if throughput_mode:
        integrality[-1] = 0
        hi[-1] = np.inf
    res = milp(c=c, constraints=LinearConstraint(np.array(A_rows), np.array(lbs), np.array(ubs)),
               integrality=integrality, bounds=Bounds(lo, hi))
    if not res.success:
        return ("infeasible", None)
    chosen = {}
    for k, (n, i) in enumerate(idx):
        if res.x[k] > 0.5:
            chosen[n] = i
    return (chosen, float(res.fun))


def min_area(stg: STG, v_tgt: float, fj: ForkJoinModel = LITERAL,
             solver: str = "auto") -> TradeoffResult:
    """Eq. 4: minimise area subject to application inverse throughput <= v_tgt."""
    t0 = time.perf_counter()
    targets = propagate_targets(stg, v_tgt)
    names = _selectable(stg)
    per_node = {n: _choices_for_target(stg, n, targets[n], fj) for n in names}

    used = "ilp-greedy"
    chosen: dict[str, int]
    if solver in ("auto", "milp") and _HAVE_SCIPY:
        out = _solve_selection_milp(per_node)
        if out is not None and out[0] != "infeasible":
            chosen, _ = out
            used = "ilp-highs"
        else:  # pragma: no cover
            chosen = {n: min(range(len(per_node[n])), key=lambda i: per_node[n][i].cost)
                      for n in names}
    else:
        # Exact fallback: the objective separates per node.
        chosen = {n: min(range(len(per_node[n])), key=lambda i: per_node[n][i].cost)
                  for n in names}

    sel = Selection(dict(_endpoint_selection(stg)))
    impl_area = overhead = 0.0
    for n in names:
        ch = per_node[n][chosen[n]]
        sel.set(n, ch.impl, ch.nr)
        impl_area += ch.area
        overhead += ch.overhead
    v_app = analyze(stg, sel).v_app
    return TradeoffResult(sel, impl_area, overhead, impl_area + overhead, v_app,
                          used, time.perf_counter() - t0,
                          feasible=v_app <= v_tgt + 1e-9,
                          meta={"v_tgt": v_tgt})


def max_throughput(stg: STG, area_budget: float, fj: ForkJoinModel = LITERAL,
                   solver: str = "auto") -> TradeoffResult:
    """Eq. 3: minimise application inverse throughput subject to area <= A_C."""
    t0 = time.perf_counter()
    q = stg.repetition_vector()
    names = _selectable(stg)
    min_impl_area = min(im.area for n in names for im in stg.nodes[n].impls)
    nr_cap = max(1, int(area_budget // max(min_impl_area, 1e-9)))
    per_node = {n: _choice_grid(stg, n, q[n], nr_cap, fj) for n in names}

    used = "ilp-bisect"
    chosen: dict[str, int] | None = None
    if solver == "milp" and _HAVE_SCIPY:
        out = _solve_selection_milp(per_node, extra_area_budget=area_budget, node_q=q)
        if out is not None and out[0] != "infeasible":
            chosen, _ = out
            used = "ilp-highs"
    if chosen is None:
        # Exact bisection over candidate v_app values (area(v) is monotone).
        cand = sorted({c.v_firing * q[n] for n in names for c in per_node[n]})

        def area_at(v: float) -> tuple[float, dict[str, int] | None]:
            total, pick = 0.0, {}
            for n in names:
                ok = [i for i, c in enumerate(per_node[n]) if c.v_firing * q[n] <= v + 1e-12]
                if not ok:
                    return math.inf, None
                i = min(ok, key=lambda i: per_node[n][i].cost)
                pick[n] = i
                total += per_node[n][i].cost
            return total, pick

        lo, hi = 0, len(cand) - 1
        best = None
        while lo <= hi:
            mid = (lo + hi) // 2
            a, pick = area_at(cand[mid])
            if a <= area_budget:
                best = pick
                hi = mid - 1
            else:
                lo = mid + 1
        chosen = best

    if chosen is None:
        sel = Selection.smallest(stg)
        for n, (i, nr) in _endpoint_selection(stg).items():
            sel.set(n, i, nr)
        an = analyze(stg, sel)
        return TradeoffResult(sel, sel.impl_area(stg), 0.0, sel.impl_area(stg),
                              an.v_app, used, time.perf_counter() - t0, feasible=False,
                              meta={"area_budget": area_budget})

    sel = Selection(dict(_endpoint_selection(stg)))
    impl_area = overhead = 0.0
    for n in names:
        ch = per_node[n][chosen[n]]
        sel.set(n, ch.impl, ch.nr)
        impl_area += ch.area
        overhead += ch.overhead
    v_app = analyze(stg, sel).v_app
    return TradeoffResult(sel, impl_area, overhead, impl_area + overhead, v_app,
                          used, time.perf_counter() - t0,
                          feasible=impl_area + overhead <= area_budget + 1e-9,
                          meta={"area_budget": area_budget})
