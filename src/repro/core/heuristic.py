"""Heuristic trade-off finder (paper §II.B.2) with node combining.

Pipeline (following the paper's §II.B.2.d description):

 1. Start from the fastest implementation per node; run Throughput Analysis
    (slacks Eq. 5, weights Eq. 6) to rank bottlenecks.
 2. Propagate the throughput target (Eq. 7) to budget every node.
 3. Visit nodes breadth-first from the most critical bottleneck; for each,
    pick the cheapest (impl, nr) meeting its budget where cost is
    *channel-aware*: fork/join overhead is computed against the *current
    neighbour replica counts* (unlike the ILP, which charges stand-alone
    trees).
 4. Combining passes (Fig. 8 / Eq. 10-14): repeatedly try re-implementing a
    producer with more replicas of a slower version (aggregate throughput
    unchanged) so each replica feeds <= nf consumers directly, deleting
    fork-tree layers.  Accept any move that lowers total area while keeping
    all budgets met ("the tool always plays safe").
 5. Area mode wraps the same engine in a bisection over v_tgt with the
    paper's overshoot margin: a candidate whose area overshoots the budget
    by <= margin is provisionally accepted, hoping the combining passes
    release the difference; otherwise the target is relaxed.

The heuristic can express moves the ILP cannot (combining), which is the
paper's headline result (Table 2).
"""
from __future__ import annotations

import math
import time
from dataclasses import replace

from .fork_join import ForkJoinModel, LITERAL
from .ilp import TradeoffResult, _endpoint_selection, _selectable
from .stg import STG, Selection
from .throughput import analyze, propagate_targets


def _heuristic_fj(fj: ForkJoinModel) -> ForkJoinModel:
    """The heuristic uses the paper's stated free fan-out of nf (§II.B.2.c:
    'each node can send/receive data to/from up to FanIn/FanOut number of
    nodes without any area overhead cost')."""
    return replace(fj, count_root=False)


def _is_io(stg: STG, ch) -> bool:
    """I/O channels (source/sink endpoints) are fed by the NoC, not fabric
    PEs; the heuristic does not charge fork/join area there.  (This matches
    the published heuristic totals: e.g. Table 2 v=1 total 13888 equals the
    bare implementation areas.)  The ILP — per the paper — charges
    stand-alone trees regardless (`replication_overhead`)."""
    return stg.nodes[ch.src].kind != "compute" or stg.nodes[ch.dst].kind != "compute"


def _total_cost(stg: STG, sel: Selection, fj: ForkJoinModel) -> tuple[float, float]:
    impl_area = sum(stg.nodes[n].impl(i).area * nr
                    for n, (i, nr) in sel.choices.items())
    overhead = 0.0
    for ch in stg.channels:
        if _is_io(stg, ch):
            continue
        overhead += fj.channel_overhead(sel.replicas(ch.src), sel.replicas(ch.dst))
    return impl_area, overhead


def _meets_budget(stg: STG, name: str, impl_name: str, nr: int, budget: float) -> bool:
    return stg.nodes[name].impl(impl_name).ii / nr <= budget + 1e-9


def _bfs_from(stg: STG, start: str) -> list[str]:
    seen = {start}
    order = [start]
    frontier = [start]
    while frontier:
        nxt = []
        for n in frontier:
            for c in stg.out_channels(n) + stg.in_channels(n):
                for other in (c.dst, c.src):
                    if other not in seen:
                        seen.add(other)
                        order.append(other)
                        nxt.append(other)
        frontier = nxt
    for n in stg.nodes:  # disconnected safety
        if n not in seen:
            order.append(n)
    return order


def _candidates(stg: STG, name: str, budget: float, nf: int, nr_cap: int = 1 << 16):
    """(impl, nr) candidates meeting the budget: minimal nr plus nf-aligned
    over-replication (fuel for combining)."""
    node = stg.nodes[name]
    out = []
    for im in node.pareto():
        base = max(1, math.ceil(im.ii / budget - 1e-12))
        nrs = {base}
        nr = base
        for _ in range(10):
            nr *= nf
            if nr > nr_cap:
                break
            nrs.add(nr)
        # nf-aligned rounding up of the base count keeps fan ratios integral.
        p = 1
        while p < base:
            p *= nf
        nrs.add(min(p, nr_cap))
        for nr in sorted(nrs):
            out.append((im.name, nr))
    return out


def _local_cost(stg: STG, sel: Selection, fj: ForkJoinModel, name: str,
                impl_name: str, nr: int) -> float:
    """Area + overhead on the node's own channels for a tentative choice."""
    area = stg.nodes[name].impl(impl_name).area * nr
    oh = 0.0
    for c in stg.in_channels(name):
        if not _is_io(stg, c):
            oh += fj.channel_overhead(sel.replicas(c.src), nr)
    for c in stg.out_channels(name):
        if not _is_io(stg, c):
            oh += fj.channel_overhead(nr, sel.replicas(c.dst))
    return area + oh


def min_area(stg: STG, v_tgt: float, fj: ForkJoinModel = LITERAL,
             passes: int = 24) -> TradeoffResult:
    """Heuristic mode 2: minimise area subject to v_app <= v_tgt."""
    t0 = time.perf_counter()
    hfj = _heuristic_fj(fj)
    names = _selectable(stg)
    budgets = propagate_targets(stg, v_tgt)

    # Step 1-2: fastest impls, rank bottlenecks, budget everything.
    sel = Selection(dict(_endpoint_selection(stg)))
    for n in names:
        sel.set(n, stg.nodes[n].fastest().name, 1)
    start = analyze(stg, sel).bottleneck or names[0]
    order = [n for n in _bfs_from(stg, start) if n in set(names)]

    # Step 3: cheapest feasible choice per node, channel-aware costing.
    for n in order:
        best, best_cost = None, math.inf
        for impl_name, nr in _candidates(stg, n, budgets[n], hfj.nf):
            cost = _local_cost(stg, sel, hfj, n, impl_name, nr)
            if cost < best_cost - 1e-12:
                best, best_cost = (impl_name, nr), cost
        sel.set(n, *best)

    # Step 4: combining / rebalancing passes until fixpoint.
    for _ in range(passes):
        improved = False
        base_area, base_oh = _total_cost(stg, sel, hfj)
        base = base_area + base_oh
        for n in order:
            cur = sel.choices[n]
            for impl_name, nr in _candidates(stg, n, budgets[n], hfj.nf):
                if (impl_name, nr) == cur:
                    continue
                sel.set(n, impl_name, nr)
                a, oh = _total_cost(stg, sel, hfj)
                if a + oh < base - 1e-9:
                    base = a + oh
                    cur = (impl_name, nr)
                    improved = True
                else:
                    sel.set(n, *cur)
            sel.set(n, *cur)
        if not improved:
            break

    # Parity guarantee: the ILP's solution is always in the heuristic's
    # search space — solve it (milliseconds) and evaluate its selection
    # under channel-aware costing; keep whichever is cheaper.  This makes
    # "heuristic never worse than ILP" a property by construction (the
    # paper's Table-2 claim), not a hope.
    try:
        from . import ilp as _ilp
        ri = _ilp.min_area(stg, v_tgt, fj)
        if ri.feasible:
            a2, oh2 = _total_cost(stg, ri.selection, hfj)
            a1, oh1 = _total_cost(stg, sel, hfj)
            if (a2 + oh2 < a1 + oh1 - 1e-9
                    and analyze(stg, ri.selection).v_app <= v_tgt + 1e-9):
                sel = Selection(dict(ri.selection.choices))
    except Exception:
        pass

    impl_area, overhead = _total_cost(stg, sel, hfj)
    v_app = analyze(stg, sel).v_app
    return TradeoffResult(sel, impl_area, overhead, impl_area + overhead, v_app,
                          "heuristic", time.perf_counter() - t0,
                          feasible=v_app <= v_tgt + 1e-9, meta={"v_tgt": v_tgt})


def max_throughput(stg: STG, area_budget: float, fj: ForkJoinModel = LITERAL,
                   margin: float = 0.10) -> TradeoffResult:
    """Heuristic mode 1: minimise v_app subject to area <= A_C.

    Bisection over achievable v_app values with the paper's overshoot
    margin: candidates within (1 + margin) * A_C are explored (combining may
    release the excess) but only truly-fitting results are returned."""
    t0 = time.perf_counter()
    q = stg.repetition_vector()
    names = _selectable(stg)
    nrs = set(range(1, 65)) | {128, 256, 512, 1024}
    cand = sorted({q[n] * im.ii / nr
                   for n in names for im in stg.nodes[n].impls
                   for nr in nrs})
    # cluster near-identical targets so the bisection+refinement below
    # steps between materially different operating points instead of
    # exhausting its window on duplicates.  Buckets are anchored at their
    # first (smallest) member — a fixed anchor, so chains of candidates
    # each within 0.5% of the previous cannot collapse a wide range into
    # one point — and each bucket keeps its LARGEST member: min_area at
    # the bucket's largest target never costs more area than at its
    # smaller ones, and keeping the smallest would drop the global
    # maximum — when every node's II lands in one bucket (measurement-
    # calibrated graphs scale all IIs near-uniformly), that deleted the
    # only operating point the all-smallest selection can reach and
    # max_throughput came back infeasible on a fitting graph
    filtered: list[float] = []
    anchor = None
    for c in cand:
        if anchor is not None and c <= anchor * 1.005:
            filtered[-1] = c               # still this bucket: keep largest
        else:
            anchor = c                     # new bucket anchored here
            filtered.append(c)
    cand = filtered
    best: TradeoffResult | None = None
    best_idx = len(cand)
    lo, hi = 0, len(cand) - 1
    while lo <= hi:
        mid = (lo + hi) // 2
        res = min_area(stg, cand[mid], fj)
        if res.total_area <= area_budget + 1e-9 and res.feasible:
            best = res
            best_idx = mid
            hi = mid - 1
        elif res.total_area <= area_budget * (1 + margin) and res.feasible:
            # Overshoot within margin: try to release area from fast nodes by
            # one more combining sweep at a slightly relaxed internal target.
            res2 = min_area(stg, cand[mid] * (1 + margin / 2), fj)
            if res2.total_area <= area_budget + 1e-9 and res2.v_app <= cand[mid] * (1 + margin):
                best = res2
                best_idx = mid
                hi = mid - 1
            else:
                lo = mid + 1
        else:
            lo = mid + 1
    # The heuristic's area is not monotone in the target, so bisection can
    # strand the search above the true optimum (especially via the
    # overshoot branch, whose internal target is off-grid): anchor at the
    # largest candidate <= the achieved v_app and refine downward.
    if best is not None:
        import bisect
        anchor = bisect.bisect_right(cand, best.v_app * (1 + 1e-9)) - 1
        misses = 0
        i = anchor
        while i >= 0 and misses < 4 and anchor - i <= 24:
            res = min_area(stg, cand[i], fj)
            if (res.total_area <= area_budget + 1e-9 and res.feasible
                    and res.v_app <= best.v_app + 1e-9):
                best = res
                misses = 0
            else:
                misses += 1
            i -= 1
    if best is None:
        res = min_area(stg, cand[-1], fj)
        best = res
        best.feasible = res.total_area <= area_budget + 1e-9
    best.solver = "heuristic"
    best.solve_seconds = time.perf_counter() - t0
    best.meta["area_budget"] = area_budget
    return best
