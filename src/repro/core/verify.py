"""Static plan verification: prove a plan safe before anything runs.

The executors discover unsafe plans at runtime — `Engine._deadlock_detail`
forensics after a wedge, `Fifo` overflow raises, XLA donation errors after
compilation.  The KPN/STG abstraction makes all of that analyzable *up
front* (TAPA-style HLS and polyhedral process-network channel sizing do
exactly this for hardware task graphs): this module takes the full plan
tuple — (STG, Selection, schedule, fusion plan, placement, channel
capacities) — and returns a structured report of ERROR/WARN findings
without touching a device.

Three check families:

  * **bounded-FIFO deadlock analysis** — channels as credit-carrying
    edges.  A rate-changing edge (consumer pops ``block`` tokens per
    firing, producer pushes ``burst``) is live iff its capacity reaches
    the classic SDF bound ``block + burst - gcd(block, burst)``; an
    unconditional-push edge (the head→embed token feedback stream) must
    absorb its worst-case in-flight burst; every cycle must keep at least
    one free credit; and a schedule's exact op order is *simulated*
    against integer credits (`simulate_credit_schedule`) — exact for
    these graphs because every FIFO has a single producer and a single
    consumer stage, which makes the credit net a marked graph: enabled
    ops stay enabled until they fire, so greedy exploration decides
    deadlock-freedom, and a wedge names the wait-for cycle plus the
    minimum viable capacity that unblocks it.
  * **plan-consistency** — schedule shape vs the built stage product,
    `Schedule.validate()` invariants, fusion groups re-checked against
    `enumerate_fusions`' heavy-set rule / `validate_restructure`, replica
    counts vs placement slices.
  * **donation/aliasing safety** — `jax.eval_shape` only (no device, no
    compile): the decode cache-out==cache-in aval contract
    (`models/lm.decode_cache_structs`) and the generic donated-argument
    aliasing rule (`donation_unmatched_leaves`) XLA would otherwise
    enforce with a runtime error.

Executors call `verify_decode_plan` / `verify_lm_plan` as a ``preflight=``
hook (on by default) and raise `PlanVerificationError` on any ERROR; the
accepted report rides into the engine so a runtime deadlock can be
cross-referenced against the static analysis (`Engine._deadlock_detail`).
`tools/stg_lint.py` runs the same checks over every example graph and
config plan in CI.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

ERROR = "ERROR"
WARN = "WARN"


# ===========================================================================
# findings
# ===========================================================================
@dataclass(frozen=True)
class Finding:
    """One verification finding.  ``check`` is a dotted family name
    (``deadlock.*`` / ``channel.*`` / ``plan.*`` / ``donation.*`` /
    ``graph.*``); ``subject`` names the edge, cycle, stage, or group the
    finding is about; ``min_viable`` is the smallest capacity that fixes
    a sized finding (None when not a sizing issue)."""
    level: str
    check: str
    subject: str
    message: str
    min_viable: int | None = None

    def describe(self) -> str:
        cap = f" (min viable capacity {self.min_viable})" \
            if self.min_viable is not None else ""
        return f"[{self.level}] {self.check} @ {self.subject}: " \
               f"{self.message}{cap}"


class PlanVerificationError(RuntimeError):
    """A preflighted plan violates a static invariant.  ``report`` holds
    the full `VerificationReport`; the message names the first violated
    invariant so the failure reads like the analysis, not like the wedge
    it prevents."""

    def __init__(self, report: "VerificationReport", context: str = ""):
        self.report = report
        self.findings = report.errors()
        head = self.findings[0].describe() if self.findings \
            else "no findings"
        more = f" (+{len(self.findings) - 1} more error(s))" \
            if len(self.findings) > 1 else ""
        where = f"{context}: " if context else ""
        super().__init__(
            f"{where}plan fails static verification — {head}{more}\n"
            + report.render())


@dataclass
class VerificationReport:
    """Structured result of one static analysis pass."""
    plan: str = ""                      # one-line plan-tuple description
    findings: list[Finding] = field(default_factory=list)
    checks: list[str] = field(default_factory=list)   # families that ran

    def add(self, level: str, check: str, subject: str, message: str,
            min_viable: int | None = None) -> None:
        self.findings.append(Finding(level, check, subject, message,
                                     min_viable))

    def ran(self, check: str) -> None:
        if check not in self.checks:
            self.checks.append(check)

    def merge(self, other: "VerificationReport") -> None:
        self.findings.extend(other.findings)
        for c in other.checks:
            self.ran(c)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.level == ERROR]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.level == WARN]

    def ok(self) -> bool:
        return not self.errors()

    def deadlock_findings(self) -> list[Finding]:
        """Findings a runtime wedge could be the dynamic face of — what
        `Engine._deadlock_detail` cross-references."""
        return [f for f in self.findings
                if f.check.startswith(("deadlock.", "channel."))]

    def summary(self) -> dict:
        """Structured form for `Engine.diagnostic_bundle`."""
        return {"plan": self.plan, "checks": list(self.checks),
                "errors": [f.describe() for f in self.errors()],
                "warnings": [f.describe() for f in self.warnings()]}

    def render(self) -> str:
        lines = [f"static verification: {self.plan or 'plan'} — "
                 f"{len(self.errors())} error(s), "
                 f"{len(self.warnings())} warning(s); "
                 f"checks: {', '.join(self.checks) or 'none'}"]
        lines += ["  " + f.describe() for f in self.findings]
        if not self.findings:
            lines.append("  no findings")
        return "\n".join(lines)

    def raise_if_errors(self, context: str = "") -> "VerificationReport":
        if not self.ok():
            raise PlanVerificationError(self, context)
        return self


# ===========================================================================
# credit-carrying edges (the pure analysis layer — no executor imports)
# ===========================================================================
@dataclass(frozen=True)
class EdgeSpec:
    """One channel as a credit-carrying edge.  ``block`` is the tokens
    the consumer pops per firing, ``burst`` the tokens the producer
    pushes per firing.  ``gated`` producers wait for free credits before
    dispatching (the executors' reserve-at-dispatch backpressure);
    ungated producers push unconditionally at retirement (the decode
    head's feedback stream), so their capacity must absorb the
    worst-case in-flight burst outright."""
    src: str
    dst: str
    capacity: int
    label: str = ""
    block: int = 1
    burst: int = 1
    gated: bool = True

    def name(self) -> str:
        return self.label or f"{self.src}->{self.dst}"


def channel_liveness_floor(block: int, burst: int) -> int:
    """Smallest capacity under which a gated producer/consumer pair on
    one bounded edge cannot wedge: the two-actor SDF bound
    ``block + burst - gcd(block, burst)``.  Below it, a rate-changing
    edge deadlocks with the producer short of free credits and the
    consumer short of tokens (e.g. block=3, burst=2, capacity=3: the
    producer parks 2, can't fit its next burst, the consumer never sees
    its 3rd token)."""
    return block + burst - math.gcd(block, burst)


def check_channel_capacities(edges: list[EdgeSpec],
                             report: VerificationReport) -> None:
    """Per-edge capacity analysis (the `channels.Fifo` sizing rules as
    provable requirements, incl. the ``min_capacity`` rate-change
    floors)."""
    report.ran("channel-capacity")
    for e in edges:
        floor = channel_liveness_floor(e.block, e.burst)
        if e.capacity < e.block:
            report.add(
                ERROR, "channel.consumer-starved", e.name(),
                f"capacity {e.capacity} < consumer block {e.block}: the "
                f"consumer can never accumulate one firing's input",
                min_viable=floor)
        elif e.capacity < e.burst:
            if e.gated:
                report.add(
                    ERROR, "channel.producer-blocked", e.name(),
                    f"capacity {e.capacity} < producer burst {e.burst}: "
                    f"the producer can never reserve one firing's output",
                    min_viable=floor)
            else:
                report.add(
                    ERROR, "channel.burst-overflow", e.name(),
                    f"capacity {e.capacity} < unconditional producer "
                    f"burst {e.burst}: the push overflows at runtime",
                    min_viable=e.burst)
        elif e.gated and e.capacity < floor:
            report.add(
                ERROR, "channel.rate-change-deadlock", e.name(),
                f"capacity {e.capacity} is under the rate-change "
                f"liveness floor {e.block}+{e.burst}-"
                f"gcd={floor}: producer (burst {e.burst}) and consumer "
                f"(block {e.block}) wedge with the buffer neither "
                f"drainable nor fillable", min_viable=floor)
        elif e.capacity < e.block + e.burst:
            report.add(
                WARN, "channel.single-buffered", e.name(),
                f"capacity {e.capacity} < block+burst "
                f"{e.block + e.burst}: producer and consumer serialize "
                f"(no double buffering)",
                min_viable=e.block + e.burst)


def _cycles_of(edges: list[EdgeSpec], limit: int = 64) -> list[list[EdgeSpec]]:
    """Enumerate simple cycles in the edge graph (DFS; the graphs here
    are stage chains plus a feedback edge or two, so this stays tiny —
    ``limit`` is a safety valve, not an expected path)."""
    by_src: dict[str, list[EdgeSpec]] = {}
    for e in edges:
        by_src.setdefault(e.src, []).append(e)
    cycles: list[list[EdgeSpec]] = []
    seen: set[tuple] = set()

    def walk(node: str, path: list[EdgeSpec], on_path: dict[str, int]):
        if len(cycles) >= limit:
            return
        for e in by_src.get(node, ()):
            if e.dst in on_path:
                cyc = path[on_path[e.dst]:] + [e]
                key = frozenset(c.name() for c in cyc)
                if key not in seen:
                    seen.add(key)
                    cycles.append(cyc)
            elif len(path) < len(edges):
                walk(e.dst, path + [e], {**on_path, e.dst: len(path) + 1})

    for start in {e.src for e in edges}:
        walk(start, [], {start: 0})
    return cycles


def _cycle_name(cycle: list[EdgeSpec]) -> str:
    hops = [cycle[0].src]
    for e in cycle:
        hops.append(e.dst)
    return " -> ".join(hops)


def check_cycles(edges: list[EdgeSpec], tokens_in_flight: int,
                 report: VerificationReport) -> None:
    """Prove every dependency cycle carries enough initial credits for
    ``tokens_in_flight`` circulating tokens (the decode loop keeps one
    token per live serving group in flight around the
    embed→…→head→feedback cycle).

    Two requirements per cycle: each *ungated* edge must absorb the full
    in-flight complement at once (its producer pushes at retirement
    without a credit check — all live tokens can land on it before the
    consumer drains any), and the ring's total capacity must exceed the
    circulating tokens (a completely full ring has no free credit for
    any producer, and with reserve-at-dispatch semantics no stage can
    dispatch: deadlock)."""
    report.ran("cycle-credits")
    for cycle in _cycles_of(edges):
        cname = _cycle_name(cycle)
        for e in cycle:
            if not e.gated and e.capacity < tokens_in_flight:
                report.add(
                    ERROR, "deadlock.feedback-capacity",
                    f"{e.name()} in cycle [{cname}]",
                    f"unconditional-push edge holds {e.capacity} "
                    f"credit(s) but up to {tokens_in_flight} token(s) "
                    f"(one per live group) can be in flight on it at "
                    f"once — {tokens_in_flight - e.capacity} credit(s) "
                    f"short", min_viable=tokens_in_flight)
        total = sum(e.capacity for e in cycle)
        if total < tokens_in_flight + 1:
            report.add(
                ERROR, "deadlock.cycle-credits", cname,
                f"cycle capacity {total} cannot keep a free credit "
                f"ahead of {tokens_in_flight} circulating token(s): "
                f"once full, no stage on the cycle can dispatch",
                min_viable=tokens_in_flight + 1 - (total - cycle[0].capacity))


# ===========================================================================
# schedule-order credit simulation
# ===========================================================================
@dataclass(frozen=True)
class SimOp:
    """One scheduled op in credit terms: which edges it pops from and
    pushes to (edge index, token count)."""
    label: str
    pops: tuple = ()
    pushes: tuple = ()


@dataclass
class Wedge:
    """A credit simulation that stopped making progress: the per-stage
    positions, why each stuck stage is blocked, the wait-for cycle, and
    the minimum viable capacities that let the same op order complete."""
    positions: list[int]
    blockers: list[tuple]       # (stage, op label, reason, edge index)
    cycle: list[str]            # wait-for cycle through stages/edges
    min_viable: dict[int, int]  # edge index -> capacity that unblocks

    def describe(self, edge_names: list[str]) -> str:
        why = "; ".join(
            f"stage{s} at {lbl}: {reason} on {edge_names[ei]}"
            for s, lbl, reason, ei in self.blockers)
        fix = ", ".join(f"{edge_names[ei]}>={cap}"
                        for ei, cap in sorted(self.min_viable.items()))
        cyc = f" wait-for cycle: {' -> '.join(self.cycle)};" \
            if self.cycle else ""
        return f"{why};{cyc} minimum viable: {fix or 'n/a'}"


def simulate_credit_schedule(op_streams: list[list[SimOp]],
                             capacities: list[int]) -> Wedge | None:
    """Run the schedule's exact op order against integer channel credits.

    Exact, not heuristic: every edge has one producer stage and one
    consumer stage, so token counts only grow until the consumer itself
    pops and credits only shrink when the producer itself fires — an
    enabled op stays enabled until it fires (marked-graph persistence),
    which makes greedy exploration order-independent.  ``None`` means
    the schedule provably runs to completion under these capacities;
    a `Wedge` is a proven deadlock for this op order."""
    wedge = _simulate(op_streams, capacities)
    if wedge is None:
        return None
    wedge.min_viable = _min_viable(op_streams, capacities, wedge)
    return wedge


def _simulate(op_streams, capacities) -> Wedge | None:
    counts = [0] * len(capacities)
    pos = [0] * len(op_streams)
    remaining = sum(len(s) for s in op_streams)
    while remaining:
        progressed = False
        for s, stream in enumerate(op_streams):
            while pos[s] < len(stream):
                op = stream[pos[s]]
                if any(counts[ei] < n for ei, n in op.pops) or any(
                        capacities[ei] - counts[ei] < n
                        for ei, n in op.pushes):
                    break
                for ei, n in op.pops:
                    counts[ei] -= n
                for ei, n in op.pushes:
                    counts[ei] += n
                pos[s] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            return _wedge_info(op_streams, capacities, counts, pos)
    return None


def _wedge_info(op_streams, capacities, counts, pos) -> Wedge:
    blockers = []
    waits: dict[int, tuple[str, int]] = {}    # stage -> (reason, edge)
    producer_of: dict[int, int] = {}
    consumer_of: dict[int, int] = {}
    for s, stream in enumerate(op_streams):
        for op in stream:
            for ei, _ in op.pushes:
                producer_of[ei] = s
            for ei, _ in op.pops:
                consumer_of[ei] = s
    for s, stream in enumerate(op_streams):
        if pos[s] >= len(stream):
            continue
        op = stream[pos[s]]
        for ei, n in op.pops:
            if counts[ei] < n:
                blockers.append((s, op.label, "starved", ei))
                waits.setdefault(s, ("starved", ei))
        for ei, n in op.pushes:
            if capacities[ei] - counts[ei] < n:
                blockers.append((s, op.label, "no credits", ei))
                waits.setdefault(s, ("no credits", ei))
    # wait-for cycle: stage -> blocking edge -> the stage that could
    # unblock it (the producer of a starved edge, the consumer of a
    # full one); a cycle in that graph is the deadlock's shape
    cycle: list[str] = []
    if waits:
        start = min(waits)
        seen: dict[int, int] = {}
        chain: list[tuple[int, str, int]] = []
        s = start
        while s in waits and s not in seen:
            seen[s] = len(chain)
            reason, ei = waits[s]
            chain.append((s, reason, ei))
            s = producer_of.get(ei, s) if reason == "starved" \
                else consumer_of.get(ei, s)
        if s in seen:
            for st, reason, ei in chain[seen[s]:]:
                cycle.append(f"stage{st}")
                cycle.append(f"edge{ei}({reason})")
            cycle.append(f"stage{s}")
    return Wedge(positions=list(pos), blockers=blockers, cycle=cycle,
                 min_viable={})


def _min_viable(op_streams, capacities, wedge: Wedge,
                max_bumps: int = 256) -> dict[int, int]:
    caps = list(capacities)
    w = wedge
    for _ in range(max_bumps):
        full = [ei for _s, _l, reason, ei in w.blockers
                if reason == "no credits"]
        if not full:
            break
        for ei in full:
            caps[ei] += 1
        w = _simulate(op_streams, caps)
        if w is None:
            break
    return {ei: caps[ei] for ei in range(len(caps))
            if caps[ei] != capacities[ei]}


def schedule_sim_ops(schedule) -> tuple[list[list[SimOp]], list[str]]:
    """Lower a `runtime.pipeline.schedule.Schedule` to credit-sim op
    streams over its act/grd edges (the same edge layout
    `jax_pipe.LMPipeline.run` builds: ``act[i]`` between model stages i
    and i+1 forward, ``grd[i]`` backward)."""
    M = schedule.n_model_stages
    n_act = max(0, M - 1)
    edge_names = [f"act{i}" for i in range(n_act)]
    if schedule.trains:
        edge_names += [f"grd{i}" for i in range(n_act)]

    def act(i):
        return i

    def grd(i):
        return n_act + i

    streams: list[list[SimOp]] = []
    for s, ops in enumerate(schedule.stage_ops):
        stream = []
        for op in ops:
            ms = schedule.model_stage(s, op.chunk)
            if op.kind == "F":
                pops = ((act(ms - 1), 1),) if ms > 0 else ()
                pushes = ((act(ms), 1),) if ms < M - 1 else ()
            else:
                pops = ((grd(ms), 1),) if ms < M - 1 else ()
                pushes = ((grd(ms - 1), 1),) if ms > 0 else ()
            stream.append(SimOp(
                label=f"{op.kind}(mb={op.mb},chunk={op.chunk})",
                pops=pops, pushes=pushes))
        streams.append(stream)
    return streams, edge_names


def verify_schedule_credits(schedule, act_capacities, grd_capacities,
                            report: VerificationReport) -> None:
    """Prove the schedule's op order completes under the given per-edge
    FIFO capacities (ERROR with the wait-for cycle and minimum viable
    capacities otherwise)."""
    report.ran("schedule-credits")
    streams, edge_names = schedule_sim_ops(schedule)
    caps = list(act_capacities)
    if schedule.trains:
        caps += list(grd_capacities)
    if len(caps) != len(edge_names):
        report.add(ERROR, "plan.edge-count", schedule.name,
                   f"{len(caps)} capacities for {len(edge_names)} edges")
        return
    wedge = simulate_credit_schedule(streams, caps)
    if wedge is not None:
        report.add(
            ERROR, "deadlock.schedule-credits", schedule.name,
            f"op order wedges under the planned FIFO capacities — "
            f"{wedge.describe(edge_names)}",
            min_viable=min(wedge.min_viable.values())
            if wedge.min_viable else None)


def verify_schedule_consistency(schedule, *, n_stages_built: int,
                                n_micro: int, train: bool,
                                report: VerificationReport) -> None:
    """The shape/coverage contract `LMPipeline._resolve_schedule`
    enforces at run time, as static findings."""
    report.ran("schedule-consistency")
    if schedule.n_model_stages != n_stages_built:
        report.add(ERROR, "plan.schedule-shape", schedule.name,
                   f"covers {schedule.n_stages} x {schedule.n_chunks} = "
                   f"{schedule.n_model_stages} model stages; the pipeline "
                   f"built {n_stages_built}")
    if schedule.n_micro != n_micro:
        report.add(ERROR, "plan.schedule-micro", schedule.name,
                   f"schedules {schedule.n_micro} microbatches; the run "
                   f"has {n_micro}")
    if schedule.trains != train:
        what = "has no backward ops" if train else "schedules backward"
        report.add(ERROR, "plan.schedule-train", schedule.name,
                   f"{what} — mismatched with train={train}")
    try:
        schedule.validate()
    except ValueError as e:
        report.add(ERROR, "plan.schedule-invalid", schedule.name, str(e))


# ===========================================================================
# fusion legality
# ===========================================================================
def verify_fusion(names, groups, *, heavy=(),
                  report: VerificationReport) -> None:
    """Re-validate a fusion plan against the structural rules
    `core.restructure.enumerate_fusions` generates under: a contiguous
    partition of the stage chain with at most one *heavy* (state-owning)
    member per group — fusing two heavy stages would relocate resident
    pipeline state, which is the planner's ``periods_per_stage`` axis,
    not stage combining."""
    report.ran("fusion-legality")
    heavy = set(heavy)
    groups = [tuple(g) if not isinstance(g, str) else (g,) for g in groups]
    flat = [n for g in groups for n in g]
    if flat != list(names):
        report.add(ERROR, "plan.fusion-partition",
                   "+".join("|".join(g) for g in groups) or "<empty>",
                   f"not a contiguous partition of the stage chain "
                   f"{list(names)}")
        return
    for g in groups:
        heavies = [n for n in g if n in heavy]
        if len(heavies) > 1:
            report.add(
                ERROR, "plan.fusion-heavy", "+".join(g),
                f"groups {len(heavies)} state-owning stages {heavies}: "
                f"`enumerate_fusions` excludes multi-heavy groups (that "
                f"axis is periods_per_stage, not combining)")


def verify_graph_fusion(stg, sel, groups,
                        report: VerificationReport) -> None:
    """Graph-level fusion check: actually apply `restructure.combine` to
    each multi-member group and run `validate_restructure` — the rewrite
    either round-trips or the combine/validate error becomes a
    finding."""
    from . import restructure
    report.ran("fusion-restructure")
    for g in groups:
        g = (g,) if isinstance(g, str) else tuple(g)
        if len(g) < 2:
            continue
        try:
            rg = restructure.combine(stg, sel, list(g))
            fused = next(iter(rg.groups))
            restructure.validate_restructure(stg, rg,
                                             touched=set(g) | {fused})
        except (ValueError, KeyError) as e:
            report.add(ERROR, "plan.fusion-illegal", "+".join(g), str(e))


# ===========================================================================
# donation / aliasing safety
# ===========================================================================
def donation_unmatched_leaves(fn, donate_argnums, *avals) -> list[str]:
    """XLA's donation rule, checked by `jax.eval_shape` instead of a
    runtime error: every leaf of a donated argument must be consumed by
    an output leaf of identical shape+dtype, or the donation silently
    falls back to a copy (and a FIFO-crossing donation becomes a
    use-after-free).  Returns the paths of donated leaves with no
    matching output aval (empty = aliasing-safe)."""
    import jax
    from jax import tree_util
    out = jax.eval_shape(fn, *avals)
    pool: dict[tuple, int] = {}
    for leaf in tree_util.tree_leaves(out):
        key = (tuple(leaf.shape), str(leaf.dtype))
        pool[key] = pool.get(key, 0) + 1
    bad: list[str] = []
    for argnum in donate_argnums:
        leaves = tree_util.tree_leaves_with_path(avals[argnum])
        for path, leaf in leaves:
            key = (tuple(leaf.shape), str(leaf.dtype))
            if pool.get(key, 0) > 0:
                pool[key] -= 1
            else:
                bad.append(f"arg{argnum}{tree_util.keystr(path)}: "
                           f"{key[1]}{list(leaf.shape)}")
    return bad


def verify_decode_cache_contract(cfg, stacked_params, *, batch: int,
                                 prompt: int, cap: int, stage: str,
                                 report: VerificationReport) -> None:
    """The cache-out == cache-in aval contract
    (`models/lm.decode_cache_structs`): a block stage donates its
    incoming cache slice every token step, which aliases only if the
    returned cache matches leaf for leaf (structure, shape, dtype)."""
    from jax import tree_util

    from ..models import lm
    report.ran("donation-cache-contract")
    cin, cout = lm.decode_cache_structs(cfg, stacked_params, batch,
                                        prompt, cap)
    tin = tree_util.tree_structure(cin)
    tout = tree_util.tree_structure(cout)
    if tin != tout:
        report.add(ERROR, "donation.cache-aval", stage,
                   f"cache-out tree structure {tout} != cache-in {tin}: "
                   f"the donated decode step cannot alias")
        return
    for (path, a), (_, b) in zip(tree_util.tree_leaves_with_path(cin),
                                 tree_util.tree_leaves_with_path(cout)):
        if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
            report.add(
                ERROR, "donation.cache-aval",
                f"{stage}{tree_util.keystr(path)}",
                f"cache-in {a.dtype}{list(a.shape)} != cache-out "
                f"{b.dtype}{list(b.shape)}: donation falls back to "
                f"allocating this leaf every token")


# ===========================================================================
# placement / selection consistency
# ===========================================================================
def verify_placement(stg, sel, placement,
                     report: VerificationReport) -> None:
    """Replica counts vs placement slices: every graph node's planned
    replica count must be materialised as that many placement slices,
    tp>1 slices should own distinct devices (else the sub-mesh is
    invalid and the executor silently falls back), and oversubscription
    is surfaced."""
    report.ran("placement-consistency")
    for name in stg.topo_order():
        nr = sel.replicas(name)
        slices = placement.replicas_of(name)
        if nr < 1:
            report.add(ERROR, "plan.replicas", name,
                       f"selection asks for {nr} replicas")
        if len(slices) != nr:
            report.add(ERROR, "plan.replica-placement", name,
                       f"plan promises {nr} replica(s) but the placement "
                       f"carries {len(slices)} slice(s)")
        for sl in slices:
            if sl.tp > 1 and not sl.distinct:
                report.add(WARN, "plan.folded-slice",
                           f"{name}@r{sl.replica}",
                           f"tp{sl.tp} slice folds onto repeated devices "
                           f"{list(sl.devices)}: no sub-mesh, executor "
                           f"falls back to single-device placement")
    if placement.oversubscription > 1.0:
        report.add(WARN, "plan.oversubscribed", "placement",
                   f"plan wants {placement.demand} chip(s) on "
                   f"{placement.n_devices} device(s) "
                   f"(x{placement.oversubscription:.1f} time-shared)")


# ===========================================================================
# plan-level entry points
# ===========================================================================
def verify_graph(stg, sel=None, *, capacity_blocks: int = 2,
                 fusion_groups=None) -> VerificationReport:
    """Static analysis of a bare (STG, Selection) pair: graph structural
    validity, rate consistency, per-channel capacity under the
    `ChannelSet.for_graph` sizing, selection coverage, and (optionally)
    graph-level fusion legality."""
    report = VerificationReport(
        plan=f"graph<{len(stg.nodes)} nodes, {len(stg.channels)} "
             f"channels> @ capacity_blocks={capacity_blocks}")
    report.ran("graph-structure")
    try:
        stg.validate()
        stg.topo_order()
        q = stg.repetition_vector()
    except (ValueError, KeyError) as e:
        report.add(ERROR, "graph.invalid", "stg", str(e))
        return report
    if sel is not None:
        report.ran("selection-coverage")
        for name in stg.topo_order():
            try:
                sel.impl_of(stg, name)
            except (KeyError, ValueError) as e:
                report.add(ERROR, "plan.selection", name, str(e))
                continue
            if sel.replicas(name) < 1:
                report.add(ERROR, "plan.replicas", name,
                           f"{sel.replicas(name)} replicas")
    # channel capacities under the executor's actual sizing rule — build
    # the real ChannelSet so the analysis can never drift from the code
    from ..runtime.pipeline.channels import ChannelSet
    cs = ChannelSet.for_graph(stg, capacity_blocks=capacity_blocks)
    edges = []
    for ch in stg.channels:
        block = max(1, stg.nodes[ch.dst].in_rates[ch.dst_port])
        burst = max(1, stg.nodes[ch.src].out_rates[ch.src_port])
        edges.append(EdgeSpec(
            src=ch.src, dst=ch.dst, capacity=cs[ch.key()].capacity,
            label=f"{ch.src}->{ch.dst}", block=block, burst=burst))
    check_channel_capacities(edges, report)
    del q
    if fusion_groups and sel is not None:
        verify_graph_fusion(stg, sel, fusion_groups, report)
    return report


def verify_decode_plan(pipe, *, n_groups: int, capacity_blocks: int = 2,
                       feedback_capacity: int | None = None,
                       group_shapes=(), check_donation: bool = True
                       ) -> VerificationReport:
    """Static analysis of a `DecodePipeline` serve: the act-chain +
    head→embed feedback cycle's credits (fusion-deleted internal hops
    are already gone from ``stage_names``), fusion legality against the
    heavy-set rule, replica counts vs placement slices, and the
    cache-donation aval contract for every (batch, bucket, cap) group
    shape this serve will run.  Device-free: FIFO construction and
    `jax.eval_shape` only."""
    from ..models import lm
    names = list(pipe.stage_names)
    S = len(names)
    fb_cap = feedback_capacity if feedback_capacity is not None \
        else max(2, n_groups)
    report = VerificationReport(
        plan=f"decode plan: {S} stage(s) [{' -> '.join(names)}], "
             f"{n_groups} group(s), feedback capacity {fb_cap}")
    edges = [EdgeSpec(src=names[s], dst=names[s + 1],
                      capacity=pipe._edge_fifo(
                          s, capacity_blocks, False).capacity,
                      label=f"act{s}")
             for s in range(S - 1)]
    # the continuous token stream: pushed unconditionally at head
    # retirement (`_ServeRun.on_head`), popped by embed decode dispatch
    edges.append(EdgeSpec(src=names[-1], dst=names[0], capacity=fb_cap,
                          label="feedback", gated=False))
    check_channel_capacities(edges, report)
    check_cycles(edges, n_groups, report)
    if pipe.fusion_plan:
        base = [m for g in pipe.fusion_plan for m in g]
        heavy = [m for m in base if m.startswith("blocks")]
        verify_fusion(base, pipe.fusion_plan, heavy=heavy, report=report)
    stg = getattr(pipe, "stg", None)
    sel = getattr(pipe, "sel", None)
    if stg is not None and sel is not None:
        verify_placement(stg, sel, pipe.placement, report)
    if check_donation:
        spans = sorted({desc.span for desc in pipe.stage_descs
                        if desc.span is not None})
        by_desc = {desc.span: desc.name for desc in pipe.stage_descs}
        for span in spans:
            stacked = lm.slice_periods(pipe._init_params["layers"], *span)
            for (batch, bucket, cap) in sorted(set(group_shapes)):
                verify_decode_cache_contract(
                    pipe.cfg, stacked, batch=batch, prompt=bucket,
                    cap=cap, stage=f"{by_desc[span]}[{batch}x{bucket}"
                                   f"->{cap}]", report=report)
    return report


def verify_lm_plan(pipe, *, schedule, n_micro: int, train: bool,
                   act_capacities=None, grd_capacities=None,
                   deep: bool = False) -> VerificationReport:
    """Static analysis of an `LMPipeline.run`: schedule consistency +
    `validate()` invariants, the op order simulated against the act/grd
    FIFO credits, replica/placement consistency, and (``deep=True``)
    the donated-accumulate aliasing contract via `jax.eval_shape`."""
    report = VerificationReport(
        plan=f"lm plan: {pipe.n_stages} stage(s), schedule "
             f"{schedule.name}, {n_micro} microbatch(es), train={train}")
    verify_schedule_consistency(schedule, n_stages_built=pipe.n_stages,
                                n_micro=n_micro, train=train,
                                report=report)
    if not report.ok():
        return report          # shape mismatch: the credit sim's edge
    #                            layout would be meaningless
    M = pipe.n_stages
    if act_capacities is None:
        act_capacities = [pipe._edge_fifo(pipe.stages[i],
                                          pipe.stages[i + 1],
                                          False).capacity
                          for i in range(M - 1)]
    if grd_capacities is None:
        grd_capacities = [pipe._edge_fifo(pipe.stages[i + 1],
                                          pipe.stages[i], False).capacity
                          for i in range(M - 1)] if train else []
    verify_schedule_credits(schedule, act_capacities, grd_capacities,
                            report)
    stg = getattr(pipe, "stg", None)
    sel = getattr(pipe, "sel", None)
    if stg is not None and sel is not None:
        verify_placement(stg, sel, pipe.placement, report)
    if deep and train:
        import jax
        from jax import tree_util

        report.ran("donation-accumulate")
        for st in pipe.stages:
            g = tree_util.tree_map(
                lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype),
                st.params[0])
            bad = donation_unmatched_leaves(
                lambda a, b: jax.tree.map(lambda x, y: x + y, a, b),
                (0,), g, g)
            if bad:
                report.add(
                    ERROR, "donation.accumulate-aval", st.name,
                    f"donated grad accumulator leaves with no matching "
                    f"output aval: {bad[:3]}")
    return report


__all__ = [
    "ERROR", "WARN", "Finding", "PlanVerificationError",
    "VerificationReport", "EdgeSpec", "SimOp", "Wedge",
    "channel_liveness_floor", "check_channel_capacities", "check_cycles",
    "simulate_credit_schedule", "schedule_sim_ops",
    "verify_schedule_credits", "verify_schedule_consistency",
    "verify_fusion", "verify_graph_fusion", "donation_unmatched_leaves",
    "verify_decode_cache_contract", "verify_placement", "verify_graph",
    "verify_decode_plan", "verify_lm_plan",
]
