"""Deterministic, shardable, checkpointable synthetic data pipeline.

Design requirements at pod scale:
  * **Stateless indexing** — batch ``i`` is a pure function of ``(seed, i)``
    (threefry-split keys), so any host can materialise any shard of any
    batch without coordination; restart = "set the step counter".
  * **Host sharding** — each process generates only its
    ``(host_id, n_hosts)`` slice of the global batch; the trainer then
    device_puts the slice against the global sharding (jax
    ``make_array_from_process_local_data`` pattern).  In this container
    there is one process, but the API is multi-host shaped.
  * **Checkpointable** — ``DataState`` is a tiny pytree (step counter +
    seed) stored inside every checkpoint; no file offsets to replay.
  * **Learnable structure** — ``SyntheticBigramLM`` draws tokens from a
    fixed random bigram transition table (peaked, low-entropy rows), so a
    model trained on it shows a real loss decrease (used by the
    quickstart/train examples and convergence tests).  ``SyntheticUniformLM``
    is i.i.d. uniform (for pure-throughput benches).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataState:
    """Checkpointable pipeline position."""
    step: int
    seed: int

    def advance(self, n: int = 1) -> "DataState":
        return dataclasses.replace(self, step=self.step + n)

    def to_dict(self) -> dict:
        return {"step": int(self.step), "seed": int(self.seed)}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(step=int(d["step"]), seed=int(d["seed"]))


class _Base:
    """Common machinery: per-(step, host) keys and batch assembly."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, accum: int = 1):
        assert global_batch % max(accum, 1) == 0
        self.vocab = int(vocab)
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)
        self.accum = int(max(accum, 1))
        self.seed = int(seed)

    def init_state(self) -> DataState:
        return DataState(step=0, seed=self.seed)

    def _key(self, state: DataState, host_id: int) -> jax.Array:
        k = jax.random.PRNGKey(state.seed)
        k = jax.random.fold_in(k, state.step)
        return jax.random.fold_in(k, host_id)

    def _sample(self, key, batch: int):  # -> (batch, seq_len+1) int32
        raise NotImplementedError

    def host_batch(self, state: DataState, host_id: int = 0,
                   n_hosts: int = 1) -> dict:
        """This host's slice of global batch ``state.step``.

        Returns {tokens, labels} with leading dims (accum, local_batch)
        (accum is always present — the train step scans over it); labels
        are next-token targets.
        """
        assert self.global_batch % n_hosts == 0
        local = self.global_batch // n_hosts
        toks = self._sample(self._key(state, host_id), local)
        tokens, labels = toks[:, :-1], toks[:, 1:]
        assert local % self.accum == 0
        mb = local // self.accum
        tokens = tokens.reshape(self.accum, mb, self.seq_len)
        labels = labels.reshape(self.accum, mb, self.seq_len)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        state = self.init_state()
        while True:
            yield self.host_batch(state), state
            state = state.advance()


class SyntheticUniformLM(_Base):
    """i.i.d. uniform tokens (throughput benches; nothing to learn)."""

    def _sample(self, key, batch: int):
        return jax.random.randint(key, (batch, self.seq_len + 1), 0,
                                  self.vocab, dtype=jnp.int32)


class SyntheticBigramLM(_Base):
    """Tokens from a fixed random bigram chain (learnable structure).

    Transition table: for each token, ``branch`` successors get probability
    mass ~1/branch, all drawn from a seed-fixed table.  The optimal LM loss
    is ~log(branch) nats; a 100M model reaches it within a few hundred
    steps, giving the train example a visible convergence signal.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, accum: int = 1, branch: int = 4):
        super().__init__(vocab, seq_len, global_batch, seed, accum)
        self.branch = int(branch)
        tkey = jax.random.PRNGKey(seed ^ 0x5EED)
        # successor table: (vocab, branch) int32, fixed for the run
        self._succ = jax.random.randint(tkey, (self.vocab, self.branch), 0,
                                        self.vocab, dtype=jnp.int32)

    @partial(jax.jit, static_argnums=(0, 2))
    def _sample(self, key, batch: int):
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, self.vocab, jnp.int32)
        choices = jax.random.randint(k1, (batch, self.seq_len), 0,
                                     self.branch, jnp.int32)

        def step(tok, choice):
            nxt = self._succ[tok, choice]
            return nxt, nxt

        _, rest = jax.lax.scan(step, first, choices.T)
        return jnp.concatenate([first[None], rest], axis=0).T

    def optimal_loss(self) -> float:
        """Entropy of the chain ≈ log(branch) (ignoring collisions)."""
        return float(np.log(self.branch))


def make_pipeline(kind: str, cfg, shape, *, seed: int = 0,
                  accum: int | None = None):
    """Pipeline for a (ModelConfig, ShapeCfg) cell."""
    cls = {"bigram": SyntheticBigramLM, "uniform": SyntheticUniformLM}[kind]
    return cls(vocab=cfg.vocab, seq_len=shape.seq_len,
               global_batch=shape.global_batch, seed=seed,
               accum=accum if accum is not None else cfg.grad_accum)
