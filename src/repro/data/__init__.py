from .pipeline import (DataState, SyntheticBigramLM, SyntheticUniformLM,
                       make_pipeline)

__all__ = ["DataState", "SyntheticBigramLM", "SyntheticUniformLM",
           "make_pipeline"]
