"""KPN simulator: functional equivalence + timed throughput validation."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import heuristic
from repro.core.fork_join import LITERAL, ForkJoinModel
from repro.core.simulate import run, run_functional
from repro.core.stg import STG, Impl, Node, Selection, unit_rate_node
from repro.core.throughput import analyze
from repro.core.transform import materialize
from repro.graphs import jpeg, nbody, streamit


def _id_chain(iis):
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    prev = "src"
    for k, ii in enumerate(iis):
        def mk(k):
            def fn(inputs, state):
                return [[("n%d" % k, t) if False else inputs[0][0] + 1]], state
            return fn
        g.add_node(unit_rate_node(f"n{k}", [Impl("v1", 1, ii)], fn=mk(k)))
        g.connect(prev, f"n{k}")
        prev = f"n{k}"
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect(prev, "out")
    g.validate()
    return g


def test_functional_chain():
    g = _id_chain([1, 1, 1])
    outs = run_functional(g, Selection.fastest(g), {"src": list(range(10))})
    assert outs["out"] == [x + 3 for x in range(10)]


def test_timed_throughput_matches_analysis():
    g = _id_chain([2, 7, 3])
    sel = Selection.fastest(g)
    res = run(g, sel, {"src": list(range(200))})
    sim_v = res.inverse_throughput("out")
    ana_v = analyze(g, sel).v_app
    assert math.isclose(sim_v, ana_v, rel_tol=0.05)


def test_timed_throughput_with_replication():
    g = _id_chain([1, 8, 1])
    sel = Selection.fastest(g).set("n1", "v1", 8)
    rep = materialize(g, sel, LITERAL)
    res = run(rep.stg, rep.selection, {"src": list(range(400))})
    # replicated middle node no longer the bottleneck: v ~ fork/join ii = 1
    assert res.inverse_throughput("out") < 8 * 0.5


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 8), min_size=1, max_size=4),
       st.sampled_from([1, 2, 4, 8, 16]),
       st.sampled_from([2, 3, 4]))
def test_replication_preserves_streams(iis, nr, nf):
    """Property: materialised graphs are stream-equivalent to the original
    (KPN determinism through fork/join round-robin trees)."""
    g = _id_chain(iis)
    sel = Selection.fastest(g)
    mid = f"n{len(iis)//2}"
    sel.set(mid, "v1", nr)
    rep = materialize(g, sel, ForkJoinModel(nf=nf))
    inputs = {"src": list(range(64))}
    want = run_functional(g, Selection.fastest(g), inputs)["out"]
    got = run_functional(rep.stg, rep.selection, inputs)["out"]
    assert got == want


def test_double_replication_preserves_streams():
    g = _id_chain([4, 8])
    sel = Selection.fastest(g).set("n0", "v1", 4).set("n1", "v1", 8)
    rep = materialize(g, sel, ForkJoinModel(nf=2))
    inputs = {"src": list(range(96))}
    want = run_functional(g, Selection.fastest(g), inputs)["out"]
    assert run_functional(rep.stg, rep.selection, inputs)["out"] == want


def test_join_then_fork_alignment():
    g = _id_chain([8, 2, 8])
    sel = Selection.fastest(g).set("n0", "v1", 8).set("n1", "v1", 2).set("n2", "v1", 8)
    rep = materialize(g, sel, ForkJoinModel(nf=4))
    inputs = {"src": list(range(128))}
    want = run_functional(g, Selection.fastest(g), inputs)["out"]
    assert run_functional(rep.stg, rep.selection, inputs)["out"] == want


# --- application graphs -----------------------------------------------------
def test_jpeg_functional_reference():
    g = jpeg.build_stg()
    blocks = jpeg.random_blocks(12)
    outs = run_functional(g, Selection.fastest(g), {"camera": blocks})
    assert outs["bitstream"] == jpeg.reference_pipeline(blocks)


@pytest.mark.parametrize("v", [1, 4])
def test_jpeg_heuristic_solution_is_stream_equivalent(v):
    from repro.core.fork_join import JPEG_CALIBRATED
    g = jpeg.build_stg()
    res = heuristic.min_area(g, v, JPEG_CALIBRATED)
    rep = materialize(g, res.selection, JPEG_CALIBRATED)
    blocks = jpeg.random_blocks(48)
    want = jpeg.reference_pipeline(blocks)
    got = run_functional(rep.stg, rep.selection, {"camera": blocks})["bitstream"]
    assert got == want


def test_nbody_functional():
    g = nbody.build_stg()
    pairs = nbody.random_pairs(16)
    outs = run_functional(g, Selection.fastest(g), {"pairs": pairs})
    for got, pair in zip(outs["acc"], pairs):
        want = nbody.force_fn(pair)
        np.testing.assert_allclose(got, want, rtol=1e-12)


def test_nbody_replicated_33x_reaches_ii1():
    g = nbody.build_stg()
    slowest = max(g.nodes["force"].impls, key=lambda im: im.ii)
    assert slowest.ii == 33
    sel = Selection.fastest(g).set("force", slowest.name, 33)
    a = analyze(g, sel)
    assert a.node_iter_time["force"] == 1.0  # 33/33


def test_streamit_fft():
    g = streamit.build_fft(8)
    rng = np.random.default_rng(3)
    blocks = [rng.normal(size=8) + 1j * rng.normal(size=8) for _ in range(6)]
    outs = run_functional(g, Selection.fastest(g), {"src": blocks})
    for got, want in zip(outs["out"], streamit.fft_reference(blocks)):
        np.testing.assert_allclose(got, want, atol=1e-9)


def test_streamit_filterbank():
    g = streamit.build_filterbank()
    rng = np.random.default_rng(4)
    blocks = [rng.normal(size=32) for _ in range(5)]
    outs = run_functional(g, Selection.fastest(g), {"src": blocks})
    for got, want in zip(outs["out"], streamit.filterbank_reference(g, blocks)):
        np.testing.assert_allclose(got, want, atol=1e-9)


def test_streamit_autocor():
    g = streamit.build_autocor()
    rng = np.random.default_rng(5)
    blocks = [rng.normal(size=16) for _ in range(5)]
    outs = run_functional(g, Selection.fastest(g), {"src": blocks})
    for got, want in zip(outs["out"], streamit.autocor_reference(blocks)):
        np.testing.assert_allclose(got, want, atol=1e-9)


def test_streamit_implementation_libraries_nontrivial():
    """Front-end validation (§III.A): every StreamIt node gets a multi-point
    implementation frontier."""
    for g in (streamit.build_fft(8), streamit.build_filterbank(), streamit.build_autocor()):
        rich = [n for n, node in g.nodes.items()
                if node.kind == "compute" and len(node.impls) >= 3]
        assert rich, f"no multi-implementation nodes in {g.nodes.keys()}"
