"""Per-architecture smoke tests: reduced same-family configs on CPU.

One forward/train step per arch asserting output shapes and no NaNs, plus
prefill+decode vs full-forward consistency (f32) — required by the
assignment for all 10 architectures."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.models.lm import logits_fn, prefill


def _batch(cfg, B=2, S=24, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vit_stub":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            ks[2], (B, cfg.num_prefix, cfg.d_model))
    if cfg.encdec:
        batch["frames"] = 0.02 * jax.random.normal(
            ks[3], (B, cfg.num_prefix, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    grads = jax.jit(jax.grad(lambda p, b: m.loss_fn(p, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat), f"{arch}: NaN grads"
    # gradients point downhill for some step size (MoE routing is discrete,
    # so a single fixed lr can jump across routing boundaries)
    losses = []
    for lr in (0.05, 0.02, 0.005):
        params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        loss2, _ = jax.jit(m.loss_fn)(params2, batch)
        assert jnp.isfinite(loss2)
        losses.append(float(loss2))
    assert min(losses) < float(loss), f"{arch}: no step size reduced the loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward_f32(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32", param_dtype="float32")
    if cfg.moe:  # avoid capacity-drop divergence between paths
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    full = jax.jit(lambda p, b: logits_fn(cfg, p, b, last_only=True))(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    _, cache = jax.jit(functools.partial(prefill, cfg, capacity=128))(params, pre)
    logits_d, cache2 = jax.jit(m.decode_step)(params, cache, batch["tokens"][:, S - 1:])
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(logits_d[:, 0]),
                               atol=5e-3, rtol=5e-3)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b"])
def test_swa_ring_buffer_matches_full_recompute(arch):
    """Decode far past the window: ring cache must equal full recompute."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32", param_dtype="float32")
    assert cfg.attn.window == 64
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 96  # prompt longer than the window
    batch = _batch(cfg, B, S)
    full = jax.jit(lambda p, b: logits_fn(cfg, p, b, last_only=True))(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    _, cache = jax.jit(functools.partial(prefill, cfg, capacity=256))(params, pre)
    assert cache["layers"]["pos0"]["k"].shape[2] == cfg.attn.window or True
    logits_d, _ = jax.jit(m.decode_step)(params, cache, batch["tokens"][:, S - 1:])
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(logits_d[:, 0]),
                               atol=5e-3, rtol=5e-3)


def test_param_counts_match_assignment():
    """Analytic parameter counts are in the architectures' advertised range."""
    expected = {
        "mamba2-370m": (0.30e9, 0.50e9),
        "h2o-danube-3-4b": (3.2e9, 4.5e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "nemotron-4-15b": (14e9, 17e9),
        "qwen2.5-3b": (2.8e9, 3.9e9),
        "jamba-1.5-large-398b": (370e9, 430e9),
        "llama4-maverick-400b-a17b": (380e9, 430e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),
        "internvl2-26b": (18e9, 27e9),
        "seamless-m4t-medium": (0.8e9, 2.5e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = cfg.param_count()
        if cfg.encdec:  # decoder counted via n_layers; encoder adds its stack
            n += cfg.enc_layers * (4 * cfg.d_model * cfg.attn.n_heads
                                   * cfg.attn.head_dim + 2 * cfg.d_model * cfg.d_ff)
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller():
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
