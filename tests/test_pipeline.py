"""Streaming executor (runtime/pipeline): the plan -> execution loop.

Acceptance contract:
  * interpreter token streams are bitwise identical to the KPN simulator
    (`core/simulate.py`) for jpeg and streamit graphs;
  * measured steady-state inverse throughput is within 15% of
    `core/throughput.analyze` on fastest / smallest / solver-chosen
    selections;
  * the jax path runs a solver-produced Selection for an LM graph
    end-to-end, bitwise equal to the unpipelined forward, and 1F1B
    training grads match sequential autodiff;
  * measurement feeds back into re-planning.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import heuristic
from repro.core.fork_join import JPEG_CALIBRATED, LITERAL
from repro.core.simulate import run_functional
from repro.core.stg import STG, Impl, Node, Selection, unit_rate_node
from repro.core.throughput import analyze
from repro.graphs import jpeg, streamit
from repro.runtime.pipeline import (Fifo, LMPipeline, LMPipelineResult,
                                    as_selection, compare, compare_lm,
                                    execute, fill_drain, fill_drain_bubble,
                                    interleaved_1f1b, max_live_activations,
                                    measured_replan, one_f_one_b, place,
                                    replan_to_fixed_point,
                                    selection_from_plan, tp_of)

N_BLOCKS = 192


def _selections(g, v_tgt=8, fj=JPEG_CALIBRATED):
    return {
        "fastest": Selection.fastest(g),
        "smallest": Selection.smallest(g),
        "solver": heuristic.min_area(g, v_tgt, fj).selection,
    }


# ===========================================================================
# placement
# ===========================================================================
def test_placement_slices_sized_tp_x_replicas():
    g = jpeg.build_stg()
    sel = Selection.fastest(g).set("encode", "v1", 4)
    pl = place(g, sel)
    assert len(pl.replicas_of("encode")) == 4
    assert all(len(s.devices) == 1 for s in pl.slices.values())
    # enough hardware by default: every device hosts exactly one worker
    assert set(pl.device_load().values()) == {1}
    assert pl.oversubscription == 1.0


def test_placement_oversubscribes_small_pools():
    g = jpeg.build_stg()
    sel = Selection.fastest(g).set("encode", "v1", 8)
    pl = place(g, sel, devices=3)
    assert pl.n_devices == 3
    assert pl.oversubscription == pytest.approx(pl.demand / 3)
    assert max(pl.device_load().values()) > 1


def test_launch_stage_device_slices_partition():
    from repro.launch.mesh import stage_device_slices
    g = jpeg.build_stg()
    sel = Selection.fastest(g).set("encode", "v1", 4)
    slices = stage_device_slices(list(range(16)), g, sel)
    assert len(slices["encode"]) == 4
    flat = [d for groups in slices.values() for tup in groups for d in tup]
    assert len(flat) == len(set(flat))      # disjoint slices


def test_tp_extraction_from_impl():
    assert tp_of(Impl("tp8", area=8, ii=1.0)) == 8
    assert tp_of(Impl("x", area=8, ii=1.0, meta={"tp": 4})) == 4
    assert tp_of(Impl("v1", area=22, ii=512)) == 1


# ===========================================================================
# channels
# ===========================================================================
def test_fifo_backpressure_and_stats():
    f = Fifo(block=2, capacity_blocks=2)
    f.push([1, 2], 0.0)
    f.push([3, 4], 1.0)
    assert not f.can_push(1)
    with pytest.raises(OverflowError):
        f.push([5], 2.0)
    assert f.ready_time() == 0.0
    assert f.pop() == [1, 2]
    assert f.can_push(2)
    assert f.stats.high_water == 4 and f.stats.pops == 2


def test_fifo_two_level_credits():
    """Async-path slot protocol: reserve at producer dispatch, pop_hold at
    consumer dispatch, release at consumer retirement — capacity bounds
    queued + in-flight work the whole way."""
    f = Fifo(block=1, capacity_blocks=3)
    f.reserve(1)                      # producer dispatched, token pending
    assert f.free == 2
    f.push([10], 0.0)                 # a second, synchronous producer
    f.push_reserved([11], 1.0)        # async producer retired
    assert f.free == 1 and len(f) == 2
    got = f.pop_hold(1)
    assert got == [10]
    assert f.free == 1                # popped but slot still held
    f.release(1)
    assert f.free == 2
    assert f.stats.inflight_high_water == 2
    with pytest.raises(OverflowError):
        f.reserve(3)
    with pytest.raises(ValueError):
        f.release(5)
    with pytest.raises(OverflowError):
        f.push_reserved([1], 0.0)     # nothing reserved


def test_fifo_credit_invariants_under_consumer_exceptions():
    """reserve/push_reserved/pop_hold/release must leave no leaked slots
    across repeated consumer failures: every abort path releases its hold
    and the channel keeps full capacity (no creeping deadlock)."""
    f = Fifo(block=1, capacity_blocks=2)
    for cycle in range(50):
        f.reserve(1)
        f.push_reserved([cycle], 0.0)
        got = f.pop_hold(1)
        assert got == [cycle]
        try:
            raise RuntimeError("consumer body failed")
        except RuntimeError:
            f.release(1)                 # the executor's abort path
    assert f.free == f.capacity == 2
    assert f.inflight_slots == 0
    # occupancy never exceeded one in-flight token at a time
    assert f.stats.inflight_high_water == 1
    # and the channel still works end to end after all those aborts
    f.reserve(2)
    f.push_reserved([98, 99], 1.0)
    assert f.pop(2) == [98, 99]


def test_fifo_prefetch_failure_leaves_queue_consistent():
    """A raising prefetch_fn (failed device transfer) propagates, but the
    channel stays consistent: nothing dropped or duplicated, no slot
    accounting moved, the un-staged token still pops, and later prefetch
    retries resume."""
    failed = []

    def flaky(tok):
        if tok == "bad" and not failed:
            failed.append(tok)
            raise ValueError("transfer failed")
        return ("staged", tok)

    f = Fifo(block=1, capacity_blocks=4, prefetch_fn=flaky, prefetch_depth=2)
    with pytest.raises(ValueError, match="transfer failed"):
        f.push(["bad", "ok"], 0.0)
    assert len(f) == 2 and f.free == 2       # push landed, no leak
    # the failing token pops raw; the pop's window advance stages the rest
    assert f.pop(1) == ["bad"]
    assert f.pop(1) == [("staged", "ok")]
    assert f.free == 4


def test_fifo_prefetch_stages_head_tokens():
    staged = []

    def stage(tok):
        staged.append(tok)
        return ("staged", tok)

    f = Fifo(block=1, capacity_blocks=4, prefetch_fn=stage, prefetch_depth=2)
    f.push([1, 2, 3], 0.0)
    assert staged == [1, 2]           # only prefetch_depth head tokens
    assert f.pop(1) == [("staged", 1)]
    assert staged == [1, 2, 3]        # pop pulls the window forward
    assert f.pop(2) == [("staged", 2), ("staged", 3)]
    assert f.stats.prefetches == 3


# ===========================================================================
# interpreter: stream equivalence + throughput accuracy
# ===========================================================================
@pytest.fixture(scope="module")
def jpeg_graph():
    return jpeg.build_stg()


@pytest.fixture(scope="module")
def jpeg_blocks():
    return jpeg.random_blocks(N_BLOCKS)


@pytest.mark.parametrize("which", ["fastest", "smallest", "solver"])
def test_jpeg_streams_bitwise_match_simulator(jpeg_graph, jpeg_blocks, which):
    g = jpeg_graph
    sel = _selections(g)[which]
    ref = run_functional(g, sel, {"camera": jpeg_blocks})["bitstream"]
    run = execute(g, sel, {"camera": jpeg_blocks}, fj=JPEG_CALIBRATED)
    assert run.outputs["bitstream"] == ref
    assert ref == jpeg.reference_pipeline(jpeg_blocks)


@pytest.mark.parametrize("which", ["fastest", "smallest", "solver"])
def test_jpeg_measured_throughput_within_15pct(jpeg_graph, jpeg_blocks, which):
    g = jpeg_graph
    sel = _selections(g)[which]
    run = execute(g, sel, {"camera": jpeg_blocks}, fj=JPEG_CALIBRATED)
    rep = compare(g, sel, run)
    a = analyze(g, sel)
    assert rep.v_app_measured == pytest.approx(a.v_app, rel=0.15)
    # per-stage: the bottleneck stage must run at its modelled rate
    assert rep.bottleneck_measured in rep.stages
    assert rep.stages[rep.bottleneck_measured].ratio == pytest.approx(1.0, rel=0.15)


@pytest.mark.parametrize("build,src,sink", [
    (streamit.build_fft, "src", "out"),
    (streamit.build_filterbank, "src", "out"),
    (streamit.build_autocor, "src", "out"),
])
def test_streamit_streams_and_throughput(build, src, sink):
    g = build()
    rng = np.random.default_rng(3)
    n_in = 8 if build is streamit.build_fft else 16
    blocks = [rng.normal(size=n_in) for _ in range(96)]
    for which, sel in _selections(g, v_tgt=4, fj=LITERAL).items():
        ref = run_functional(g, sel, {src: blocks})[sink]
        run = execute(g, sel, {src: blocks}, fj=LITERAL)
        got = run.outputs[sink]
        assert len(got) == len(ref), which
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        rep = compare(g, sel, run)
        a = analyze(g, sel)
        assert rep.v_app_measured == pytest.approx(a.v_app, rel=0.15), which


def test_replicated_chain_reaches_divided_throughput():
    """4 round-robin replicas of a ii=8 stage must sustain v = 2."""
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    g.add_node(unit_rate_node("slow", [Impl("v1", 1, 8.0)],
                              fn=lambda ins, st: ([[ins[0][0]]], st)))
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect("src", "slow")
    g.connect("slow", "out")
    sel = Selection.fastest(g).set("slow", "v1", 4)
    run = execute(g, sel, {"src": list(range(256))}, fj=LITERAL)
    assert run.outputs["out"] == list(range(256))
    assert run.stage_inverse_throughput("slow") == pytest.approx(2.0, rel=0.15)


def test_oversubscription_slows_pipeline_honestly():
    """On 1 device, a 2-stage pipeline time-shares: v doubles."""
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    for n in ("a", "b"):
        g.add_node(unit_rate_node(n, [Impl("v1", 1, 4.0)],
                                  fn=lambda ins, st: ([[ins[0][0]]], st)))
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect("src", "a"); g.connect("a", "b"); g.connect("b", "out")
    sel = Selection.fastest(g)
    spatial = execute(g, sel, {"src": list(range(64))}, fj=LITERAL)
    folded = execute(g, sel, {"src": list(range(64))}, devices=1, fj=LITERAL)
    v_spatial = spatial.inverse_throughput("out")
    v_folded = folded.inverse_throughput("out")
    assert v_spatial == pytest.approx(4.0, rel=0.15)
    assert v_folded == pytest.approx(8.0, rel=0.15)
    assert folded.placement.oversubscription > 1.0


def test_multirate_producer_burst_fits_fifo():
    """A 1->3 rate-changing producer must not wedge on consumer-sized
    buffers; streams still match the simulator."""
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    g.add_node(Node("mid", impls=(Impl("v1", 1, 3.0),), in_rates=(1,),
                    out_rates=(3,),
                    fn=lambda ins, st: ([[ins[0][0], ins[0][0] + 1,
                                          ins[0][0] + 2]], st)))
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect("src", "mid")
    g.connect("mid", "out")
    sel = Selection.fastest(g)
    inputs = {"src": [10 * k for k in range(24)]}
    run = execute(g, sel, inputs, fj=LITERAL)
    assert run.outputs["out"] == run_functional(g, sel, inputs)["out"]
    assert run.fired["mid"] == 24


# ===========================================================================
# measurement -> replanning feedback
# ===========================================================================
def test_measured_replan_adds_replicas_for_slow_stage(jpeg_graph, jpeg_blocks):
    g = jpeg_graph
    sel = _selections(g)["solver"]
    run = execute(g, sel, {"camera": jpeg_blocks}, fj=JPEG_CALIBRATED)
    rep = compare(g, sel, run)
    # pretend dct measured 4x slower than modelled
    rep.stages["dct"].measured_v *= 4
    res = measured_replan(g, rep, v_tgt=8, fj=JPEG_CALIBRATED)
    assert res.feasible
    base = heuristic.min_area(g, 8, JPEG_CALIBRATED)
    # replanned capacity on the measured-slow stage strictly grows
    assert res.selection.choices["dct"] != base.selection.choices["dct"] or \
        res.total_area > base.total_area


def _fixed_point_graph():
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    g.add_node(unit_rate_node("a", [Impl("v1", 1, 3.0)]))
    g.add_node(unit_rate_node("b", [Impl("v1", 1, 1.0)]))
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect("src", "a"); g.connect("a", "b"); g.connect("b", "out")
    return g


def _flappy_run_fn(sel):
    """Stage ``a`` measures slow single-replica and fast replicated — the
    classic measured-replan oscillator: at v_tgt=3.9 the undamped loop
    calibrates to 2.0, adds a replica, calibrates to 1.25, removes it,
    forever (the switch threshold is scale = 3.9/3 = 1.3)."""
    return {"a": 2.0 if sel.replicas("a") == 1 else 1.25, "b": 1.0}


def test_replan_to_fixed_point_oscillates_without_damping():
    g = _fixed_point_graph()
    res = replan_to_fixed_point(g, _flappy_run_fn, v_tgt=3.9, fj=LITERAL,
                                damping=1.0, max_iters=10)
    assert res.oscillated                 # the guard caught the cycle
    assert res.iterations <= 10           # ... and terminated
    # the first three undamped selections flip 1 -> 2 -> 1 replicas
    flips = [h.selection["a"][1] for h in res.history[:3]]
    assert flips == [1, 2, 1]


def test_replan_to_fixed_point_converges_with_damping():
    g = _fixed_point_graph()
    res = replan_to_fixed_point(g, _flappy_run_fn, v_tgt=3.9, fj=LITERAL,
                                damping=0.5, max_iters=10)
    assert res.converged and not res.oscillated
    # geometric damping keeps the memory of the slow measurement, so the
    # calibration settles above the flip threshold: a keeps its replica
    assert res.selection.choices["a"][1] == 2
    assert res.iterations <= 4
    assert res.history[-1].residual >= 0


def test_max_throughput_survives_uniform_calibration():
    """Near-uniform measured ratios (the wall-clock-vs-roofline scale
    every host measurement produces) put all tp1 IIs in one 0.5% bucket;
    the bisection's candidate clustering must keep that bucket's largest
    target or the all-smallest operating point vanishes and a fitting
    budget solves infeasible."""
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    shape = ShapeCfg("decode_cal", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    ratios = {s.name: 1e4 * (1.0 + 0.002 * i)       # ~0.2% spread
              for i, s in enumerate(plan.stages)}
    new, _ = planner.replan(tiny, shape, plan, new_chips=8,
                            measured_ratio=ratios, max_tp=4)
    assert new.feasible
    assert new.total_chips <= 8


def test_max_throughput_cluster_anchor_does_not_drift():
    """Candidates spaced just under the 0.5% bucket width must not chain
    into one mega-bucket: the bucket anchor is its first member, so a
    geometric ladder keeps ~one operating point per bucket width."""
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    # impl IIs form a 1.004-ratio ladder spanning ~1.5x
    impls = [Impl(f"v{k}", area=1 + k, ii=100.0 * 1.004 ** k)
             for k in range(100)]
    g.add_node(unit_rate_node("a", impls))
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect("src", "a"); g.connect("a", "out")
    res = heuristic.max_throughput(g, 1.0, LITERAL)   # only nr=1 area-1 fits
    assert res.feasible
    # the cheapest impl is the slowest rung; a drifted mega-bucket would
    # leave only far-apart targets and still find v0 here, so assert the
    # candidate grid kept fine structure by hitting the exact optimum
    assert res.selection.choices["a"] == ("v0", 1)
    assert res.v_app == pytest.approx(100.0)


def test_replan_to_fixed_point_validates_modes():
    g = _fixed_point_graph()
    with pytest.raises(ValueError, match="exactly one"):
        replan_to_fixed_point(g, _flappy_run_fn, fj=LITERAL)


def test_as_selection_accepts_all_plan_shapes():
    """One materialisation rule: Selection passthrough, TradeoffResult
    .selection, PlanResult per-stage choices."""
    g = _fixed_point_graph()
    sel = Selection.fastest(g)
    assert as_selection(sel) is sel
    res = heuristic.min_area(g, 8, LITERAL)
    assert as_selection(res) is res.selection
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    plan = planner.plan(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                        chips=16, max_tp=4)
    sel2 = as_selection(plan)
    assert sel2.choices == selection_from_plan(plan).choices
    assert set(sel2.choices) == {s.name for s in plan.stages}


def test_report_json_roundtrip(jpeg_graph, jpeg_blocks):
    import json
    g = jpeg_graph
    sel = Selection.fastest(g)
    run = execute(g, sel, {"camera": jpeg_blocks}, fj=JPEG_CALIBRATED)
    rep = compare(g, sel, run)
    d = json.loads(rep.to_json())
    assert d["bottleneck_measured"] == rep.bottleneck_measured
    assert set(d["stages"]) == set(rep.stages)
    assert 0.8 < d["accuracy"] < 1.2


# ===========================================================================
# schedules (first-class plan objects; full coverage in test_schedule.py)
# ===========================================================================
@pytest.mark.parametrize("n_stages,n_micro", [(1, 1), (2, 3), (4, 8), (6, 4)])
def test_one_f_one_b_invariants(n_stages, n_micro):
    sched = one_f_one_b(n_stages, n_micro)
    assert sched.n_stages == n_stages and sched.n_chunks == 1
    for s, ops in enumerate(sched):
        assert sorted((op.kind, op.mb) for op in ops) == \
            sorted([("F", m) for m in range(n_micro)]
                   + [("B", m) for m in range(n_micro)])
        seen_f = set()
        for op in ops:
            if op.kind == "F":
                seen_f.add(op.mb)
            else:
                assert op.mb in seen_f, "backward before forward"
        assert max_live_activations(ops) <= min(n_stages - s, n_micro)
        assert max_live_activations(ops) <= sched.live_bounds[s]
    # last stage strictly alternates once warm
    last = sched[-1]
    assert [(op.kind, op.mb) for op in last[:2]] == [("F", 0), ("B", 0)]


def test_fill_drain_is_streaming_order():
    from repro.runtime.pipeline import SchedOp
    sched = fill_drain(3, 2)
    assert sched.stage_ops == [[SchedOp("F", 0), SchedOp("F", 1)]] * 3
    assert not sched.trains


def test_fill_drain_bubble_fraction():
    assert fill_drain_bubble(1, 8) == 0.0
    assert fill_drain_bubble(4, 12) == pytest.approx(3 / 15)
    with pytest.raises(ValueError):
        fill_drain_bubble(0, 4)


def test_compare_error_names_underfired_stages(jpeg_graph):
    """A too-short stream must say which stage fired how often, not just
    fail with a bare count."""
    g = jpeg_graph
    sel = Selection.fastest(g)
    run = execute(g, sel, {"camera": jpeg.random_blocks(2)},
                  fj=JPEG_CALIBRATED)
    with pytest.raises(ValueError, match=r"dct: 2") as ei:
        compare(g, sel, run)
    assert "need >= 4 firings" in str(ei.value)


# ===========================================================================
# jax LM path
# ===========================================================================
@pytest.fixture(scope="module")
def lm_setup():
    import jax.numpy as jnp
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph
    shape = ShapeCfg("pipe_test", 16, 8, "train")
    plan = planner.plan(tiny, shape, chips=16, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    sel = selection_from_plan(plan)
    pipe = LMPipeline(tiny, stg, sel)
    rng = np.random.default_rng(0)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 16)), jnp.int32)
           for _ in range(5)]
    return pipe, plan, mbs


def test_lm_pipeline_runs_solver_selection_end_to_end(lm_setup):
    pipe, plan, mbs = lm_setup
    assert pipe.n_stages == 6          # embed + 4 blocks + head
    res = pipe.run(mbs)
    ref = pipe.reference(mbs)
    assert all(o is not None for o in res.outputs)
    for a, b in zip(res.outputs, ref):
        # host-side compare: outputs may live on different replica devices
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res.tokens_per_s(toks_per_mb=32) > 0
    for st in pipe.stages:
        assert res.stage_firings[st.name] == len(mbs)


def test_lm_pipeline_1f1b_grads_match_sequential(lm_setup):
    import jax
    import jax.numpy as jnp
    pipe, _, mbs = lm_setup
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    res = pipe.run(mbs, train=True, loss_fn=loss)
    assert all(res.grads[st.name] is not None for st in pipe.stages)

    def full_loss(all_params):
        tot = 0.0
        for mb in mbs:
            x = mb
            for st, p in zip(pipe.stages, all_params):
                x = st.fwd(p, x)
            tot = tot + loss(x)
        return tot

    gref = jax.grad(full_loss)([st.params[0] for st in pipe.stages])
    for st, gr in zip(pipe.stages, gref):
        for a, b in zip(jax.tree.leaves(res.grads[st.name]),
                        jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-5)


def test_lm_pipeline_rejects_grouping_that_drops_replicas(lm_setup):
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.graphs import lm_graph
    pipe, plan, _ = lm_setup
    stg, _ = lm_graph.build_stg(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                                max_tp=4)
    sel = selection_from_plan(plan)
    sel.set("block01", sel.choices["block01"][0],
            sel.choices["block01"][1] * 2)     # misalign within a group
    with pytest.raises(ValueError, match="drop replicas"):
        LMPipeline(tiny, stg, sel, layers_per_stage=2)


def test_lm_pipeline_overlap_off_matches_reference(lm_setup):
    """The serial A/B baseline (overlap=False) runs the same graph and
    must stay bitwise equal to the async default."""
    pipe, _, mbs = lm_setup
    res = pipe.run(mbs, overlap=False)
    for a, b in zip(res.outputs, pipe.reference(mbs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tokens_per_s_short_run_excludes_fill():
    """< 3 completed microbatches: throughput anchors at the first
    completion instead of dividing by the full wall (which counts the
    pipeline fill ramp and deflates tiny runs)."""
    res = LMPipelineResult(outputs=[None, None],
                           mb_done_s=[5.0, 5.5], wall_s=10.0)
    assert res.tokens_per_s(10) == pytest.approx(10 * 1 / 0.5)
    # a single completion has no gap to measure — wall_s fallback remains
    res1 = LMPipelineResult(outputs=[None], mb_done_s=[5.0], wall_s=10.0)
    assert res1.tokens_per_s(10) == pytest.approx(1.0)


def test_backpressure_bounds_inflight_under_async(lm_setup):
    """A slow consumer with capacity_blocks=1 must stall its producer
    (bounded in-flight work, no unbounded device-memory growth) and never
    trip the deadlock detector on a valid schedule."""
    import time as _time

    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.graphs import lm_graph
    stg, _ = lm_graph.build_stg(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                                max_tp=4)
    pipe = LMPipeline(tiny, stg, Selection.smallest(stg),
                      capacity_blocks=1, replica_queue=1)
    rng = np.random.default_rng(7)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 16)), jnp.int32)
           for _ in range(12)]

    def slow_wrap(fwd, dt):
        # host-side sleep on the stage's worker thread: a device-side
        # sleep (pure_callback inside the jit) occupies the single shared
        # CPU device and serialises *every* stage behind it — producers
        # then starve instead of backing up and the test races.  Sleeping
        # on the worker leaves the device free, so upstream stages run
        # ahead and deterministically fill the slow stage's input FIFO.
        def wrapped(p, x):
            _time.sleep(dt)
            return fwd(p, x)
        return wrapped

    slow_idx = pipe.n_stages - 2
    pipe.stages[slow_idx].fwd = slow_wrap(pipe.stages[slow_idx].fwd, 0.15)
    ref = pipe.reference(mbs)             # same wrapped fns: values unchanged
    from repro.runtime.pipeline import Tracer
    tr = Tracer()
    res = pipe.run(mbs, tracer=tr)
    for a, b in zip(res.outputs, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the producer feeding the slow stage was actually deferred, and the
    # backpressure shows up as traced credit-stall wait time upstream
    assert res.fifo_stats[("act", slow_idx - 1)].producer_stalls > 0
    assert sum(res.stage_wait_s.get(pipe.stages[i].name, {})
               .get("credit", 0.0) for i in range(slow_idx)) > 0.0
    # bounded in-flight: no edge ever exceeded its slot budget
    # (capacity_blocks=1 + one producer slot + one consumer slot), and at
    # most one op per stage was ever in flight (replica_queue=1, nr=1)
    for stats in res.fifo_stats.values():
        assert stats.inflight_high_water <= 1 + 2
    assert res.max_inflight <= pipe.n_stages


def test_compare_lm_report_feeds_replan(lm_setup):
    """The jax path is a calibration source: completion-event ratios flow
    through PipelineReport into planner.replan(measured_ratio=...)."""
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph
    pipe, plan, mbs = lm_setup
    stg, _ = lm_graph.build_stg(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                                max_tp=4)
    sel = selection_from_plan(plan)
    res = pipe.run(mbs)
    rep = compare_lm(stg, sel, res)
    assert rep.bottleneck_measured in rep.stages
    ratios = rep.ratios()
    assert ratios and all(r > 0 for r in ratios.values())
    new, diff = planner.replan(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                               plan, new_chips=16, measured_ratio=ratios,
                               max_tp=4)
    assert new.feasible
    assert "throughput_ratio" in diff


def test_compare_lm_too_few_microbatches_names_counts(lm_setup):
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.graphs import lm_graph
    pipe, plan, mbs = lm_setup
    stg, _ = lm_graph.build_stg(tiny, ShapeCfg("pipe_test", 16, 8, "train"),
                                max_tp=4)
    res = pipe.run(mbs[:2])
    with pytest.raises(ValueError, match=r"embed: 2"):
        compare_lm(stg, selection_from_plan(plan), res)


def test_stage_submeshes_fold_to_none_without_hardware():
    """tp>1 slices on a too-small or abstract pool cannot form a sub-mesh:
    the plumbing reports None and the executor falls back to single-device
    placement instead of sharding dishonestly."""
    import jax
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.graphs import lm_graph
    from repro.launch.mesh import stage_submeshes, submesh_of
    stg, _ = lm_graph.build_stg(tiny, ShapeCfg("pipe_test", 16, 8, "serve"),
                                max_tp=4)
    sel = Selection.smallest(stg).set("block00", "tp2", 1)
    subs = stage_submeshes(jax.devices(), stg, sel)   # 1-device CI pool
    assert set(subs) == set(stg.nodes)
    if len(jax.devices()) < 2:
        assert subs["block00"] == [None]              # folded slice
    assert submesh_of((0, 1)) is None                 # abstract int pool
    assert submesh_of((jax.devices()[0],)) is None    # tp == 1


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, time
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core.fork_join import LITERAL
    from repro.core.stg import STG, Impl, Node, Selection, unit_rate_node
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import LMPipeline, execute

    shape = ShapeCfg("parity", 16, 8, "serve")
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)

    # --- A: tp-sharded stage params over a per-stage sub-mesh ------------
    sel_tp = Selection.smallest(stg).set("block00", "tp2", 1)
    pipe_tp = LMPipeline(tiny, stg, sel_tp)
    b0 = [st for st in pipe_tp.stages if st.name == "block00"][0]
    assert b0.meshes[0] is not None, "tp2 slice should build a sub-mesh"
    leaves = jax.tree.leaves(b0.params[0])
    assert sum(1 for l in leaves
               if not l.sharding.is_fully_replicated) >= 4, \\
        "block params should shard over the slice, not sit on one device"
    assert all(len(l.sharding.device_set) == 2 for l in leaves)
    pipe_1d = LMPipeline(tiny, stg, sel_tp, devices=[jax.devices()[0]])
    rng = np.random.default_rng(0)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 16)), jnp.int32)
           for _ in range(5)]
    out_tp = pipe_tp.run(mbs).outputs
    out_1d = pipe_1d.run(mbs).outputs
    for a, b in zip(out_tp, out_1d):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=0.08, rtol=0.05)
    print("TPSHARD_OK")

    # --- B: concurrent replica dispatch reads ii/nr ----------------------
    # stage bodies are wall-clock sleeps (a host-time device simulator), so
    # the jax path's completion-event measurement can be lined up against
    # the interpreter executing the mirror STG with the same IIs
    SLEEPS = {"embed": 0.010, "head": 0.010, "block01": 0.200}
    DEFAULT = 0.050
    sel_par = Selection.smallest(stg).set(
        "block01", Selection.smallest(stg).choices["block01"][0], 2)
    pipe = LMPipeline(tiny, stg, sel_par, replica_queue=1)

    def sleep_stage(dt):
        def slow(v):
            time.sleep(dt)
            return v
        return jax.jit(lambda p, x: jax.pure_callback(
            slow, jax.ShapeDtypeStruct(x.shape, x.dtype), x))

    for st in pipe.stages:
        st.fwd = sleep_stage(SLEEPS.get(st.name, DEFAULT))

    mirror = STG()
    mirror.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    chain = [st.name for st in pipe.stages]
    for n in chain:
        ii_us = SLEEPS.get(n, DEFAULT) * 1e6
        mirror.add_node(unit_rate_node(
            n, [Impl("v1", 1, ii_us)],
            fn=lambda ins, st: ([[ins[0][0]]], st)))
    mirror.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    prev = "src"
    for n in chain + ["out"]:
        mirror.connect(prev, n)
        prev = n
    msel = Selection.fastest(mirror).set("block01", "v1", 2)
    irun = execute(mirror, msel, {"src": list(range(64))}, fj=LITERAL)
    interp_v = irun.stage_inverse_throughput("block01")   # == ii/nr us
    assert abs(interp_v - 100000) / 100000 < 0.05

    mbs_p = [jnp.zeros((1, 4), jnp.float32) for _ in range(14)]
    pipe.run(mbs_p[:2])                                   # warm compiles
    best = float("inf")
    for trial in range(3):      # shared CI boxes hiccup; best-of-3
        res = pipe.run(mbs_p)
        jax_v = res.stage_inverse_us("block01")
        best = min(best, abs(jax_v - interp_v) / interp_v)
        print(f"trial {trial}: jax {jax_v/1e3:.1f} ms vs interpreter "
              f"{interp_v/1e3:.1f} ms (off {best:.1%})")
        if best < 0.15:
            break
    assert best < 0.15, f"replicated stage off by {best:.1%} (>15%)"
    print("PARITY_OK")
""")


def test_multidevice_tp_sharding_and_replica_parity():
    """On an 8-device pool: a tp2 stage's params shard over its sub-mesh
    with outputs matching the single-device run, and a 2-replica stage's
    measured inverse throughput reads ii/nr within 15% of the interpreter
    path executing the mirror graph."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "TPSHARD_OK" in r.stdout
    assert "PARITY_OK" in r.stdout


# ===========================================================================
# interleaved 1F1B on the jax LM path (schedules as plan objects)
# ===========================================================================
@pytest.fixture(scope="module")
def lm6_setup():
    """A 6-layer tiny variant: embed + 6 blocks + head = 8 built stages,
    the smallest graph that interleaves over >= 4 physical stages."""
    from dataclasses import replace

    import jax.numpy as jnp
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.graphs import lm_graph
    tiny6 = replace(tiny, name="tiny6", n_layers=6)
    stg, _ = lm_graph.build_stg(tiny6, ShapeCfg("ilv_test", 16, 8, "train"),
                                max_tp=4)
    pipe = LMPipeline(tiny6, stg, Selection.smallest(stg))
    rng = np.random.default_rng(11)
    mbs = [jnp.asarray(rng.integers(0, tiny6.vocab, (2, 16)), jnp.int32)
           for _ in range(8)]
    return pipe, mbs


def _sequential_vjp_grads(pipe, mbs, loss):
    """Sequential autodiff over the same jitted stage fns the pipeline
    runs, accumulated in microbatch order on the same grad targets — the
    bitwise reference both schedules must reproduce."""
    import jax
    import jax.numpy as jnp
    grads = {st.name: None for st in pipe.stages}
    losses = {}
    for i, mb in enumerate(mbs):
        x = mb
        vjps = []
        for st in pipe.stages:
            x = jax.device_put(x, st.x_target(0))
            y, vjp = jax.vjp(st.fwd, st.params[0], x)
            vjps.append(vjp)
            x = y
        lval, y_bar = jax.value_and_grad(loss)(x)
        losses[i] = float(lval)
        for st, vjp in reversed(list(zip(pipe.stages, vjps))):
            p_bar, y_bar = vjp(y_bar)
            pb = jax.device_put(p_bar, st.grad_target())
            grads[st.name] = (pb if grads[st.name] is None else
                              jax.tree.map(jnp.add, grads[st.name], pb))
    return grads, losses


def test_interleaved_1f1b_grads_bitwise_equal(lm6_setup):
    """Acceptance: interleaved 1F1B over 4 physical stages x 2 chunks
    produces grads bitwise-equal to plain 1F1B and to sequential
    autodiff (same vjp chain, same accumulation order)."""
    import jax
    import jax.numpy as jnp
    pipe, mbs = lm6_setup
    assert pipe.n_stages == 8
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    r_plain = pipe.run(mbs, train=True, loss_fn=loss,
                       schedule=one_f_one_b(8, len(mbs)))
    r_ilv = pipe.run(mbs, train=True, loss_fn=loss,
                     schedule=interleaved_1f1b(4, len(mbs), 2))
    # 4 physical programs, each named for its two chunks
    assert len(r_ilv.stage_firings) == 4
    assert "embed+block03" in r_ilv.stage_firings
    assert r_ilv.stage_firings["embed+block03"] == 2 * 2 * len(mbs)
    g_seq, losses_seq = _sequential_vjp_grads(pipe, mbs, loss)
    assert r_plain.losses == r_ilv.losses == pytest.approx(losses_seq)
    for st in pipe.stages:
        for a, b, c in zip(jax.tree.leaves(r_plain.grads[st.name]),
                           jax.tree.leaves(r_ilv.grads[st.name]),
                           jax.tree.leaves(g_seq[st.name])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_interleaved_default_schedule_at_construction(lm6_setup):
    """LMPipeline(schedule=...) sets the default `run` executes."""
    import jax
    import jax.numpy as jnp
    from repro.runtime.pipeline import Schedule
    pipe, mbs = lm6_setup
    mbs = mbs[:4]
    pipe2 = LMPipeline(pipe.cfg, *_lm6_graph_sel(pipe.cfg),
                       schedule=interleaved_1f1b(4, 4, 2))
    assert isinstance(pipe2.schedule, Schedule)
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    res = pipe2.run(mbs, train=True, loss_fn=loss)
    assert set(res.stage_firings) == {"embed+block03", "block00+block04",
                                      "block01+block05", "block02+head"}
    ref = pipe2.run(mbs, train=True, loss_fn=loss,
                    schedule=one_f_one_b(8, 4))
    for name in res.grads:
        for a, b in zip(jax.tree.leaves(res.grads[name]),
                        jax.tree.leaves(ref.grads[name])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _lm6_graph_sel(cfg):
    from repro.configs.base import ShapeCfg
    from repro.graphs import lm_graph
    stg, _ = lm_graph.build_stg(cfg, ShapeCfg("ilv_test", 16, 8, "train"),
                                max_tp=4)
    return stg, Selection.smallest(stg)


def test_run_rejects_mismatched_schedules(lm6_setup):
    import jax.numpy as jnp
    pipe, mbs = lm6_setup
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    with pytest.raises(ValueError, match="model stages"):
        pipe.run(mbs, train=True, loss_fn=loss,
                 schedule=interleaved_1f1b(2, len(mbs), 2))
    with pytest.raises(ValueError, match="microbatches"):
        pipe.run(mbs[:4], train=True, loss_fn=loss,
                 schedule=one_f_one_b(8, len(mbs)))
    with pytest.raises(ValueError, match="no backward"):
        pipe.run(mbs, train=True, loss_fn=loss,
                 schedule=fill_drain(8, len(mbs)))
    with pytest.raises(ValueError, match="schedules backward"):
        pipe.run(mbs, schedule=one_f_one_b(8, len(mbs)))


_MULTIDEV_ILV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    from dataclasses import replace
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core.stg import Selection
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import (LMPipeline, interleaved_1f1b,
                                        one_f_one_b)

    assert len(jax.devices()) == 8
    tiny6 = replace(tiny, name="tiny6", n_layers=6)
    stg, _ = lm_graph.build_stg(tiny6, ShapeCfg("ilv_par", 16, 8, "train"),
                                max_tp=4)
    pipe = LMPipeline(tiny6, stg, Selection.smallest(stg))
    assert pipe.n_stages == 8
    spread = {st.devices[0] for st in pipe.stages}
    assert len(spread) == 8, f"stages folded onto {len(spread)} device(s)"
    rng = np.random.default_rng(5)
    mbs = [jnp.asarray(rng.integers(0, tiny6.vocab, (2, 16)), jnp.int32)
           for _ in range(8)]
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    r_plain = pipe.run(mbs, train=True, loss_fn=loss,
                       schedule=one_f_one_b(8, 8))
    r_ilv = pipe.run(mbs, train=True, loss_fn=loss,
                     schedule=interleaved_1f1b(4, 8, 2))
    assert r_plain.losses == r_ilv.losses
    for st in pipe.stages:
        for a, b in zip(jax.tree.leaves(r_plain.grads[st.name]),
                        jax.tree.leaves(r_ilv.grads[st.name])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("INTERLEAVED_PARITY_OK")
""")


def test_multidevice_interleaved_schedule_parity():
    """On an 8-device pool an interleaved schedule runs its virtual-stage
    chunks on their real placement devices (activations device-to-device
    across the wrap-around edges) and still produces grads bitwise-equal
    to plain 1F1B."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_ILV],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "INTERLEAVED_PARITY_OK" in r.stdout


def test_lm_pipeline_rejects_graphs_it_cannot_execute():
    """Enc-dec graphs emit encNN nodes no built decoder stage claims —
    construction must fail loudly instead of running less model than the
    plan placed."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCfg
    from repro.graphs import lm_graph
    cfg = get_config("seamless-m4t-medium").reduced()
    stg, _ = lm_graph.build_stg(cfg, ShapeCfg("encdec", 16, 8, "serve"),
                                max_tp=2)
    with pytest.raises(ValueError, match="enc00"):
        LMPipeline(cfg, stg, Selection.smallest(stg))


def test_planner_replan_accepts_measured_ratios():
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    shape = ShapeCfg("pipe_test", 16, 8, "train")
    old = planner.plan(tiny, shape, chips=16, max_tp=4)
    # head measured 8x slower than the roofline promise
    new, diff = planner.replan(tiny, shape, old, new_chips=16,
                               measured_ratio={"head": 8.0}, max_tp=4)
    assert new.feasible
    old_head = next(s for s in old.stages if s.name == "head")
    new_head = next(s for s in new.stages if s.name == "head")
    cap_old = old_head.tp * old_head.replicas
    cap_new = new_head.tp * new_head.replicas
    assert cap_new >= cap_old   # measured-slow stage never loses capacity
