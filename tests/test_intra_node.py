"""Intra-Node Optimizer: pipelining / expansion / clustering (Figs. 2-4)."""
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.intra_node import (CompositeBody, PrimOp, enumerate_impls,
                                   schedule_for_target)
from repro.graphs.nbody import FORCE_BODY, force_impls


def test_nbody_sum_ii_is_33():
    assert FORCE_BODY.total_ii() == 33


def test_nbody_naive_pipeline_stalls_at_div():
    # Fig. 2: one PE per op, II limited by the 8-cycle division.
    s = schedule_for_target(FORCE_BODY, 8.0)
    assert s.impl.ii == 8.0
    assert not s.expansions  # nothing needs expansion at II=8


def test_nbody_expansion_reaches_ii1():
    # Fig. 3: expanding div (and sqrt) round-robin reaches II = 1.
    s = schedule_for_target(FORCE_BODY, 1.0)
    assert s.impl.ii == 1.0
    assert s.expansions["f"] == 8      # 8 dividers
    assert s.expansions["r"] == 8      # 8 sqrt units
    assert s.impl.area == FORCE_BODY.total_ii()  # full expansion area = sum ii


def test_nbody_frontier_spans_1_to_33():
    # Fig. 4: inverse throughput varies from 1 to 33.
    impls = force_impls()
    iis = [im.ii for im in impls]
    assert min(iis) == 1 and max(iis) == 33
    # single-PE point has area 1; fastest has area 33
    by_ii = {im.ii: im for im in impls}
    assert by_ii[33].area == 1
    assert by_ii[1].area == 33
    # frontier is monotone: slower => no more area
    for a, b in zip(impls, impls[1:]):
        assert a.ii < b.ii and a.area > b.area


def test_replication_equivalence_claim():
    """Paper: II=1 reachable by replicating the II=33 impl 33x (area 33) or
    using the fastest impl directly (area 33) — identical area."""
    by_ii = {im.ii: im for im in force_impls()}
    assert by_ii[33].area * 33 == by_ii[1].area * 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["add", "mul", "div", "sqrt", "sub"]),
                min_size=1, max_size=12),
       st.integers(min_value=1, max_value=40))
def test_schedule_meets_target_and_area_sane(kinds, target):
    ops = tuple(PrimOp(f"o{i}", k, deps=(f"o{i-1}",) if i else ())
                for i, k in enumerate(kinds))
    body = CompositeBody(ops=ops)
    s = schedule_for_target(body, float(target))
    assert s.impl.ii <= target + 1e-9
    # area is between 1 PE and full expansion
    assert 1 <= s.impl.area <= body.total_ii()
    # every op is placed exactly once
    placed = [n for c in s.clusters for n in c]
    assert sorted(placed) == sorted(o.name for o in ops)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["add", "mul", "div"]), min_size=1, max_size=10))
def test_frontier_pareto(kinds):
    ops = tuple(PrimOp(f"o{i}", k) for i, k in enumerate(kinds))
    impls = enumerate_impls(CompositeBody(ops=ops))
    for a, b in zip(impls, impls[1:]):
        assert a.ii < b.ii and a.area > b.area
