"""Fork/join trees and node combining math (Eq. 8-14, Fig. 8)."""
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fork_join import (ForkJoinModel, JPEG_CALIBRATED, LITERAL,
                                  combined_tree_overhead_eq14,
                                  combining_savings, layer_rates,
                                  replicas_needed, tree_height,
                                  tree_overhead_eq9)


def test_eq8_replicas():
    assert replicas_needed(33, 1) == 33
    assert replicas_needed(8, 2) == 4
    assert replicas_needed(7, 2) == 4  # ceil


def test_eq9_literal_values():
    assert tree_overhead_eq9(4, 4) == 1
    assert tree_overhead_eq9(16, 4) == 1 + 4
    assert tree_overhead_eq9(64, 4) == 1 + 4 + 16
    assert tree_overhead_eq9(512, 4) == 1 + 4 + 16 + 64 + 256  # H=5


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 4096), st.integers(2, 8))
def test_eq9_vs_eq14_savings(nr, nf):
    """Eq. 14 = Eq. 9 minus the leaf layer; savings = nf^(H-1)."""
    H = tree_height(nr, nf)
    assert nf ** max(H - 1, 0) < nr * nf  # sanity on H
    assert tree_overhead_eq9(nr, nf) - combined_tree_overhead_eq14(nr, nf) == \
        combining_savings(nr, nf)


def test_paper_75pct_claim():
    """nf=4: 'more than 75% overhead area will be saved' by one combining
    step (for trees with H >= 2)."""
    for H in (2, 3, 4, 5):
        nr = 4 ** H
        save = combining_savings(nr, 4)
        assert save / tree_overhead_eq9(nr, 4) >= 0.75


def test_eq10_11_layer_rates():
    # nr = nf^H replicas; at layer h: v_in = v_S * nf^(h-1) = v_D / nf^(H+1-h)
    v_s, nf, H = 2.0, 4, 3
    v_d = v_s * nf ** H
    for h in range(1, H + 1):
        v_in, v_out = layer_rates(v_s, v_d, nf, h, H)
        assert math.isclose(v_in, v_s * nf ** (h - 1))
        assert math.isclose(v_in, v_d / nf ** (H + 1 - h))
        assert math.isclose(v_out, v_in * nf)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 1024), st.integers(1, 1024))
def test_overhead_symmetric_and_zero_when_matched(ns, nd):
    m = LITERAL
    assert m.overhead(ns, nd) == m.overhead(nd, ns)
    assert m.overhead(ns, ns) == 0.0


def test_free_fanout_variant():
    m = ForkJoinModel(nf=4, node_area=1.0, count_root=False)
    assert m.overhead(1, 4) == 0.0         # within fan-out: free (paper text)
    assert m.overhead(1, 16) == 4.0        # Eq9(16,4)=5 minus the root
    assert LITERAL.overhead(1, 4) == 1.0   # Eq. 9 literal counts the root


def test_jpeg_calibrated_matches_published_overheads():
    """Published Table-2 ILP fork/join overhead column vs calibrated model."""
    m = JPEG_CALIBRATED
    assert abs(m.replication_overhead(512) - 10880) / 10880 < 0.01
    assert abs(m.replication_overhead(128) - 2688) / 2688 < 0.02


def test_grouped_overhead_uses_fan_ratio():
    # 128 producers feeding 512 consumers: fan 4 => one routing layer per producer.
    m = LITERAL
    assert m.overhead(128, 512) == 128 * 1
    assert m.overhead(32, 512) == 32 * tree_overhead_eq9(16, 4)
