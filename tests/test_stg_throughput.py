"""Throughput analysis (Eq. 1, 5, 6, 7) and STG IR invariants."""
import math

import pytest

from repro.core.stg import STG, Channel, Impl, Node, Selection, unit_rate_node
from repro.core.throughput import analyze, min_replicas, propagate_targets


def chain(iis, rates=None):
    g = STG()
    names = [f"n{k}" for k in range(len(iis))]
    for k, ii in enumerate(iis):
        g.add_node(unit_rate_node(names[k], [Impl("v1", area=1, ii=ii)]))
    for a, b in zip(names, names[1:]):
        g.connect(a, b)
    g.validate()
    return g, names


def test_inverse_throughput_eq1():
    im = Impl("x", area=4, ii=12)
    assert im.v_in(3) == 4 and im.v_out(2) == 6


def test_slack_eq5_sign_convention():
    # A(ii=9) -> B(ii=3): producer starves consumer => positive slack.
    g, names = chain([9, 3])
    sel = Selection.fastest(g)
    a = analyze(g, sel)
    ch = a.channels[("n0", 0, "n1", 0)]
    assert ch.v_mo == 9 and ch.v_ei == 3 and ch.slack == 6
    # replicate producer x3 => matched
    sel.set("n0", "v1", 3)
    a = analyze(g, sel)
    assert a.channels[("n0", 0, "n1", 0)].slack == 0


def test_weights_eq6_identify_bottleneck():
    # paper Fig. 6 style: middle node much slower than its neighbours
    g, names = chain([1, 8, 1])
    a = analyze(g, Selection.fastest(g))
    assert a.weights["n1"] > a.weights["n0"]
    assert a.weights["n1"] > a.weights["n2"]
    assert a.bottleneck == "n1"


def test_app_inverse_throughput_is_max_over_nodes():
    g, _ = chain([2, 7, 3])
    a = analyze(g, Selection.fastest(g))
    assert a.v_app == 7
    sel = Selection.fastest(g).set("n1", "v1", 7)
    assert analyze(g, sel).v_app == 3


def test_propagation_eq7_multirate():
    # n0 emits 2 tokens per firing, n1 consumes 1: n1 must fire 2x faster.
    g = STG()
    g.add_node(Node("n0", impls=(Impl("v1", 1, 4),), in_rates=(1,), out_rates=(2,)))
    g.add_node(Node("n1", impls=(Impl("v1", 1, 4),), in_rates=(1,), out_rates=(1,)))
    g.connect("n0", "n1")
    tg = propagate_targets(g, 4.0)
    assert tg["n0"] == 4.0
    assert tg["n1"] == 2.0  # Eq. 7: v_out = (v_in * In)/Out halves per-firing budget
    q = g.repetition_vector()
    assert q == {"n0": 1, "n1": 2}


def test_repetition_vector_rejects_inconsistent_rates():
    g = STG()
    g.add_node(Node("a", impls=(Impl("v1", 1, 1),), out_rates=(2, 3)))
    g.add_node(Node("b", impls=(Impl("v1", 1, 1),), in_rates=(1, 1)))
    g.connect("a", "b", 0, 0)
    g.connect("a", "b", 1, 1)
    with pytest.raises(ValueError):
        g.repetition_vector()


def test_feedback_rejected():
    g = STG()
    g.add_node(unit_rate_node("a", [Impl("v1", 1, 1)], n_in=1, n_out=1))
    g.add_node(unit_rate_node("b", [Impl("v1", 1, 1)], n_in=1, n_out=1))
    g.connect("a", "b")
    g.connect("b", "a")
    with pytest.raises(ValueError, match="feed"):
        g.topo_order()


def test_min_replicas_eq8():
    assert min_replicas(33, 1) == 33
    assert min_replicas(32, 1) == 32
    assert min_replicas(8, 2) == 4
    assert min_replicas(8, 3) == 3


def test_pareto_filters_dominated():
    n = Node("x", impls=(Impl("a", 10, 4), Impl("b", 12, 4), Impl("c", 5, 8),
                         Impl("d", 20, 1)))
    names = {im.name for im in n.pareto()}
    assert names == {"a", "c", "d"}
