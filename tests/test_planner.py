"""The space/time planner on LM task graphs (paper technique -> pods)."""
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core import planner
from repro.core.throughput import analyze
from repro.graphs import lm_graph

QWEN = get_config("qwen2.5-3b")
TRAIN = SHAPES["train_4k"]
DECODE = SHAPES["decode_32k"]


# ------------------------------------------------------------- lm_graph ----
def test_stg_structure():
    g, info = lm_graph.build_stg(QWEN, TRAIN)
    assert len(g.nodes) == QWEN.n_layers + 2          # embed + blocks + head
    assert len(g.channels) == QWEN.n_layers + 1       # a chain
    g.validate()
    assert info["toks_per_firing"] == TRAIN.global_batch // QWEN.grad_accum \
        * TRAIN.seq_len


def test_impl_ii_decreases_with_tp():
    g, _ = lm_graph.build_stg(QWEN, TRAIN)
    for node in g.nodes.values():
        iis = [(im.meta["tp"], im.ii) for im in node.impls]
        iis.sort()
        for (tp1, ii1), (tp2, ii2) in zip(iis, iis[1:]):
            assert ii2 <= ii1 * 1.05, f"{node.name}: II not ~monotone in tp"


def test_memory_filters_small_tp_for_big_stages():
    """Jamba's MoE stages can't fit tp=1 (87GB state vs 12GB usable HBM)."""
    jamba = get_config("jamba-1.5-large-398b")
    g, _ = lm_graph.build_stg(jamba, TRAIN)
    moe_nodes = [n for n in g.nodes.values()
                 if n.name.startswith("block") and
                 any("tp1" != im.name for im in n.impls)]
    has_min = {n.name: min(im.meta["tp"] for im in n.impls)
               for n in g.nodes.values() if n.name.startswith("block")}
    assert max(has_min.values()) >= 8      # MoE stages need tp >= 8
    assert min(has_min.values()) == 1      # mamba-only stages fit tp=1


def test_decode_stage_is_memory_bound():
    g, _ = lm_graph.build_stg(QWEN, DECODE)
    n = g.nodes["block00"]
    im = n.impls[0]
    assert im.meta["memory_us"] > im.meta["compute_us"]


# -------------------------------------------------------------- planner ----
def test_plan_budget_mode_respects_budget():
    for eng in ("ilp", "heuristic"):
        p = planner.plan(QWEN, TRAIN, chips=256, engine=eng)
        assert p.feasible
        assert p.total_chips <= 256 + 1e-6
        assert p.tokens_per_s > 0


def test_plan_target_mode_meets_target():
    p = planner.plan(QWEN, TRAIN, tokens_per_s=5e5)
    assert p.feasible
    assert p.tokens_per_s >= 5e5 * 0.999


def test_more_chips_never_slower():
    p128 = planner.plan(QWEN, TRAIN, chips=128)
    p256 = planner.plan(QWEN, TRAIN, chips=256)
    assert p256.tokens_per_s >= p128.tokens_per_s * 0.999


def test_heuristic_not_worse_than_ilp_at_fixed_target():
    for tps in (5e5, 1e6):
        pi = planner.plan(QWEN, TRAIN, tokens_per_s=tps, engine="ilp")
        ph = planner.plan(QWEN, TRAIN, tokens_per_s=tps, engine="heuristic")
        assert ph.total_chips <= pi.total_chips * 1.02


def test_selection_meets_target_in_stg_semantics():
    """The planner's claim must hold in the paper's own throughput
    analysis, not just in its summary arithmetic."""
    p = planner.plan(QWEN, TRAIN, tokens_per_s=1e6)
    g, info = lm_graph.build_stg(QWEN, TRAIN)
    from repro.core.stg import Selection
    sel = Selection({s.name: (s.impl, s.replicas) for s in p.stages})
    v = analyze(g, sel).v_app
    assert info["toks_per_firing"] / v * 1e6 >= 1e6 * 0.999


def test_execution_projection_divides_chips():
    p = planner.plan(QWEN, TRAIN, chips=256)
    ex = planner.to_execution(p, cfg=QWEN, chips=256)
    assert ex.dp * ex.tp <= 256
    assert 256 % ex.tp == 0
    assert ex.mesh_shape == (ex.dp, ex.tp)


def test_replan_shrink_grow_roundtrip():
    p = planner.plan(QWEN, TRAIN, chips=256)
    small, diff = planner.replan(QWEN, TRAIN, p, new_chips=64)
    assert small.total_chips <= 64 + 1e-6
    assert diff["throughput_ratio"] < 1.0
    big, diff2 = planner.replan(QWEN, TRAIN, small, new_chips=256)
    assert diff2["throughput_ratio"] > 1.0


def test_folded_throughput_prefers_planner_tp_over_tp16():
    """The planner's folded projection beats the naive uniform-TP16 policy
    (the analytic version of the §Perf hillclimb's first move)."""
    p = planner.plan(QWEN, TRAIN, chips=256)
    ex = planner.to_execution(p, cfg=QWEN, chips=256)
    f_plan = planner.folded_tokens_per_s(QWEN, TRAIN, chips=256, tp=ex.tp)
    f_16 = planner.folded_tokens_per_s(QWEN, TRAIN, chips=256, tp=16)
    assert f_plan["tokens_per_s"] > f_16["tokens_per_s"]


def test_all_archs_plan_without_error():
    for arch in ("mamba2-370m", "deepseek-coder-33b",
                 "llama4-scout-17b-a16e", "seamless-m4t-medium"):
        cfg = get_config(arch)
        p = planner.plan(cfg, TRAIN, chips=512)
        assert p.total_chips > 0
        pd = planner.plan(cfg, DECODE, chips=256)
        assert pd.total_chips > 0


def test_plan_both_returns_both_engines():
    d = planner.plan_both(QWEN, TRAIN, chips=128)
    assert set(d) == {"ilp", "heuristic"}
