"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


ATTN_SHAPES = [
    # (B, Sq, Sk, H, KV, D, block_q, block_k)
    (1, 16, 16, 2, 2, 16, 8, 8),       # MHA, tiny
    (2, 64, 64, 4, 2, 32, 16, 16),     # GQA 2:1
    (1, 33, 33, 8, 1, 64, 16, 16),     # MQA, ragged seq
    (2, 32, 128, 4, 4, 32, 16, 32),    # cross/prefix (Sk > Sq)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(shape, dtype, causal):
    b, sq, sk, h, kv, d, bq, bk = shape
    rng = np.random.default_rng(hash((shape, causal)) % 2**31)
    q = _rand(rng, (b, sq, h, d), dtype)
    k = _rand(rng, (b, sk, kv, d), dtype)
    v = _rand(rng, (b, sk, kv, d), dtype)
    off = sk - sq
    got = flash_attention(q, k, v, causal=causal, kv_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, kv_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [1, 7, 16, 64])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(7)
    q = _rand(rng, (2, 48, 4, 32), jnp.float32)
    k = _rand(rng, (2, 48, 2, 32), jnp.float32)
    v = _rand(rng, (2, 48, 2, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_block_sparsity_skips_are_correct():
    """Causal + window => many fully-masked blocks; results must not change."""
    rng = np.random.default_rng(8)
    q = _rand(rng, (1, 256, 2, 16), jnp.float32)
    k = _rand(rng, (1, 256, 2, 16), jnp.float32)
    v = _rand(rng, (1, 256, 2, 16), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=32,
                          block_q=32, block_k=32, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


SSD_SHAPES = [
    # (B, L, H, P, N, chunk)
    (1, 16, 1, 4, 8, 4),
    (2, 64, 3, 8, 16, 16),
    (1, 50, 2, 16, 32, 16),   # ragged
    (2, 128, 4, 64, 128, 32),  # production-like dims
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracle(shape, dtype):
    b, l, h, p, n, chunk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = _rand(rng, (b, l, h, p), dtype)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(b, l, h)), dtype)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bb = _rand(rng, (b, l, n), dtype)
    cc = _rand(rng, (b, l, n), dtype)
    got_y, got_s = ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    want_y, want_s = ref.ssd_reference(x, dt, a, bb, cc)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), **tol)


def test_ssd_chunked_ref_matches_sequential():
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 37, 3, 8), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(2, 37, 3)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(3,)), jnp.float32)
    b = _rand(rng, (2, 37, 16), jnp.float32)
    c = _rand(rng, (2, 37, 16), jnp.float32)
    y1, s1 = ref.ssd_reference(x, dt, a, b, c)
    y2, s2 = ref.ssd_chunked(x, dt, a, b, c, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5, rtol=1e-5)


def test_ssd_decode_step_consistent_with_scan():
    rng = np.random.default_rng(4)
    B, L, H, P, N = 1, 12, 2, 4, 8
    x = _rand(rng, (B, L, H, P), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    b = _rand(rng, (B, L, N), jnp.float32)
    c = _rand(rng, (B, L, N), jnp.float32)
    want_y, want_s = ref.ssd_reference(x, dt, a, b, c)
    s = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        y, s = ref.ssd_decode_step(s, x[:, t], dt[:, t], a, b[:, t], c[:, t])
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y[:, -1]), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", [(4, 16), (3, 5, 64), (2, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    rng = np.random.default_rng(5)
    x = _rand(rng, shape, dtype)
    w = _rand(rng, shape[-1:], jnp.float32)
    got = rmsnorm(x, w, block_rows=2, interpret=True)
    want = ref.rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ops_dispatch_ref_on_cpu():
    assert ops.resolve_impl(None) == "ref"
    assert ops.resolve_impl("interpret") == "interpret"
    rng = np.random.default_rng(6)
    q = _rand(rng, (1, 8, 2, 16), jnp.float32)
    k = _rand(rng, (1, 8, 2, 16), jnp.float32)
    v = _rand(rng, (1, 8, 2, 16), jnp.float32)
    a = ops.attention(q, k, v)          # ref path
    b = ops.attention(q, k, v, impl="interpret", block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
