"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


ATTN_SHAPES = [
    # (B, Sq, Sk, H, KV, D, block_q, block_k)
    (1, 16, 16, 2, 2, 16, 8, 8),       # MHA, tiny
    (2, 64, 64, 4, 2, 32, 16, 16),     # GQA 2:1
    (1, 33, 33, 8, 1, 64, 16, 16),     # MQA, ragged seq
    (2, 32, 128, 4, 4, 32, 16, 32),    # cross/prefix (Sk > Sq)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(shape, dtype, causal):
    b, sq, sk, h, kv, d, bq, bk = shape
    rng = np.random.default_rng(hash((shape, causal)) % 2**31)
    q = _rand(rng, (b, sq, h, d), dtype)
    k = _rand(rng, (b, sk, kv, d), dtype)
    v = _rand(rng, (b, sk, kv, d), dtype)
    off = sk - sq
    got = flash_attention(q, k, v, causal=causal, kv_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, kv_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [1, 7, 16, 64])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(7)
    q = _rand(rng, (2, 48, 4, 32), jnp.float32)
    k = _rand(rng, (2, 48, 2, 32), jnp.float32)
    v = _rand(rng, (2, 48, 2, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_block_sparsity_skips_are_correct():
    """Causal + window => many fully-masked blocks; results must not change."""
    rng = np.random.default_rng(8)
    q = _rand(rng, (1, 256, 2, 16), jnp.float32)
    k = _rand(rng, (1, 256, 2, 16), jnp.float32)
    v = _rand(rng, (1, 256, 2, 16), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=32,
                          block_q=32, block_k=32, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


SSD_SHAPES = [
    # (B, L, H, P, N, chunk)
    (1, 16, 1, 4, 8, 4),
    (2, 64, 3, 8, 16, 16),
    (1, 50, 2, 16, 32, 16),   # ragged
    (2, 128, 4, 64, 128, 32),  # production-like dims
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracle(shape, dtype):
    b, l, h, p, n, chunk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = _rand(rng, (b, l, h, p), dtype)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(b, l, h)), dtype)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bb = _rand(rng, (b, l, n), dtype)
    cc = _rand(rng, (b, l, n), dtype)
    got_y, got_s = ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    want_y, want_s = ref.ssd_reference(x, dt, a, bb, cc)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), **tol)


def test_ssd_chunked_ref_matches_sequential():
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 37, 3, 8), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(2, 37, 3)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(3,)), jnp.float32)
    b = _rand(rng, (2, 37, 16), jnp.float32)
    c = _rand(rng, (2, 37, 16), jnp.float32)
    y1, s1 = ref.ssd_reference(x, dt, a, b, c)
    y2, s2 = ref.ssd_chunked(x, dt, a, b, c, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5, rtol=1e-5)


def test_ssd_decode_step_consistent_with_scan():
    rng = np.random.default_rng(4)
    B, L, H, P, N = 1, 12, 2, 4, 8
    x = _rand(rng, (B, L, H, P), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    b = _rand(rng, (B, L, N), jnp.float32)
    c = _rand(rng, (B, L, N), jnp.float32)
    want_y, want_s = ref.ssd_reference(x, dt, a, b, c)
    s = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        y, s = ref.ssd_decode_step(s, x[:, t], dt[:, t], a, b[:, t], c[:, t])
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y[:, -1]), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", [(4, 16), (3, 5, 64), (2, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    rng = np.random.default_rng(5)
    x = _rand(rng, shape, dtype)
    w = _rand(rng, shape[-1:], jnp.float32)
    got = rmsnorm(x, w, block_rows=2, interpret=True)
    want = ref.rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ops_dispatch_fused_on_cpu():
    assert ops.resolve_impl(None) == "fused"
    assert ops.resolve_impl("interpret") == "interpret"
    assert ops.resolve_impl("ref") == "ref"
    rng = np.random.default_rng(6)
    q = _rand(rng, (1, 8, 2, 16), jnp.float32)
    k = _rand(rng, (1, 8, 2, 16), jnp.float32)
    v = _rand(rng, (1, 8, 2, 16), jnp.float32)
    a = ops.attention(q, k, v)          # fused == ref for prefill wrappers
    b = ops.attention(q, k, v, impl="interpret", block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
    c = ops.attention(q, k, v, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_ops_dispatch_honors_env_var(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    assert ops.resolve_impl(None) == "interpret"
    # explicit per-call / set_default_impl still win over the env var
    assert ops.resolve_impl("ref") == "ref"
    ops.set_default_impl("fused")
    try:
        assert ops.resolve_impl(None) == "fused"
    finally:
        ops.set_default_impl(None)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "bogus")
    assert ops.resolve_impl(None) == "fused"   # unknown names fall to auto


# ===========================================================================
# decode attention (single token over a ring-buffered cache)
# ===========================================================================
DECODE_SHAPES = [
    # (B, H, KV, hd, C, cache_len, window)
    (1, 8, 8, 16, 32, 32, None),    # MHA, full cache
    (2, 8, 4, 32, 64, 17, None),    # GQA 2:1, short prefix masking
    (3, 8, 1, 32, 48, 5, None),     # MQA
    (2, 16, 2, 16, 200, 77, None),  # GQA 8:1, multi-block (block_k=64)
    (2, 8, 4, 32, 64, 64, 30),      # SWA window inside a full ring
    (1, 6, 2, 20, 130, 100, None),  # odd head count / head dim tail
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(shape, dtype):
    from repro.kernels.decode_attention import decode_attention
    b, h, kv, hd, c, clen, window = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = _rand(rng, (b, h, hd), dtype)
    k = _rand(rng, (b, c, kv, hd), dtype)
    v = _rand(rng, (b, c, kv, hd), dtype)
    want = ref.decode_attention_ref(q, k, v, clen, window=window)
    got_k = decode_attention(q, k, v, clen, window=window, block_k=64,
                             interpret=True)
    got_c = ref.decode_attention_chunked(q, k, v, clen, window=window,
                                         block_k=64)
    np.testing.assert_allclose(np.asarray(got_k, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got_c, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_chunked_per_batch_lengths():
    rng = np.random.default_rng(11)
    q = _rand(rng, (3, 8, 32), jnp.float32)
    k = _rand(rng, (3, 40, 4, 32), jnp.float32)
    v = _rand(rng, (3, 40, 4, 32), jnp.float32)
    lens = jnp.asarray([1, 17, 40])
    want = ref.decode_attention_ref(q, k, v, lens[:, None])
    got = ref.decode_attention_chunked(q, k, v, lens, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # the ops wrapper must not hand per-batch lengths to the Pallas kernel
    via_ops = ops.decode_attention(q, k, v, lens, impl="interpret")
    np.testing.assert_allclose(np.asarray(via_ops), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_wraparound():
    """pos > C: every slot is live; kernel == oracle on the wrapped ring."""
    from repro.kernels.decode_attention import decode_attention
    rng = np.random.default_rng(12)
    B, H, KV, hd, C = 2, 8, 4, 32, 24
    q = _rand(rng, (B, H, hd), jnp.float32)
    k = _rand(rng, (B, C, KV, hd), jnp.float32)
    v = _rand(rng, (B, C, KV, hd), jnp.float32)
    for pos in [C, C + 1, 5 * C + 3]:
        clen = min(pos + 1, C)              # what blocks.attn_decode passes
        want = ref.decode_attention_ref(q, k, v, clen)
        got = decode_attention(q, k, v, clen, block_k=8, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_decode_attention_property_bcpos():
    """hypothesis sweep over (B, C, pos): kernel blocking == oracle for any
    ring state, including cache_len < C masking and wrapped positions."""
    from hypothesis import given, settings, strategies as st
    from repro.kernels.decode_attention import decode_attention

    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 3), c=st.integers(1, 70),
           pos=st.integers(0, 200), block=st.sampled_from([8, 32, 128]))
    def prop(b, c, pos, block):
        rng = np.random.default_rng(b * 1000003 + c * 101 + pos)
        H, KV, hd = 4, 2, 16
        q = _rand(rng, (b, H, hd), jnp.float32)
        k = _rand(rng, (b, c, KV, hd), jnp.float32)
        v = _rand(rng, (b, c, KV, hd), jnp.float32)
        clen = min(pos + 1, c)
        want = ref.decode_attention_ref(q, k, v, clen)
        got = decode_attention(q, k, v, clen, block_k=block, interpret=True)
        chk = ref.decode_attention_chunked(q, k, v, clen, block_k=block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(chk), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    prop()


@pytest.mark.parametrize("impl", ["fused", "interpret"])
@pytest.mark.parametrize("pos", [0, 3, 15, 16, 40])
def test_attn_decode_step_matches_historical_body(impl, pos):
    """The fused single-token step (composed XLA and single-Pallas-kernel)
    == the historical op-by-op `blocks.attn_decode` body, across growing
    (pos < C), boundary (pos == C) and wrapped (pos > C) ring states —
    outputs AND the freshly written cache slot."""
    from repro.configs import get_config
    from repro.models import blocks
    from repro.models.common import KeyGen

    cfg = get_config("tiny")
    p = blocks.init_attn(KeyGen(jax.random.PRNGKey(0)), cfg, "t")
    rng = np.random.default_rng(13)
    B, C = 3, 16
    cache = blocks.init_attn_cache(cfg, B, C, jnp.float32)
    cache = {k: _rand(rng, v.shape, jnp.float32) * 0.1
             for k, v in cache.items()}
    x = _rand(rng, (B, 1, cfg.d_model), jnp.float32)
    o_ref, c_ref = blocks.attn_decode(p, cfg, x, cache, jnp.int32(pos),
                                      impl="ref")
    o, c = blocks.attn_decode(p, cfg, x, cache, jnp.int32(pos), impl=impl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)
    for leaf in ("k", "v"):
        np.testing.assert_allclose(np.asarray(c[leaf]),
                                   np.asarray(c_ref[leaf]),
                                   atol=5e-5, rtol=5e-5)
        assert c[leaf].shape == c_ref[leaf].shape
        assert c[leaf].dtype == c_ref[leaf].dtype


def test_cross_attn_decode_dispatches_like_self_attn():
    from repro.configs import get_config
    from repro.models import blocks
    from repro.models.common import KeyGen

    cfg = get_config("tiny")
    a = cfg.attn
    p = blocks.init_attn(KeyGen(jax.random.PRNGKey(1)), cfg, "t")
    rng = np.random.default_rng(14)
    B = 2
    x = _rand(rng, (B, 1, cfg.d_model), jnp.float32)
    enc = (_rand(rng, (B, 7, a.n_kv_heads, a.head_dim), jnp.float32),
           _rand(rng, (B, 7, a.n_kv_heads, a.head_dim), jnp.float32))
    want = blocks.cross_attn_decode(p, cfg, x, enc, impl="ref")
    for impl in ("fused", "interpret"):
        got = blocks.cross_attn_decode(p, cfg, x, enc, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)
