"""Data pipeline determinism/sharding + checkpoint atomicity/retention."""
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step, list_steps,
                              restore_checkpoint, save_checkpoint)
from repro.configs.base import ShapeCfg
from repro.data import DataState, SyntheticBigramLM, SyntheticUniformLM


# ---------------------------------------------------------------- data ----
def test_batch_is_pure_function_of_state():
    pipe = SyntheticBigramLM(vocab=128, seq_len=16, global_batch=8, seed=3)
    s = DataState(step=7, seed=3)
    a = pipe.host_batch(s)
    b = pipe.host_batch(s)
    assert jnp.array_equal(a["tokens"], b["tokens"])
    c = pipe.host_batch(s.advance())
    assert not jnp.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    pipe = SyntheticUniformLM(vocab=64, seq_len=12, global_batch=4, seed=0)
    b = pipe.host_batch(pipe.init_state())
    assert b["tokens"].shape == (1, 4, 12)
    # tokens[t+1] == labels[t] by construction (shared underlying stream)
    assert jnp.array_equal(b["tokens"][0, :, 1:], b["labels"][0, :, :-1])


@settings(max_examples=20, deadline=None)
@given(n_hosts=st.sampled_from([1, 2, 4]), step=st.integers(0, 1000))
def test_host_shards_differ_and_are_deterministic(n_hosts, step):
    """Property: host shards are deterministic and pairwise distinct."""
    pipe = SyntheticUniformLM(vocab=1000, seq_len=8, global_batch=8, seed=1)
    s = DataState(step=step, seed=1)
    shards = [pipe.host_batch(s, host_id=h, n_hosts=n_hosts)
              for h in range(n_hosts)]
    for h, sh in enumerate(shards):
        assert sh["tokens"].shape == (1, 8 // n_hosts, 8)
        again = pipe.host_batch(s, host_id=h, n_hosts=n_hosts)
        assert jnp.array_equal(sh["tokens"], again["tokens"])
    for i in range(n_hosts):
        for j in range(i + 1, n_hosts):
            assert not jnp.array_equal(shards[i]["tokens"],
                                       shards[j]["tokens"])


def test_bigram_tokens_follow_transition_table():
    pipe = SyntheticBigramLM(vocab=64, seq_len=32, global_batch=4, seed=5,
                             branch=4)
    b = pipe.host_batch(pipe.init_state())
    toks = np.asarray(b["tokens"][0])
    labels = np.asarray(b["labels"][0])
    succ = np.asarray(pipe._succ)
    for r in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            assert labels[r, t] in succ[toks[r, t]]


def test_bigram_optimal_loss_is_log_branch():
    pipe = SyntheticBigramLM(vocab=64, seq_len=8, global_batch=2, branch=8)
    assert abs(pipe.optimal_loss() - np.log(8)) < 1e-6


# ---------------------------------------------------------- checkpoint ----
def _tree(step):
    return {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3) + step,
                       "b": np.float32(step)},
            "step": np.int64(step)}


def test_save_restore_roundtrip_bitwise(tmp_path):
    save_checkpoint(tmp_path, 10, _tree(10))
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype),
                        _tree(0))
    tree, meta = restore_checkpoint(tmp_path, like)
    assert meta["step"] == 10
    assert np.array_equal(tree["params"]["w"], _tree(10)["params"]["w"])
    assert tree["params"]["b"] == 10.0


def test_latest_and_retention(tmp_path):
    for s in (5, 10, 15, 20, 25):
        save_checkpoint(tmp_path, s, _tree(s), keep=3)
    assert latest_step(tmp_path) == 25
    assert list_steps(tmp_path) == [15, 20, 25]


def test_keep_every_milestones(tmp_path):
    for s in (10, 20, 30, 40, 50):
        save_checkpoint(tmp_path, s, _tree(s), keep=2, keep_every=30)
    assert set(list_steps(tmp_path)) == {30, 40, 50}


def test_torn_checkpoint_is_invisible(tmp_path):
    """A .tmp dir (simulated crash mid-write) is never listed/restored."""
    save_checkpoint(tmp_path, 1, _tree(1))
    tmp = Path(tmp_path) / ".tmp-2-999-123"
    tmp.mkdir()
    (tmp / "shard-00000.npz").write_bytes(b"garbage")
    assert list_steps(tmp_path) == [1]
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype),
                        _tree(0))
    tree, meta = restore_checkpoint(tmp_path, like)
    assert meta["step"] == 1


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree(1))
    bad = {"params": {"w": jax.ShapeDtypeStruct((3, 3), np.float32),
                      "b": jax.ShapeDtypeStruct((), np.float32)},
           "step": jax.ShapeDtypeStruct((), np.int64)}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, bad)


def test_restore_missing_leaf_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree(1))
    bad = {"params": {"extra": jax.ShapeDtypeStruct((2,), np.float32)}}
    with pytest.raises(ValueError, match="missing"):
        restore_checkpoint(tmp_path, bad)


def test_async_checkpointer_orders_and_drains(tmp_path):
    with AsyncCheckpointer(tmp_path, keep=10) as ck:
        for s in (1, 2, 3):
            ck.save(s, _tree(s))
    assert list_steps(tmp_path) == [1, 2, 3]


def test_async_snapshot_isolated_from_later_mutation(tmp_path):
    """save() must snapshot: mutating the tree afterwards can't corrupt."""
    tree = _tree(7)
    with AsyncCheckpointer(tmp_path) as ck:
        ck.save(7, tree)
        tree["params"]["w"] += 999  # mutate after enqueue
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype),
                        _tree(0))
    restored, _ = restore_checkpoint(tmp_path, like)
    assert restored["params"]["w"].max() < 100
