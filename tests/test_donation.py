"""Zero-copy hot path: buffer donation, AOT precompile, async retirement.

Acceptance contract of the donation/AOT rework:
  * decode steady state allocates **no new KV-cache buffers per token** —
    the donated block-decode program aliases every cache leaf in place
    (verified by buffer pointer), and the donation contract of
    `models/lm.decode_blocks` (cache-out avals == cache-in avals) holds
    structurally for every leaf;
  * donation changes *allocation behaviour, not results*: donated decode
    tokens are identical to the non-donated single-device `serve_round`,
    and donated-accumulate 1F1B / interleaved grads stay bitwise-equal to
    sequential autodiff;
  * no use-after-donate under overlap + prefetch (stale reads raise, the
    pipelines never trigger one);
  * every stage program is compiled before the first op of a timed run
    (``compile_stats.late == 0``), and the engine exposes per-stage host
    dispatch overhead as its own measurement column.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeCfg
from repro.configs.tiny import CONFIG as tiny
from repro.core import planner
from repro.core.stg import Selection
from repro.graphs import lm_graph
from repro.models import lm
from repro.runtime.pipeline import (AotProgram, CompileStats, DecodePipeline,
                                    LMPipeline, selection_from_plan)
from repro.runtime.server import LMServer, Request



@pytest.fixture(scope="module")
def decode_setup():
    shape = ShapeCfg("donate_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    return plan, stg


@pytest.fixture(scope="module")
def lm_setup():
    shape = ShapeCfg("donate_lm", 16, 8, "train")
    plan = planner.plan(tiny, shape, chips=16, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = LMPipeline(tiny, stg, selection_from_plan(plan))
    rng = np.random.default_rng(3)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 16)), jnp.int32)
           for _ in range(5)]
    return pipe, mbs


# ===========================================================================
# donation mechanics
# ===========================================================================
def test_decode_cache_donation_aliases_every_leaf(decode_setup):
    """One decode step through the donated block program updates the
    resident cache slice IN PLACE: the old buffers are deleted, the new
    cache's leaves live at the same addresses (zero new allocations), and
    reading a donated buffer raises instead of silently reusing it."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    s = 1                                          # first block stage
    params = pipe.stage_params[s][0]
    dev = pipe.stage_devices[s][0]
    B, bucket, cap = 2, 16, 24
    x = jax.device_put(jnp.zeros((B, bucket, tiny.d_model), jnp.bfloat16),
                       dev)
    _, cache = pipe._block_prefill(params, x, cap)
    old_leaves = jax.tree.leaves(cache)
    ptrs_in = [l.unsafe_buffer_pointer() for l in old_leaves]
    xd = jax.device_put(jnp.zeros((B, 1, tiny.d_model), jnp.bfloat16), dev)
    pos = jax.device_put(jnp.asarray(bucket, jnp.int32), dev)
    h, cache2 = pipe._block_decode(params, cache, xd, pos)
    jax.block_until_ready(h)
    assert all(l.is_deleted() for l in old_leaves), \
        "donated cache inputs must be consumed"
    ptrs_out = [l.unsafe_buffer_pointer() for l in jax.tree.leaves(cache2)]
    assert ptrs_out == ptrs_in, \
        "every cache leaf must alias in place (no new buffers per token)"
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(old_leaves[0])                  # use-after-donate is loud


def test_decode_blocks_signature_is_donation_safe():
    """`lm.decode_cache_structs`: the cache a decode step returns matches
    the cache it consumed aval-for-aval — the structural precondition for
    full aliasing, checked for every leaf of a real (sub-)stack."""
    params = lm.init_params(tiny, jax.random.PRNGKey(0))
    sub = lm.slice_periods(params["layers"], 0, tiny.n_periods)
    cin, cout = lm.decode_cache_structs(tiny, sub, batch=2, prompt=8, cap=16)
    assert jax.tree.structure(cin) == jax.tree.structure(cout)
    for a, b in zip(jax.tree.leaves(cin), jax.tree.leaves(cout)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_donated_accumulate_matches_tree_map_add():
    """The donated in-place grad accumulate is bitwise-equal to the
    host-driven per-leaf `jax.tree.map(jnp.add, ...)` it replaced, and
    consumes its acc argument."""
    from repro.runtime.pipeline import tree_add_program
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    upd = jax.tree.map(lambda l: l * 0.5, tree)
    ref = jax.tree.map(jnp.add, tree, upd)
    acc = jax.tree.map(lambda l: l + 0, tree)      # fresh donatable copy
    old = jax.tree.leaves(acc)
    prog = tree_add_program("t.acc", CompileStats())
    out = prog(acc, upd)
    jax.block_until_ready(out)
    assert all(l.is_deleted() for l in old)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 8), st.integers(1, 8), st.integers(2, 5),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_donated_accumulate_fold_property(rows, cols, folds, seed):
    """Property: folding ``folds`` random updates through the donated
    accumulator equals the eager per-leaf add chain bitwise for arbitrary
    leaf shapes and fold lengths, and every intermediate acc buffer is
    consumed (one live accumulator at any time)."""
    from repro.runtime.pipeline import tree_add_program
    rng = np.random.default_rng(seed)
    updates = [{"a": jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(cols,)), jnp.float32)}
               for _ in range(folds)]
    ref = updates[0]
    for u in updates[1:]:
        ref = jax.tree.map(jnp.add, ref, u)
    prog = tree_add_program("p.acc", CompileStats())
    acc = jax.tree.map(lambda l: l + 0, updates[0])
    for u in updates[1:]:
        old = jax.tree.leaves(acc)
        acc = prog(acc, u)
        jax.block_until_ready(acc)
        assert all(l.is_deleted() for l in old)
    for a, b in zip(jax.tree.leaves(acc), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ===========================================================================
# donation changes allocation, not results
# ===========================================================================
def test_donated_decode_tokens_equal_single_device(decode_setup):
    """Pipelined serve (donated caches, AOT programs, async retirement,
    overlap + prefetch on) is token-identical to the non-donated
    single-device `serve_round` — and no op tripped a use-after-donate."""
    plan, stg = decode_setup
    rng = np.random.default_rng(11)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, tiny.vocab,
                                        rng.integers(4, 20)).tolist(),
                    max_new=8)
            for i in range(8)]
    pipe = DecodePipeline(tiny, stg, plan)
    out_p = LMServer(tiny, max_batch=4, pipeline=pipe).serve(reqs)
    out_r = LMServer(tiny, max_batch=4).serve(reqs)
    for a, b in zip(out_p, out_r):
        assert a.tokens == b.tokens


def test_donated_accumulate_grads_bitwise_equal_sequential(lm_setup):
    """1F1B with the donated accumulator reproduces the sequential eager
    vjp-chain grads BITWISE (same fold order, same adds — donation only
    changed where the sums live)."""
    pipe, mbs = lm_setup
    loss = lambda lg: jnp.sum(lg * lg) / lg.size
    res = pipe.run(mbs, train=True, loss_fn=loss)

    grads = {st.name: None for st in pipe.stages}
    for mb in mbs:
        x = mb
        vjps = []
        for st in pipe.stages:
            x = jax.device_put(x, st.x_target(0))
            y, vjp = jax.vjp(st.fwd, st.params[0], x)
            vjps.append(vjp)
            x = y
        _, y_bar = jax.value_and_grad(loss)(x)
        for st, vjp in reversed(list(zip(pipe.stages, vjps))):
            p_bar, y_bar = vjp(y_bar)
            pb = jax.device_put(p_bar, st.grad_target())
            grads[st.name] = (pb if grads[st.name] is None else
                              jax.tree.map(jnp.add, grads[st.name], pb))
    for st in pipe.stages:
        for a, b in zip(jax.tree.leaves(res.grads[st.name]),
                        jax.tree.leaves(grads[st.name])):
            assert (np.asarray(a) == np.asarray(b)).all(), st.name


def test_interleaved_grads_bitwise_stable_under_donation(lm_setup):
    """Plain vs interleaved 1F1B still agree bitwise with the donated
    accumulator in the loop (per-built-stage fold order is schedule-
    independent)."""
    from repro.runtime.pipeline import interleaved_1f1b, one_f_one_b
    shape = ShapeCfg("donate_ilv", 16, 8, "train")
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = LMPipeline(tiny, stg, Selection.smallest(stg), layers_per_stage=2)
    rng = np.random.default_rng(5)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (1, 16)), jnp.int32)
           for _ in range(4)]
    loss = lambda lg: jnp.mean(lg * lg)
    M = pipe.n_stages
    r_plain = pipe.run(mbs, train=True, loss_fn=loss,
                       schedule=one_f_one_b(M, len(mbs)))
    r_ilv = pipe.run(mbs, train=True, loss_fn=loss,
                     schedule=interleaved_1f1b(M // 2, len(mbs), 2))
    for st in pipe.stages:
        for a, b in zip(jax.tree.leaves(r_plain.grads[st.name]),
                        jax.tree.leaves(r_ilv.grads[st.name])):
            assert (np.asarray(a) == np.asarray(b)).all(), st.name


@pytest.mark.parametrize("group_size,max_new", [(1, 3), (2, 6), (3, 2)])
def test_no_use_after_donate_under_overlap_and_prefetch(decode_setup,
                                                        group_size, max_new):
    """Any grouping/budget under full overlap + prefetch + tight channel
    capacity serves to completion without a use-after-donate (a deleted
    buffer read raises RuntimeError — the engine surfaces it, never
    wedges) and with a drained token stream."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    prompts = [list(range(2, 8)), list(range(3, 12)), list(range(2, 6)),
               list(range(4, 10))]
    run = pipe.serve(prompts, max_new, group_size=group_size,
                     capacity_blocks=1)
    assert all(1 <= len(t) <= max_new for t in run.tokens)


# ===========================================================================
# AOT precompile: no compiles inside timed runs
# ===========================================================================
def test_no_compiles_inside_timed_serve(decode_setup):
    """With warmup on (default), every program is compiled before the
    engine's clock starts: `compile_stats.late == 0` across repeated
    serves and fresh shape classes."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    pipe.serve([list(range(2, 10))] * 4, 5, group_size=2)
    pipe.serve([list(range(2, 30))] * 2, 7, group_size=2)   # new bucket
    assert pipe.compile_stats.late == 0, pipe.compile_stats.summary()
    assert pipe.compile_stats.compiles > 0
    assert pipe.compile_stats.calls > 0


def test_no_compiles_inside_timed_lm_run(lm_setup):
    pipe, mbs = lm_setup
    pipe.run(mbs)
    pipe.run(mbs, train=True,
             loss_fn=lambda lg: jnp.sum(lg * lg) / lg.size)
    assert pipe.compile_stats.late == 0, pipe.compile_stats.summary()


def test_warmup_escape_hatch_counts_late_compiles(decode_setup):
    """``warmup=False`` skips precompile; the compiles that then land
    inside the timed window are counted — the measurement the default
    mode exists to keep at zero."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan, warmup=False)
    pipe.serve([list(range(2, 40))] * 2, 4, group_size=2)
    assert pipe.compile_stats.late > 0


def test_aot_program_is_traceable_and_bitwise_equal_jit():
    """An AotProgram is a drop-in for the jit it wraps: concrete calls
    (compiled path) match the jit bitwise, and `jax.vjp` traces through
    it (the train path's contract)."""
    def fn(p, x):
        return (x @ p["w"]).astype(jnp.float32)

    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
    prog = AotProgram(fn, name="t")
    jit_out = jax.jit(fn)(p, x)
    np.testing.assert_array_equal(np.asarray(prog(p, x)), np.asarray(jit_out))
    y, vjp = jax.vjp(prog, p, x)
    ref_y, ref_vjp = jax.vjp(jax.jit(fn), p, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref_y))
    g = vjp(jnp.ones_like(y))
    rg = ref_vjp(jnp.ones_like(ref_y))
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(rg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_aot_precompile_from_structs_hits_at_runtime():
    """precompile() with ShapeDtypeStructs (sharding attached) builds the
    executable the concrete call then hits — zero cache-miss compiles."""
    from jax.sharding import SingleDeviceSharding
    def fn(p, x):
        return x * p

    stats = CompileStats()
    prog = AotProgram(fn, name="t", stats=stats)
    dev = jax.devices()[0]
    sh = SingleDeviceSharding(dev)
    prog.precompile(jax.ShapeDtypeStruct((4,), jnp.float32, sharding=sh),
                    jax.ShapeDtypeStruct((4,), jnp.float32, sharding=sh))
    assert stats.compiles == 1
    p = jax.device_put(jnp.ones((4,), jnp.float32), dev)
    x = jax.device_put(jnp.arange(4, dtype=jnp.float32), dev)
    out = prog(p, x)
    assert stats.compiles == 1 and stats.misses == 0 and stats.late == 0
    np.testing.assert_array_equal(np.asarray(out), np.arange(4, dtype=np.float32))


def test_shared_embed_program_one_compile_per_aval(decode_setup):
    """The satellite fix: prefill and decode embed share ONE program (the
    old pair of jit instances of the same function paid separate compile
    caches) — identical avals compile once."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    assert not hasattr(pipe, "_embed_prefill") and \
        not hasattr(pipe, "_embed_decode")
    pipe.serve([list(range(2, 10))] * 2, 4, group_size=2)
    n0 = pipe._embed.n_compiled
    # decode embed aval (B, 1) already compiled: a second serve with the
    # same grouping adds no embed executables
    pipe.serve([list(range(2, 10))] * 2, 4, group_size=2)
    assert pipe._embed.n_compiled == n0


# ===========================================================================
# host-overhead accounting
# ===========================================================================
def test_host_overhead_surfaces_in_report(lm_setup):
    from repro.runtime.pipeline import compare_lm
    shape = ShapeCfg("donate_lm", 16, 8, "train")
    plan = planner.plan(tiny, shape, chips=16, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe, mbs = lm_setup
    res = pipe.run(mbs * 2)
    for st in pipe.stages:
        assert res.stage_host_us(st.name) > 0
    rep = compare_lm(stg, selection_from_plan(plan), res)
    assert any(m.host_v is not None and m.host_v > 0
               for m in rep.stages.values())
    assert "host" in rep.summary()
    # host overhead must be a component of, not exceed, total stage time
    for st in pipe.stages:
        assert (res.stage_dispatch_s[st.name]
                <= res.stage_seconds[st.name] + 1e-6)


def test_serve_run_reports_host_overhead(decode_setup):
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    run = pipe.serve([list(range(2, 12))] * 4, 6, group_size=2)
    for name in pipe.stage_names:
        assert run.stage_host_us(name) > 0


# ===========================================================================
# fused decode kernels keep the donation contract
# ===========================================================================
@pytest.mark.parametrize("impl", ["ref", "fused", "interpret"])
def test_fused_step_cache_out_aval_matches_contract(impl):
    """The fused single-token step must return caches with EXACTLY the
    avals `decode_cache_structs` promises — leaf-for-leaf — under every
    kernel impl, or cache donation would silently stop aliasing."""
    import functools
    params = lm.init_params(tiny, jax.random.PRNGKey(0))
    sub = lm.slice_periods(params["layers"], 0, tiny.n_periods)
    cin, cout = lm.decode_cache_structs(tiny, sub, batch=2, prompt=8, cap=16)
    step = functools.partial(lm.decode_blocks, tiny, impl=impl)
    x = jax.ShapeDtypeStruct((2, 1, tiny.d_model), jnp.bfloat16)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    _, got = jax.eval_shape(step, sub, cin, x, pos)
    assert jax.tree.structure(got) == jax.tree.structure(cout)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(cout)):
        assert a.shape == b.shape and a.dtype == b.dtype, impl


def test_single_device_server_decode_is_donated():
    """PR-5 leftover: `LMServer`'s non-pipelined decode loop compiles
    `decode_step` with the cache donated — every leaf aliases in place
    (zero new cache allocations per token) and a stale read is loud."""
    srv = LMServer(tiny, max_batch=2)
    batch = {"tokens": jnp.asarray([[2, 3, 4, 5], [3, 4, 5, 6]], jnp.int32)}
    _, cache = srv._prefill(srv.params, batch, 12)
    old_leaves = [l for l in jax.tree.leaves(cache)
                  if hasattr(l, "unsafe_buffer_pointer")]
    ptrs_in = sorted(l.unsafe_buffer_pointer() for l in old_leaves
                     if l.ndim >= 2)          # cache tensors, not pos scalar
    cur = jnp.asarray([[7], [8]], jnp.int32)
    _, cache2 = srv._decode(srv.params, cache, cur)
    jax.block_until_ready(jax.tree.leaves(cache2))
    assert all(l.is_deleted() for l in old_leaves)
    ptrs_out = sorted(l.unsafe_buffer_pointer()
                      for l in jax.tree.leaves(cache2) if l.ndim >= 2)
    assert ptrs_out == ptrs_in, "cache leaves must alias in place"
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(old_leaves[0])


def test_single_device_tokens_identical_across_impls():
    """Acceptance pin: the donated fused-kernel server decodes the SAME
    tokens as the historical (`impl="ref"`) single-device path, and the
    interpret-mode Pallas kernels agree too (greedy argmax is stable
    across the allclose-level numeric differences)."""
    rng = np.random.default_rng(21)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, tiny.vocab,
                                        rng.integers(4, 16)).tolist(),
                    max_new=6)
            for i in range(4)]
    outs = {impl: LMServer(tiny, max_batch=2, impl=impl).serve(reqs)
            for impl in ("ref", "fused", "interpret")}
    for impl in ("fused", "interpret"):
        for a, b in zip(outs["ref"], outs[impl]):
            assert a.tokens == b.tokens, impl


_TP_DONATE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.tiny import CONFIG as tiny
from repro.models import lm

assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()[:2]), ("tp",))
params = lm.init_params(tiny, jax.random.PRNGKey(0))
sub = lm.slice_periods(params["layers"], 0, tiny.n_periods)

prefill = jax.jit(functools.partial(lm.prefill_blocks, tiny, impl="fused"),
                  static_argnames=("cap",))
x = jnp.zeros((2, 8, tiny.d_model), jnp.bfloat16)
_, cache = prefill(sub, x, jnp.arange(8), cap=16)

# shard every cache leaf over the kv-head axis of the 2-way tp sub-mesh
def shard(l):
    spec = [None] * l.ndim
    spec[3] = "tp"            # (layers, B, C, KV, hd) stacked leaf
    return jax.device_put(l, NamedSharding(mesh, P(*spec)))
cache = jax.tree.map(shard, cache)

step = jax.jit(functools.partial(lm.decode_blocks, tiny, impl="fused"),
               donate_argnums=(1,))
old = jax.tree.leaves(cache)
shardings_in = [l.sharding for l in old]
ptrs_in = sorted(s.data.unsafe_buffer_pointer()
                 for l in old for s in l.addressable_shards)
xd = jnp.zeros((2, 1, tiny.d_model), jnp.bfloat16)
_, cache2 = step(sub, cache, xd, jnp.asarray(8, jnp.int32))
jax.block_until_ready(jax.tree.leaves(cache2))
assert all(l.is_deleted() for l in old), "tp-sharded donation must consume"
ptrs_out = sorted(s.data.unsafe_buffer_pointer()
                  for l in jax.tree.leaves(cache2)
                  for s in l.addressable_shards)
assert ptrs_out == ptrs_in, "every shard must alias in place"
for l, sh in zip(jax.tree.leaves(cache2), shardings_in):
    assert l.sharding.is_equivalent_to(sh, l.ndim), \
        "donation must preserve the tp sharding"
print("TP_DONATE_OK")
"""


def test_tp_sharded_decode_cache_donation():
    """PR-5 leftover: donation still aliases shard-for-shard when the
    decode cache is tp-sharded over a sub-mesh (8 simulated devices,
    kv-head axis partitioned 2-way) — run in a subprocess so the forced
    device count cannot leak into this process's backend."""
    import subprocess
    import sys
    import os
    r = subprocess.run([sys.executable, "-c", _TP_DONATE],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2500:])
    assert "TP_DONATE_OK" in r.stdout
