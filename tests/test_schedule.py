"""Schedules as first-class plan objects + the one-Program/two-drivers
contract.

Acceptance contract:
  * `interleaved_1f1b` satisfies its structural invariants for every
    (p, m, v) shape (hypothesis properties): each (mb, chunk) forward
    precedes its backward per stage, per-stage live activations respect
    the analytic bound, and flattening the schedule covers every op
    exactly once;
  * the same `ScheduleProgram` objects execute under BOTH drivers — the
    wall-clock `Engine` and the virtual-clock `run_event_loop` — with
    identical per-stage firing order and dependency-consistent timing;
  * the virtual-clock simulation reproduces the analytic bubble ceilings
    and shows interleaved 1F1B strictly below plain 1F1B.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.pipeline import (Engine, SchedOp, Schedule, fill_drain,
                                    interleaved_1f1b, interleaved_bubble,
                                    max_live_activations, max_live_by_chunk,
                                    measured_bubble, one_f_one_b,
                                    run_event_loop, schedule_programs,
                                    simulate_schedule)


# ===========================================================================
# interleaved_1f1b properties
# ===========================================================================
@settings(max_examples=40)
@given(p=st.integers(1, 6), mult=st.integers(1, 4), v=st.integers(1, 4))
def test_interleaved_f_precedes_b_per_mb_chunk(p, mult, v):
    m = p * mult
    sched = interleaved_1f1b(p, m, v)
    for ops in sched:
        seen_f = set()
        for op in ops:
            if op.kind == "F":
                seen_f.add((op.mb, op.chunk))
            else:
                assert (op.mb, op.chunk) in seen_f, \
                    f"B(mb={op.mb},chunk={op.chunk}) before its F"


@settings(max_examples=40)
@given(p=st.integers(1, 6), mult=st.integers(1, 4), v=st.integers(1, 4))
def test_interleaved_live_activations_within_analytic_bound(p, mult, v):
    m = p * mult
    sched = interleaved_1f1b(p, m, v)
    for s, ops in enumerate(sched):
        live = max_live_activations(ops)
        assert live <= sched.live_bounds[s]
        # the analytic form the bound was derived from
        if v > 1 and m > p:
            assert sched.live_bounds[s] <= min(
                m * v, (p - s - 1) * 2 + (v - 1) * p + 1)
        # chunk-aware accounting is consistent with the total
        by_chunk = max_live_by_chunk(ops)
        assert set(by_chunk) == set(range(sched.n_chunks))
        assert live <= sum(by_chunk.values())


@settings(max_examples=40)
@given(p=st.integers(1, 6), mult=st.integers(1, 4), v=st.integers(1, 4))
def test_interleaved_flatten_covers_every_op_exactly_once(p, mult, v):
    m = p * mult
    sched = interleaved_1f1b(p, m, v)
    want = sorted([(kind, mb, c) for kind in ("F", "B")
                   for mb in range(m) for c in range(sched.n_chunks)])
    per_stage: dict[int, list] = {}
    for s, op in sched.flatten():
        per_stage.setdefault(s, []).append(tuple(op))
    assert set(per_stage) == set(range(sched.n_stages))
    for s, ops in per_stage.items():
        assert sorted(ops) == want, f"stage {s} op coverage broke"


def test_interleaved_requires_micro_multiple_of_stages():
    with pytest.raises(ValueError, match="multiple of"):
        interleaved_1f1b(4, 6, 2)
    # v == 1 is plain 1F1B: no multiple-of constraint
    assert interleaved_1f1b(4, 6, 1).stage_ops == one_f_one_b(4, 6).stage_ops


def test_shape_validation_is_shared():
    for bad in (lambda: one_f_one_b(0, 4), lambda: fill_drain(4, 0),
                lambda: interleaved_1f1b(4, 4, 0),
                lambda: interleaved_bubble(0, 4, 1)):
        with pytest.raises(ValueError, match="bad schedule shape"):
            bad()


def test_validate_rejects_corrupt_schedules():
    good = one_f_one_b(2, 2)
    # B before its F (coverage intact: same ops, bad order)
    bad = Schedule("bad", 2, 2, 1,
                   [[SchedOp("B", 0), SchedOp("F", 0), SchedOp("F", 1),
                     SchedOp("B", 1)], good.stage_ops[1]], good.live_bounds)
    with pytest.raises(ValueError, match="before its F"):
        bad.validate()
    # incomplete forward coverage
    bad2 = Schedule("bad2", 2, 2, 1,
                    [good.stage_ops[0][:-1], good.stage_ops[1]],
                    good.live_bounds)
    with pytest.raises(ValueError, match="cover"):
        bad2.validate()
    # live activations beyond the declared bound
    bad3 = Schedule("bad3", 2, 2, 1, good.stage_ops, [1, 1])
    with pytest.raises(ValueError, match="live"):
        bad3.validate()


def test_max_live_by_chunk_matches_plain_accounting():
    ops = one_f_one_b(4, 8).stage_ops[0]
    assert max_live_by_chunk(ops) == {0: max_live_activations(ops)}
    ilv = interleaved_1f1b(2, 4, 2).stage_ops[0]
    by_chunk = max_live_by_chunk(ilv)
    assert set(by_chunk) == {0, 1} and all(v >= 1 for v in by_chunk.values())


# ===========================================================================
# analytic bubble models
# ===========================================================================
def test_interleaved_bubble_divides_warmup_cost():
    assert interleaved_bubble(4, 8, 1) == pytest.approx(3 / 11)
    assert interleaved_bubble(4, 8, 2) == pytest.approx(3 / 19)
    assert interleaved_bubble(1, 8, 4) == 0.0
    for v in (2, 3, 4):
        assert interleaved_bubble(4, 8, v) < interleaved_bubble(4, 8, v - 1)


# ===========================================================================
# the schedule executed as data: virtual-clock measurement
# ===========================================================================
def test_simulated_bubbles_match_analytic_and_interleaved_wins():
    p, m, v = 4, 8, 2
    plain = simulate_schedule(one_f_one_b(p, m), f_cost=float(v))
    ilv = simulate_schedule(interleaved_1f1b(p, m, v))
    assert plain.bubble == pytest.approx(interleaved_bubble(p, m, 1))
    assert ilv.bubble == pytest.approx(interleaved_bubble(p, m, v))
    assert ilv.bubble < plain.bubble          # the payoff, measured
    # measured_bubble reads the same number off the event-loop stats
    assert measured_bubble(plain.stats) == pytest.approx(plain.bubble)


def test_simulate_schedule_raises_on_wedged_schedules():
    # stage 1 demands mb 1 first, but the act fifo's head is mb 0 and
    # capacity 1 leaves no room to skip ahead: stage 0 stalls forever
    bad = Schedule("wedge", 2, 2, 1,
                   [[SchedOp("F", 0), SchedOp("F", 1)],
                    [SchedOp("F", 1), SchedOp("F", 0)]], [2, 2])
    with pytest.raises((RuntimeError, AssertionError)):
        simulate_schedule(bad, capacity_blocks=1)


# ===========================================================================
# one Program, two drivers
# ===========================================================================
def _trace_precedence_ok(trace, sched):
    """Every model-stage-i op starts at/after its producer's completion
    (activations forward; for B ops, gradients backward)."""
    p = sched.n_stages
    done = {}                                # ("F"/"B", mb, model_i) -> t_done
    for s, kind, mb, chunk, t0, t1 in trace:
        done[(kind, mb, chunk * p + s)] = t1
    M = sched.n_model_stages
    for s, kind, mb, chunk, t0, t1 in trace:
        i = chunk * p + s
        if kind == "F" and i > 0:
            assert t0 >= done[("F", mb, i - 1)] - 1e-9
        if kind == "B" and i < M - 1:
            assert t0 >= done[("B", mb, i + 1)] - 1e-9
    return True


@pytest.mark.parametrize("make", [
    lambda: one_f_one_b(3, 4),
    lambda: interleaved_1f1b(2, 4, 2),
    lambda: fill_drain(3, 4),
])
def test_both_drivers_run_the_same_program(make):
    """The two-drivers contract: identical `ScheduleProgram` op streams
    execute to completion under the wall-clock Engine and the
    virtual-clock event loop, firing each stage's ops in schedule order
    with dependency-consistent timing in both domains."""
    sched = make()

    # virtual clock
    vprogs, vtrace = schedule_programs(sched)
    vstats = run_event_loop({p.name: p for p in vprogs})
    assert all(p.pending() == 0 for p in vprogs)

    # wall clock (serial baseline: deterministic scheduling, no sleeps)
    wprogs, wtrace = schedule_programs(sched)
    Engine(wprogs, overlap=False).run()
    assert all(p.pending() == 0 for p in wprogs)

    for trace in (vtrace, wtrace):
        assert len(trace) == len(sched.flatten())
        per_stage: dict[int, list] = {}
        for s, kind, mb, chunk, _, _ in trace:
            per_stage.setdefault(s, []).append(SchedOp(kind, mb, chunk))
        # each driver fired each stage's ops in exactly schedule order
        assert per_stage == {s: list(ops)
                             for s, ops in enumerate(sched.stage_ops)}
        assert _trace_precedence_ok(trace, sched)
    # and the virtual domain's firing counts match the wall domain's
    assert {p.name: vstats.fired[p.name] for p in vprogs} == \
        {s: len(ops) for s, ops in
         ((p.name, p.ops) for p in wprogs)}


def test_wall_engine_deadlock_names_schedule_position():
    """A wedged run's diagnostic points at the schedule line: next op
    index and (kind, mb, chunk) — not just a FIFO."""
    bad = Schedule("stuck", 2, 2, 1,
                   [[SchedOp("F", 0), SchedOp("F", 1)], []], [2, 0])
    progs, _ = schedule_programs(bad, capacity_blocks=1)
    with pytest.raises(RuntimeError, match=r"deadlock.*stage0: op 1/2 "
                                           r"next=F\(mb=1,chunk=0\)"):
        Engine(progs, overlap=False).run()
