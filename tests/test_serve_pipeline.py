"""Decode-shape serving pipelines (runtime/pipeline/decode + engine core).

Acceptance contract:
  * decode through the pipelined `LMServer` produces token-identical
    completions to the single-device ``serve_round`` (greedy sampling) —
    in-process and on an 8-device pool (subprocess);
  * per-stage prefill/decode math is the *same code* the single-device
    path runs (`models/lm.prefill_blocks` / `decode_blocks` over
    `slice_periods`);
  * `channels.StreamChannel` carries the continuous decode token stream
    with open/close semantics;
  * the graph-generic engine drives dynamically-growing op queues to
    quiescence and frees channel credits when an op's body raises.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.tiny import CONFIG as tiny
from repro.core import planner
from repro.graphs import lm_graph
from repro.runtime.pipeline import (DecodePipeline, Engine, Fifo, Op,
                                    StreamChannel)
from repro.runtime.server import LMServer, Request


@pytest.fixture(scope="module")
def decode_setup():
    shape = ShapeCfg("decode_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    return plan, stg


def _reqs(n, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, tiny.vocab,
                                        rng.integers(4, 20)).tolist(),
                    max_new=max_new)
            for i in range(n)]


# ===========================================================================
# token parity with the single-device server
# ===========================================================================
def test_pipelined_server_token_identical(decode_setup):
    """Same seed, same grouping: the pipelined backend must generate the
    exact token sequences of the single-device prefill/decode loop."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    reqs = _reqs(8)
    out_p = LMServer(tiny, max_batch=4, pipeline=pipe).serve(reqs)
    out_r = LMServer(tiny, max_batch=4).serve(reqs)
    assert len(out_p) == len(out_r) == len(reqs)
    for a, b in zip(out_p, out_r):
        assert a.uid == b.uid
        assert a.tokens == b.tokens, (a.uid, a.tokens, b.tokens)
        assert a.prompt_len == b.prompt_len


def test_pipelined_server_respects_budgets(decode_setup):
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    reqs = _reqs(4, seed=1, max_new=3)
    outs = LMServer(tiny, max_batch=4, pipeline=pipe).serve(reqs)
    for c in outs:
        assert 1 <= len(c.tokens) <= 3
        assert c.prefill_s >= 0 and c.decode_s >= 0


def test_pipelined_server_overlap_off_matches(decode_setup):
    """The serial A/B baseline (overlap=False) runs the same stage graph
    and must produce identical tokens."""
    plan, stg = decode_setup
    reqs = _reqs(8, seed=2)
    on = LMServer(tiny, max_batch=4,
                  pipeline=DecodePipeline(tiny, stg, plan)).serve(reqs)
    off = LMServer(tiny, max_batch=4,
                   pipeline=DecodePipeline(tiny, stg, plan,
                                           overlap=False)).serve(reqs)
    for a, b in zip(on, off):
        assert a.tokens == b.tokens


def test_serve_run_measurement_surface(decode_setup):
    """A pipelined serve emits the engine's measurement surface: stage
    completion streams, decode tokens/s, per-token latency samples."""
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    run = pipe.serve([list(range(2, 12))] * 8, 12, group_size=4)
    assert run.decode_tokens > 0 and run.prefill_tokens > 0
    assert run.decode_tokens_per_s() > 0
    lats = run.token_latencies_s()
    assert lats and all(l >= 0 for l in lats)
    assert set(run.stage_done_s) == set(pipe.stage_names)
    # every stage fired once per scheduled op (prefill + decode steps)
    firings = set(run.stage_firings.values())
    assert len(firings) == 1            # linear chain: same op count per stage
    assert run.fifo_stats["feedback"].pushes > 0


def test_serve_run_is_a_calibration_source(decode_setup):
    """A serve run's completion streams flow through the same
    measure.compare_lm core as LM microbatch runs (one comparison logic,
    no serving special case) and on into planner.replan."""
    from repro.runtime.pipeline import as_selection, compare_lm

    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    run = pipe.serve([list(range(2, 12))] * 8, 16, group_size=4)
    rep = compare_lm(stg, as_selection(plan), run,
                     stage_map=pipe.graph_stage_map())
    assert rep.bottleneck_measured in rep.stages
    ratios = rep.ratios()
    assert ratios and all(r > 0 for r in ratios.values())
    new, diff = planner.replan(
        tiny, ShapeCfg("decode_test", 64, 16, "decode"), plan,
        new_chips=8, measured_ratio=ratios, max_tp=4)
    assert new.feasible and "throughput_ratio" in diff


def test_pipelined_server_token_identical_with_attention_window(decode_setup):
    """SWA configs ring-buffer the KV cache at the attention window: the
    pipeline must apply the same capacity clamp as lm.prefill or it
    attends further back than the single-device server."""
    from dataclasses import replace
    swa = replace(tiny, name="tiny-swa", attn=replace(tiny.attn, window=16))
    shape = ShapeCfg("decode_swa", 64, 16, "decode")
    plan = planner.plan(swa, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(swa, shape, max_tp=4)
    pipe = DecodePipeline(swa, stg, plan)
    # prompts longer than the window so the ring buffer actually wraps
    reqs = _reqs(4, seed=7, max_new=8)
    for r in reqs:
        r.prompt = (r.prompt * 4)[:30]
    out_p = LMServer(swa, max_batch=4, pipeline=pipe).serve(reqs)
    out_r = LMServer(swa, max_batch=4).serve(reqs)
    for a, b in zip(out_p, out_r):
        assert a.tokens == b.tokens, (a.uid, a.tokens, b.tokens)


def test_serve_rejects_empty_queue_and_samples_with_temperature(decode_setup):
    plan, stg = decode_setup
    pipe = DecodePipeline(tiny, stg, plan)
    with pytest.raises(ValueError, match="at least one prompt"):
        pipe.serve([], [])
    # ... but the server entry point mirrors the single-device backend
    # and drains an empty queue to an empty list
    assert LMServer(tiny, max_batch=4, pipeline=pipe).serve([]) == []
    assert LMServer(tiny, max_batch=4).serve([]) == []
    # LMServer forwards its temperature: the stochastic path runs end to
    # end (draws use per-group key streams, so only shape is asserted)
    srv = LMServer(tiny, max_batch=4, temperature=0.8, pipeline=pipe)
    outs = srv.serve(_reqs(4, seed=5, max_new=4))
    assert all(1 <= len(c.tokens) <= 4 for c in outs)


def test_decode_pipeline_rejects_encdec():
    from repro.configs import get_config
    cfg = get_config("seamless-m4t-medium").reduced()
    stg, _ = lm_graph.build_stg(cfg, ShapeCfg("encdec", 16, 8, "decode"),
                                max_tp=2)
    from repro.core.stg import Selection
    with pytest.raises(ValueError, match="decoder pipelines only"):
        DecodePipeline(cfg, stg, Selection.smallest(stg))


# ===========================================================================
# stream channel: continuous decode traffic
# ===========================================================================
def test_stream_channel_open_close_semantics():
    ch = StreamChannel(block=1, capacity_blocks=4)
    ch.push([(0, "a")], 0.0)
    assert not ch.exhausted
    ch.close()
    assert ch.closed and not ch.exhausted    # still a token to drain
    with pytest.raises(RuntimeError, match="after close"):
        ch.push([(1, "b")], 1.0)
    assert ch.pop(1) == [(0, "a")]
    assert ch.exhausted


def test_stream_channel_is_still_a_bounded_fifo():
    ch = StreamChannel(block=1, capacity_blocks=2)
    ch.push([1, 2], 0.0)
    assert not ch.can_push(1)
    with pytest.raises(OverflowError):
        ch.push([3], 0.0)


# ===========================================================================
# engine core
# ===========================================================================
@pytest.mark.parametrize("overlap", [True, False])
def test_engine_releases_held_slots_when_op_raises(overlap):
    """An op whose body raises must not leak its channel credits: the
    engine frees op.releases on the failure path — pooled and inline
    execution alike — so the fifo returns to full capacity instead of
    wedging later consumers."""
    fifo = Fifo(block=1, capacity_blocks=2)
    fifo.push([(0, "x")], 0.0)

    class Consumer:
        name = "cons"
        n_replicas = 1

        def __init__(self):
            self.done = False

        def pending(self):
            return 0 if self.done else 1

        def peek(self):
            return None if self.done else Op(stage=0, kind="F", seq=0, rep=0)

        def ready(self, op, count_stall=False):
            return 0.0 if fifo.can_pop(1) else None

        def dispatch(self, op, driver):
            self.done = True
            fifo.pop_hold(1)
            op.releases.append((fifo, 1))

            def boom():
                raise RuntimeError("op body failed")
            return boom, ()

        def retire(self, op, result, engine):
            raise AssertionError("retire must not run for a failed op")

        def describe(self):
            return "cons"

    eng = Engine([Consumer()], overlap=overlap, workers=2)
    with pytest.raises(RuntimeError, match="op body failed"):
        eng.run()
    assert fifo.free == fifo.capacity


def test_engine_detects_deadlock_with_program_state():
    class Stuck:
        name = "stuck"
        n_replicas = 1

        def pending(self):
            return 1

        def peek(self):
            return Op(stage=0, kind="F", seq=0, rep=0)

        def ready(self, op, count_stall=False):
            return None             # forever blocked, nothing in flight

        def dispatch(self, op, driver):
            raise AssertionError

        def retire(self, *a):
            raise AssertionError

        def describe(self):
            return "stuck: 0/1"

    with pytest.raises(RuntimeError, match="deadlock.*stuck: 0/1"):
        Engine([Stuck()], overlap=False).run()


# ===========================================================================
# multi-device pool (subprocess: XLA_FLAGS must be set before jax import)
# ===========================================================================
_SERVE_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, numpy as np
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import DecodePipeline
    from repro.runtime.server import LMServer, Request

    assert len(jax.devices()) == 8
    shape = ShapeCfg("decode_par", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = DecodePipeline(tiny, stg, plan)
    spread = {d for devs in pipe.stage_devices for d in devs}
    assert len(spread) > 1, f"stages all folded onto {spread}"
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, tiny.vocab,
                                        rng.integers(4, 20)).tolist(),
                    max_new=10)
            for i in range(12)]
    out_p = LMServer(tiny, max_batch=4, pipeline=pipe).serve(reqs)
    out_r = LMServer(tiny, max_batch=4).serve(reqs)
    for a, b in zip(out_p, out_r):
        assert a.tokens == b.tokens, (a.uid, a.tokens, b.tokens)
    assert sum(len(c.tokens) for c in out_p) > 12
    print("DECODE_PARITY_OK")
""")


def test_multidevice_decode_parity():
    """On an 8-device pool the decode pipeline spreads stages over real
    devices (caches resident per slice, activations device-to-device) and
    still generates token-identical completions to the single-device
    serve_round."""
    r = subprocess.run([sys.executable, "-c", _SERVE_MULTIDEV],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "DECODE_PARITY_OK" in r.stdout
