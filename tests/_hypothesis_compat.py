"""Degenerate hypothesis fallback for clean environments.

When the real ``hypothesis`` package is unavailable, ``conftest.py``
installs this module under ``sys.modules["hypothesis"]`` so test modules
importing ``from hypothesis import given, settings`` still collect and run.

``@given`` becomes a deterministic sampler: each strategy draws a fixed,
seeded pseudo-random stream of examples (seeded by the test's qualified
name), so the suite exercises a spread of inputs and failures reproduce
bit-for-bit.  This is NOT property-based testing — no shrinking, no
coverage-guided search — just enough fixed examples to keep the invariant
tests meaningful.  Install ``requirements-dev.txt`` for the real thing.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_EXAMPLES = 12
_MAX_EXAMPLES_CAP = 25        # keep the degenerate path fast


class SearchStrategy:
    """Base strategy: ``sample(rng)`` draws one example."""

    def sample(self, rng: random.Random):
        raise NotImplementedError

    # hypothesis API surface some tests touch
    def example(self):
        return self.sample(random.Random(0))

    def map(self, f):
        return _Mapped(self, f)

    def filter(self, pred, _tries: int = 100):
        return _Filtered(self, pred, _tries)


class _Mapped(SearchStrategy):
    def __init__(self, base, f):
        self.base, self.f = base, f

    def sample(self, rng):
        return self.f(self.base.sample(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred, tries):
        self.base, self.pred, self.tries = base, pred, tries

    def sample(self, rng):
        for _ in range(self.tries):
            x = self.base.sample(rng)
            if self.pred(x):
                return x
        raise ValueError("filter predicate never satisfied")


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(1 << 16) if min_value is None else min_value
        self.hi = (1 << 16) if max_value is None else max_value

    def sample(self, rng):
        # bias toward the boundaries — they are where invariants break
        r = rng.random()
        if r < 0.15:
            return self.lo
        if r < 0.3:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=0.0, max_value=1.0, **_kw):
        self.lo = 0.0 if min_value is None else min_value
        self.hi = 1.0 if max_value is None else max_value

    def sample(self, rng):
        return self.lo + (self.hi - self.lo) * rng.random()


class _Booleans(SearchStrategy):
    def sample(self, rng):
        return rng.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def sample(self, rng):
        return self.value


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def sample(self, rng):
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None, **_kw):
        self.elements = elements
        self.min_size = min_size
        self.max_size = min_size + 10 if max_size is None else max_size

    def sample(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.sample(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *elements):
        self.elements = elements

    def sample(self, rng):
        return tuple(e.sample(rng) for e in self.elements)


class _OneOf(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def sample(self, rng):
        return rng.choice(self.strategies).sample(rng)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kwargs):
            n = min(getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_EXAMPLES), _MAX_EXAMPLES_CAP)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                args = tuple(s.sample(rng) for s in arg_strategies)
                kwargs = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
        # NOT functools.wraps: copying __wrapped__/__signature__ would make
        # pytest unwrap to ``fn`` and treat its sampled params as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = _Integers
strategies.floats = _Floats
strategies.booleans = _Booleans
strategies.just = _Just
strategies.sampled_from = _SampledFrom
strategies.lists = _Lists
strategies.tuples = _Tuples
strategies.one_of = _OneOf


def install() -> None:
    """Register this module as ``hypothesis`` in ``sys.modules``."""
    mod = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
