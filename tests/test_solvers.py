"""ILP and heuristic solver correctness on randomised instances."""
import itertools
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import heuristic, ilp
from repro.core.fork_join import LITERAL, ForkJoinModel
from repro.core.stg import STG, Impl, Node, Selection, unit_rate_node
from repro.core.throughput import analyze, propagate_targets


def make_chain(impl_sets):
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    prev = "src"
    for k, impls in enumerate(impl_sets):
        n = f"n{k}"
        g.add_node(unit_rate_node(n, [Impl(f"v{i}", a, ii)
                                      for i, (a, ii) in enumerate(impls)]))
        g.connect(prev, n)
        prev = n
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect(prev, "out")
    g.validate()
    return g


def brute_force_min_area(g, v_tgt, fj):
    """Exhaustive reference for the ILP objective (selection + minimal nr,
    stand-alone tree overhead)."""
    names = [n for n in g.topo_order() if g.nodes[n].kind == "compute"]
    tgt = propagate_targets(g, v_tgt)
    best = math.inf
    for combo in itertools.product(*[g.nodes[n].impls for n in names]):
        total = 0.0
        for n, im in zip(names, combo):
            nr = max(1, math.ceil(im.ii / tgt[n] - 1e-12))
            total += nr * im.area + fj.replication_overhead(nr)
        best = min(best, total)
    return best


impl_strategy = st.lists(
    st.tuples(st.integers(1, 50), st.integers(1, 32)),  # (area, ii)
    min_size=1, max_size=4)


@settings(max_examples=40, deadline=None)
@given(st.lists(impl_strategy, min_size=1, max_size=4),
       st.sampled_from([1, 2, 3, 4, 8]))
def test_ilp_matches_brute_force(impl_sets, v_tgt):
    g = make_chain(impl_sets)
    res = ilp.min_area(g, v_tgt, LITERAL)
    assert math.isclose(res.total_area, brute_force_min_area(g, v_tgt, LITERAL))


@settings(max_examples=25, deadline=None)
@given(st.lists(impl_strategy, min_size=1, max_size=3),
       st.sampled_from([1, 2, 4]))
def test_heuristic_feasible_and_not_worse_than_ilp_objective(impl_sets, v_tgt):
    """Same-accounting dominance: the heuristic explores a superset of the
    ILP's move space (it evaluates the ILP's own selection as a fallback),
    so under the heuristic's costing it is never worse than the ILP's
    selection.  (Raw totals are NOT comparable across engines — each
    method prices fork/join with its own model, exactly as the paper's
    Table 2 does: ILP = stand-alone Eq. 9 trees, heuristic = free fan-out
    of nf; tests/test_jpeg_repro.py covers the published cross-engine
    comparison.)"""
    from repro.core.heuristic import _heuristic_fj, _total_cost
    g = make_chain(impl_sets)
    ri = ilp.min_area(g, v_tgt, LITERAL)
    rh = heuristic.min_area(g, v_tgt, LITERAL)
    assert rh.feasible
    assert analyze(g, rh.selection).v_app <= v_tgt + 1e-9
    a, oh = _total_cost(g, ri.selection, _heuristic_fj(LITERAL))
    assert rh.total_area <= a + oh + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(impl_strategy, min_size=1, max_size=3),
       st.integers(10, 2000))
def test_max_throughput_respects_budget(impl_sets, budget):
    g = make_chain(impl_sets)
    for solver in (ilp.max_throughput, heuristic.max_throughput):
        res = solver(g, float(budget), LITERAL)
        if res.feasible:
            assert res.total_area <= budget + 1e-6
            assert math.isclose(analyze(g, res.selection).v_app, res.v_app)


def test_max_throughput_monotone_in_budget():
    g = make_chain([[(10, 1), (5, 2), (1, 8)], [(20, 1), (2, 16)]])
    vs = []
    for budget in (5, 10, 20, 50, 100, 500):
        res = ilp.max_throughput(g, budget, LITERAL)
        if res.feasible:
            vs.append(res.v_app)
    assert vs == sorted(vs, reverse=True) or len(vs) <= 1


def test_ilp_milp_backend_agrees_with_bisection():
    g = make_chain([[(10, 1), (5, 2), (1, 8)], [(20, 1), (2, 16)], [(7, 3)]])
    for budget in (10.0, 40.0, 200.0):
        a = ilp.max_throughput(g, budget, LITERAL, solver="milp")
        b = ilp.max_throughput(g, budget, LITERAL, solver="auto")
        if a.feasible and b.feasible:
            assert math.isclose(a.v_app, b.v_app, rel_tol=1e-6)
