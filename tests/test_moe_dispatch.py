"""Sorted (ragged) MoE dispatch vs the GShard einsum reference.

The sorted path is the §Perf Cell-B optimisation; it must be numerically
identical to the einsum path whenever capacity drops nothing, locally AND
under a real sharded mesh (8 simulated devices, shard_map all_to_all).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import blocks
from repro.models.common import KeyGen


def _cfg(top_k=1, experts=8, cf=8.0):
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=top_k,
                                     n_experts=experts,
                                     capacity_factor=cf))


@pytest.mark.parametrize("top_k,experts", [(1, 8), (2, 8), (2, 4)])
def test_sorted_matches_einsum_no_drops(top_k, experts):
    cfg = _cfg(top_k, experts)
    p = blocks.init_moe(KeyGen(jax.random.PRNGKey(0)), cfg, "t")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    a = blocks.moe_forward(p, cfg, x)
    b = blocks.moe_forward_sorted(p, cfg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_sorted_capacity_drops_tokens_deterministically():
    """With tiny capacity the sorted path drops the lowest-rank tokens per
    expert; output must still be finite and the kept tokens unchanged."""
    cfg = _cfg(1, 4, cf=0.26)      # cap ~= S*0.26/4 -> heavy dropping
    p = blocks.init_moe(KeyGen(jax.random.PRNGKey(0)), cfg, "t")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model),
                          jnp.float32)
    y1 = blocks.moe_forward_sorted(p, cfg, x)
    y2 = blocks.moe_forward_sorted(p, cfg, x)
    assert bool(jnp.isfinite(y1).all())
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import blocks
    from repro.models.common import KeyGen
    from repro import sharding_ctx as sc
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config("llama4-scout-17b-a16e").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, top_k=2, n_experts=8, capacity_factor=8.0))
    p = blocks.init_moe(KeyGen(jax.random.PRNGKey(0)), cfg, "t")
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32)
    ref = blocks.moe_forward(p, cfg, x)          # unsharded einsum oracle

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = sc.from_mesh(mesh, ep_data=True)
    # place params/inputs as the launcher would (experts on "data",
    # F on "model"; batch on "data")
    def put(tree, specs):
        return jax.tree.map(lambda t, s: jax.device_put(
            t, NamedSharding(mesh, s)), tree, specs)
    p_sh = dict(p)
    p_sh["experts"] = put(p["experts"], {
        "w_gate": P("data", None, "model"), "w_up": P("data", None, "model"),
        "w_down": P("data", "model", None)})
    p_sh["shared"] = p["shared"] if "shared" in p else None
    if p_sh["shared"] is None:
        p_sh.pop("shared")
    x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))

    with mesh, sc.activate(ctx):
        got = jax.jit(lambda pp, xx: blocks.moe_forward_sorted(pp, cfg, xx))(
            p_sh, x_sh)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 3e-3, err
    print("SHARDED_OK", err)
""")


def test_sorted_dispatch_sharded_8dev_matches_oracle():
    """The full shard_map path (all_to_all over 'data', psum over 'model')
    must reproduce the unsharded einsum oracle."""
    r = subprocess.run([sys.executable, "-c", _SHARDED],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2500:])
    assert "SHARDED_OK" in r.stdout
