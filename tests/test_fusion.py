"""Executable stage fusion (`fusion_plan` on both executors).

Acceptance contract:
  * a fused `DecodePipeline` generates bitwise-identical tokens to the
    unfused pipeline (and hence the single-device reference) with
    ``late == 0`` compile stats — one AOT program per combined stage;
  * ``fusion_plan="auto"`` selects the planner's endpoint fusion
    (embed+blocks00, blocks03+head on the tiny decode plan);
  * the source stage (embed) appears in traced ``stage_wait_s`` — the
    engine attributes queue-empty idle via ``idle_reason()``;
  * a replica of a COMBINED stage can crash mid-decode and fail over
    with bitwise token parity + failover evidence (replica pooling gives
    a fused stage its members' slices);
  * elastic rescale carries the fusion plan to the successor pipeline;
  * the fused training pipeline (`LMPipeline`) matches the unfused run
    bitwise on losses AND grads (member-keyed grad trees).
"""
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.tiny import CONFIG as tiny
from repro.core import planner
from repro.graphs import lm_graph
from repro.runtime.pipeline import (DecodePipeline, LMPipeline, Tracer,
                                    as_selection)
from repro.runtime.failures import ReplicaFaultPlan

TARGET = (("embed", "blocks00"), ("blocks01",), ("blocks02",),
          ("blocks03", "head"))


@pytest.fixture(scope="module")
def fusion_setup():
    shape = ShapeCfg("fusion_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, tiny.vocab, rng.integers(4, 20)).tolist()
               for _ in range(8)]
    base = DecodePipeline(tiny, stg, plan)
    ref = base.serve(prompts, 12, group_size=4)
    return shape, plan, stg, prompts, base, ref


def test_fused_decode_token_parity_and_aot(fusion_setup):
    _, plan, stg, prompts, _, ref = fusion_setup
    pipe = DecodePipeline(tiny, stg, plan, fusion_plan=list(TARGET))
    assert pipe.stage_names == ["embed+blocks00", "blocks01", "blocks02",
                                "blocks03+head"]
    res = pipe.serve(prompts, 12, group_size=4)
    assert res.tokens == ref.tokens
    assert pipe.compile_stats.late == 0, pipe.compile_stats.summary()


def test_auto_fusion_selects_planner_groups(fusion_setup):
    _, plan, stg, prompts, _, ref = fusion_setup
    pipe = DecodePipeline(tiny, stg, plan, fusion_plan="auto")
    assert pipe.fusion_plan == TARGET
    res = pipe.serve(prompts, 12, group_size=4)
    assert res.tokens == ref.tokens


def test_fused_serial_engine_parity(fusion_setup):
    """The serial A/B driver (overlap=False) runs the same fused stage
    graph and must produce identical tokens."""
    _, plan, stg, prompts, _, ref = fusion_setup
    pipe = DecodePipeline(tiny, stg, plan, fusion_plan=list(TARGET),
                          overlap=False)
    res = pipe.serve(prompts, 12, group_size=4)
    assert res.tokens == ref.tokens


def test_fusion_plan_must_be_contiguous_partition(fusion_setup):
    _, plan, stg, _, _, _ = fusion_setup
    with pytest.raises(ValueError, match="contiguous partition"):
        DecodePipeline(tiny, stg, plan,
                       fusion_plan=[("embed", "blocks01"), ("blocks00",),
                                    ("blocks02",), ("blocks03", "head")])
    with pytest.raises(ValueError, match="contiguous partition"):
        DecodePipeline(tiny, stg, plan, fusion_plan=[("embed", "blocks00")])


def test_embed_idle_is_accounted(fusion_setup):
    """Satellite: the source stage's queue-empty waits (its op arrives in
    the same head retirement that pushes its feedback token) now open
    spans via ``idle_reason()`` — embed no longer vanishes from the
    stall/starve attribution."""
    _, plan, stg, prompts, base, _ = fusion_setup
    tr = Tracer()
    res = base.serve(prompts, 24, group_size=4, tracer=tr)
    assert "embed" in res.stage_wait_s
    assert res.stage_wait_s["embed"].get("starve", 0.0) > 0.0
    # the fused pipeline's source stage is accounted under its fused name
    fpipe = DecodePipeline(tiny, stg, plan, fusion_plan=list(TARGET))
    res_f = fpipe.serve(prompts, 24, group_size=4, tracer=Tracer())
    assert "embed+blocks00" in res_f.stage_wait_s


def test_fused_stage_failover_bitwise_parity(fusion_setup):
    """Kill a replica of a COMBINED stage mid-decode: replica pooling
    (the fused stage unions its members' placement slices) leaves a
    survivor, lost ops replay, and token parity holds bitwise."""
    _, plan, stg, prompts, _, ref = fusion_setup
    pipe = DecodePipeline(tiny, stg, plan, fusion_plan=list(TARGET))
    s = pipe.stage_names.index("embed+blocks00")
    assert len(pipe.stage_devices[s]) >= 2, "fused stage lost its pooled replicas"
    inj = ReplicaFaultPlan.parse("embed+blocks00:r1@tok6=crash")
    tr = Tracer()
    res = pipe.serve(prompts, 12, group_size=4, injector=inj, tracer=tr)
    assert inj.fired == 1
    assert res.tokens == ref.tokens
    assert len(res.failovers) == 1
    fo = res.failovers[0]
    assert fo["stage"] == "embed+blocks00" and fo["kind"] == "crash"
    assert fo["recovery_s"] >= 0.0
    assert tr.failovers and tr.failovers[0][0] == "embed+blocks00"


def test_fused_rescale_preserves_fusion_plan(fusion_setup):
    """Elastic rescale rebuilds the pipeline with the same fusion plan and
    the resumed serve stays bitwise."""
    from repro.runtime.elastic import rescale_serving

    shape, plan, stg, prompts, _, ref = fusion_setup
    pipe = DecodePipeline(tiny, stg, plan, fusion_plan=list(TARGET))
    paused = pipe.serve(prompts, 12, group_size=4, pause_after_tokens=3)
    assert paused.paused and paused.resume_state is not None
    rs = rescale_serving(pipe, tiny, shape, plan, new_chips=6, stg=stg,
                         measured_ratio={"embed+blocks00": 2.0})
    assert rs.pipe.fusion_plan == TARGET
    res = rs.pipe.resume(paused.resume_state)
    assert res.tokens == ref.tokens


# ===========================================================================
# training path (LMPipeline)
# ===========================================================================
def test_fused_lm_pipeline_bitwise_losses_and_grads():
    import jax
    import jax.numpy as jnp

    shape = ShapeCfg("fusion_train", 64, 16, "train")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    sel = as_selection(plan)
    mbs = [np.random.default_rng(i).integers(
        2, tiny.vocab, (2, 16)).astype(np.int32) for i in range(4)]

    def loss(lg):
        return jnp.mean(lg.astype(jnp.float32) ** 2)

    pu = LMPipeline(tiny, stg, sel)
    ru = pu.run(mbs, train=True, loss_fn=loss)
    fp = [("embed", "block00"), ("block01",), ("block02",),
          ("block03", "head")]
    pf = LMPipeline(tiny, stg, sel, fusion_plan=fp)
    assert [s.name for s in pf.stages] == \
        ["embed+block00", "block01", "block02", "block03+head"]
    rf = pf.run(mbs, train=True, loss_fn=loss)

    for mb in ru.losses:
        assert float(ru.losses[mb]) == float(rf.losses[mb])

    def assert_tree_equal(a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    assert_tree_equal(ru.grads["embed"], rf.grads["embed+block00"]["embed"])
    assert_tree_equal(ru.grads["block00"],
                      rf.grads["embed+block00"]["block00"])
    assert_tree_equal(ru.grads["block01"], rf.grads["block01"])
    assert_tree_equal(ru.grads["block03"],
                      rf.grads["block03+head"]["block03"])
    assert_tree_equal(ru.grads["head"], rf.grads["block03+head"]["head"])


def test_fused_lm_pipeline_serve_outputs_bitwise():
    shape = ShapeCfg("fusion_serve", 64, 16, "train")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    sel = as_selection(plan)
    mbs = [np.random.default_rng(i).integers(
        2, tiny.vocab, (2, 16)).astype(np.int32) for i in range(3)]
    ru = LMPipeline(tiny, stg, sel).run(mbs)
    pf = LMPipeline(tiny, stg, sel, fusion_plan="auto")
    rf = pf.run(mbs)
    assert pf.compile_stats.late == 0
    for a, b in zip(ru.outputs, rf.outputs):
        assert np.array_equal(np.asarray(a), np.asarray(b))
