"""Stage combining & splitting (`core/restructure`): plan-level rewrites.

Acceptance contract:
  * ``combine`` merges a linear chain into one node with II/area/latency
    sums and deletes the internal channels; ``split`` of the result
    restores the originals bit-for-bit (round trip on IIs, areas, impls,
    channel keys, Selection);
  * ``split`` of a plain node partitions II/area at the declared cut and
    ``combine`` of the halves restores the original exactly;
  * rewrites are functionally invisible: the KPN simulator produces the
    same sink streams before and after a combine;
  * ``auto_fusion`` selects endpoint fusion on the tiny decode chain
    (under uniform and measured host cost, and at the fixed point when
    re-scored with fused-run measurement keys) and structurally refuses
    to fuse two heavy (state-owning) stages;
  * `planner.plan_fusion` drives the scorer from a real plan.
"""
import math

import pytest

from repro.configs.base import ShapeCfg
from repro.configs.tiny import CONFIG as tiny
from repro.core import planner, restructure
from repro.core.restructure import (auto_fusion, combine, enumerate_fusions,
                                    score_fusion, split)
from repro.core.simulate import run_functional
from repro.core.stg import STG, Impl, Node, Selection, unit_rate_node
from repro.graphs import lm_graph


# ===========================================================================
# fixtures
# ===========================================================================
def _chain(iis, areas=None, with_fns=True):
    """src -> n0 -> n1 -> ... -> out, unit rates, +1 per hop."""
    areas = areas or [1.0] * len(iis)
    g = STG()
    g.add_node(Node("src", impls=(Impl("s", 0, 1e-9),), kind="source"))
    prev = "src"
    for k, (ii, area) in enumerate(zip(iis, areas)):
        def mk():
            def fn(inputs, state):
                return [[inputs[0][0] + 1]], state
            return fn
        g.add_node(unit_rate_node(f"n{k}", [Impl("v1", area, ii)],
                                  fn=mk() if with_fns else None))
        g.connect(prev, f"n{k}")
        prev = f"n{k}"
    g.add_node(Node("out", impls=(Impl("t", 0, 1e-9),), kind="sink"))
    g.connect(prev, "out")
    g.validate()
    return g


def _lm_setup():
    shape = ShapeCfg("restructure_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    sel = Selection()
    for sp in plan.stages:
        sel.set(sp.name, sp.impl, sp.replicas)
    return shape, plan, stg, sel


# ===========================================================================
# combine
# ===========================================================================
def test_combine_sums_ii_area_and_deletes_channel():
    g = _chain([2.0, 3.0, 5.0], areas=[10.0, 20.0, 40.0])
    sel = Selection.fastest(g)
    rg = combine(g, sel, ["n0", "n1"])
    fused = rg.stg.nodes["n0+n1"]
    im = rg.selection.impl_of(rg.stg, "n0+n1")
    assert im.ii == 5.0 and im.area == 30.0
    assert rg.groups == {"n0+n1": ("n0", "n1")}
    assert [c.key() for c in rg.deleted_channels] == [("n0", 0, "n1", 0)]
    keys = {(c.src, c.dst) for c in rg.stg.channels}
    assert ("src", "n0+n1") in keys and ("n0+n1", "n2") in keys
    assert fused.kind == "compute"


def test_combine_is_functionally_invisible():
    g = _chain([1.0, 2.0, 1.0])
    sel = Selection.fastest(g)
    before = run_functional(g, sel, {"src": list(range(16))})
    rg = combine(g, sel, ["n1", "n2"])
    after = run_functional(rg.stg, rg.selection, {"src": list(range(16))})
    assert before["out"] == after["out"] == [x + 3 for x in range(16)]


def test_combined_timed_throughput_matches_analysis():
    """Virtual clock: the combined graph's simulated inverse throughput
    tracks the analytic model (II sums; the fused node is the new
    bottleneck)."""
    from repro.core.simulate import run as sim_run
    from repro.core.throughput import analyze

    g = _chain([2.0, 3.0, 4.0])
    sel = Selection.fastest(g)
    rg = combine(g, sel, ["n0", "n1"])
    res = sim_run(rg.stg, rg.selection, {"src": list(range(200))})
    ana = analyze(rg.stg, rg.selection)
    assert math.isclose(ana.v_app, 5.0)
    assert math.isclose(res.inverse_throughput("out"), ana.v_app,
                        rel_tol=0.05)


def test_combine_rejects_nonlinear_and_mismatched():
    g = _chain([1.0, 1.0, 1.0])
    sel = Selection.fastest(g)
    with pytest.raises(ValueError, match="at least two"):
        combine(g, sel, ["n0"])
    with pytest.raises(ValueError, match="exactly one channel"):
        combine(g, sel, ["n0", "n2"])          # not adjacent
    with pytest.raises(KeyError):
        combine(g, sel, ["n0", "nope"])
    with pytest.raises(ValueError, match="only compute"):
        combine(g, sel, ["src", "n0"])
    sel2 = Selection.fastest(g).set("n1", "v1", 2)
    with pytest.raises(ValueError, match="replica counts"):
        combine(g, sel2, ["n0", "n1"])


# ===========================================================================
# split + round trips
# ===========================================================================
def test_split_combine_round_trip_restores_exactly():
    g = _chain([2.0, 3.0], areas=[8.0, 16.0])
    sel = Selection.fastest(g)
    rg = split(g, sel, "n1", cut=0.4)
    a, b = rg.groups["n1"]
    ia = rg.selection.impl_of(rg.stg, a)
    ib = rg.selection.impl_of(rg.stg, b)
    assert math.isclose(ia.ii + ib.ii, 3.0)
    assert math.isclose(ia.ii, 0.4 * 3.0)
    assert math.isclose(ia.area + ib.area, 16.0)
    back = combine(rg.stg, rg.selection, [a, b])
    assert set(back.stg.nodes) == set(g.nodes)
    assert back.selection.choices == sel.choices
    assert {c.key() for c in back.stg.channels} == \
        {c.key() for c in g.channels}
    im = back.selection.impl_of(back.stg, "n1")
    assert im.ii == 3.0 and im.area == 16.0
    # the restored node kept its executable fn
    outs = run_functional(back.stg, back.selection, {"src": [0, 1, 2]})
    assert outs["out"] == [2, 3, 4]


def test_combine_split_round_trip_restores_exactly():
    g = _chain([2.0, 3.0, 5.0], areas=[1.0, 2.0, 4.0])
    sel = Selection.fastest(g)
    rg = combine(g, sel, ["n1", "n2"])
    back = split(rg.stg, rg.selection, "n1+n2")
    assert set(back.stg.nodes) == set(g.nodes)
    assert {c.key() for c in back.stg.channels} == \
        {c.key() for c in g.channels}
    for n in ("n1", "n2"):
        assert back.selection.impl_of(back.stg, n).ii == \
            sel.impl_of(g, n).ii
        assert back.selection.impl_of(back.stg, n).area == \
            sel.impl_of(g, n).area
    assert back.selection.choices == sel.choices


def test_split_rejects_bad_cut():
    g = _chain([1.0, 4.0])
    sel = Selection.fastest(g)
    for cut in (0.0, 1.0, -0.5, 2.0):
        with pytest.raises(ValueError, match="cut"):
            split(g, sel, "n1", cut=cut)
    with pytest.raises(KeyError):
        split(g, sel, "nope")


def test_round_trip_on_lm_graph():
    """combine/split on the real decode-shape LM graph, untouched channels
    preserved verbatim (the `validate_restructure` contract)."""
    _, _, stg, sel = _lm_setup()
    blocks = sorted(n for n in stg.nodes if n.startswith("block"))
    rg = combine(stg, sel, ["embed", blocks[0]])
    assert "embed+" + blocks[0] in rg.stg.nodes
    back = split(rg.stg, rg.selection, "embed+" + blocks[0])
    assert set(back.stg.nodes) == set(stg.nodes)
    assert {c.key() for c in back.stg.channels} == \
        {c.key() for c in stg.channels}
    assert back.selection.choices == sel.choices


# ===========================================================================
# fusion scoring
# ===========================================================================
NAMES = ["embed", "blocks00", "blocks01", "blocks02", "blocks03", "head"]
HEAVY = [n for n in NAMES if n.startswith("blocks")]
TARGET = (("embed", "blocks00"), ("blocks01",), ("blocks02",),
          ("blocks03", "head"))


def test_enumerate_fusions_excludes_heavy_pairs():
    cands = enumerate_fusions(NAMES, heavy=HEAVY)
    assert (tuple((n,) for n in NAMES)) in cands
    assert TARGET in cands
    for cand in cands:
        for g in cand:
            assert sum(1 for n in g if n in HEAVY) <= 1


def test_auto_fusion_uniform_picks_endpoint_fusion():
    """No measurements: the score reduces to dispatch-count minimization
    under the structural rules, which uniquely fuses the endpoints."""
    sc = auto_fusion(NAMES, heavy=HEAVY, dev_in_score=False)
    assert sc.groups == TARGET and sc.fused


def test_auto_fusion_measured_picks_endpoint_fusion():
    host = {"embed": 344.0, "blocks00": 691.0, "blocks01": 616.0,
            "blocks02": 539.0, "blocks03": 776.0, "head": 397.0}
    dev = {n: 2.7 if n.startswith("blocks") else 2.5 for n in NAMES}
    sc = auto_fusion(NAMES, host_us=host, dev_us=dev, heavy=HEAVY)
    assert sc.groups == TARGET
    unfused = score_fusion(tuple((n,) for n in NAMES), host_us=host,
                           dev_us=dev)
    assert sc.period_us < unfused.period_us
    assert sc.host_us < unfused.host_us     # two dispatches deleted


def test_auto_fusion_fixed_point_with_fused_keys():
    """Re-scoring with measurements keyed by the fused stage names keeps
    the same winner (members inherit their group's dispatch cost)."""
    host = {"embed+blocks00": 700.0, "blocks01": 616.0, "blocks02": 539.0,
            "blocks03+head": 780.0}
    dev = {n: 2.7 if n.startswith("blocks") else 2.5 for n in NAMES}
    sc = auto_fusion(NAMES, host_us=host, dev_us=dev, heavy=HEAVY)
    assert sc.groups == TARGET


def test_auto_fusion_respects_replica_mismatch():
    reps = {n: 1 for n in NAMES}
    reps["blocks00"] = 2                    # embed can't join blocks00
    sc = auto_fusion(NAMES, heavy=HEAVY, replicas=reps, dev_in_score=False)
    for g in sc.groups:
        assert "embed" not in g or len(g) == 1 or "blocks00" not in g


def test_plan_fusion_on_real_plan():
    shape, plan, _, _ = _lm_setup()
    sc = planner.plan_fusion(tiny, shape, plan)
    assert sc.groups == TARGET
    host = {"embed": 344.0, "blocks00": 691.0, "blocks01": 616.0,
            "blocks02": 539.0, "blocks03": 776.0, "head": 397.0}
    sc2 = planner.plan_fusion(tiny, shape, plan, host_us=host)
    assert sc2.groups == TARGET


def test_replan_reports_fusion_groups():
    shape, plan, _, _ = _lm_setup()
    host = {"embed": 344.0, "blocks00": 691.0, "blocks01": 616.0,
            "blocks02": 539.0, "blocks03": 776.0, "head": 397.0}
    new, diff = planner.replan(tiny, shape, plan, new_chips=8,
                               fusion_host_us=host)
    assert diff["fusion_groups"] == TARGET
